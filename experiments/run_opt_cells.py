"""Bonus beyond-paper optimized variants for additional cells.

Applies the validated §Perf knobs (sequence parallelism + CP attention;
EP-over-all for MoE decode) to more (arch x shape) pairs and saves tagged
artifacts next to the baselines.

    PYTHONPATH=src python experiments/run_opt_cells.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import dryrun

CELLS = [
    # (arch, shape, mesh, overrides)
    ("yi-34b", "train_4k", "single", {"seq_shard": True}),
    ("minicpm3-4b", "train_4k", "single", {"seq_shard": True}),
    ("starcoder2-3b", "train_4k", "single", {"seq_shard": True}),
    ("deepseek-v3-671b", "train_4k", "single",
     {"seq_shard": True, "accum_steps": 16}),
    ("dbrx-132b", "decode_32k", "single", {"ep_over_data": True}),
]


def main():
    rows = []
    for arch, shape, mesh, ov in CELLS:
        base = dryrun.run_cell(arch, shape, mesh, save=False, verbose=False)
        opt = dryrun.run_cell(arch, shape, mesh, overrides=ov, tag="opt",
                              save=True, verbose=False)
        b, o = base["roofline"], opt["roofline"]
        b_dom = max(b["compute_s"], b["memory_s"], b["collective_s"])
        o_dom = max(o["compute_s"], o["memory_s"], o["collective_s"])
        rows.append((arch, shape, b_dom, o_dom, b_dom / max(o_dom, 1e-12),
                     opt["fits_hbm_16g"]))
        print(f"{arch:20s} {shape:12s} dominant {b_dom:8.2f}s -> "
              f"{o_dom:8.2f}s  ({b_dom / max(o_dom, 1e-12):5.2f}x) "
              f"fits={opt['fits_hbm_16g']}")
    return rows


if __name__ == "__main__":
    main()
