"""Train a ~100M-parameter LM for a few hundred steps (deliverable (b)).

    PYTHONPATH=src python examples/train_lm.py --steps 300

Full production path on local devices: sharded init, synthetic pipeline,
jit train step with gradient accumulation, async checkpointing + restore.
The config is a scaled qwen3-family model (qk_norm + GQA) of ~100M
parameters.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import configs
from repro.launch.train import train
from repro.models import model as model_lib


def lm100m():
    return configs.get("qwen3-14b").replace(
        name="qwen3-100m",
        n_layers=10, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32000, dtype="float32", remat=False,
        accum_steps=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = lm100m()
    n = model_lib.count_params(cfg)
    print(f"training {cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    # register the custom config so the standard driver can use it
    import repro.configs as C
    import types
    mod = types.ModuleType("lm100m_cfg")
    mod.CONFIG = cfg
    mod.REDUCED = cfg
    sys.modules["lm100m_cfg"] = mod
    C._MODULES["qwen3-100m"] = "lm100m_cfg"

    out = train("qwen3-100m", reduced=False, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=100, log_every=20)
    print(f"\nloss: {out['first_loss']:.4f} -> {out['last_loss']:.4f} "
          f"(improvement {(out['first_loss'] - out['last_loss']):.4f})")


if __name__ == "__main__":
    main()
