"""LM serving through the paper's scheduler (mixed-cost decode requests).

    PYTHONPATH=src python examples/serve_lm.py --arch starcoder2-3b

The paper's workload shape — many evaluations with unpredictable per-
request cost — transplanted onto LM serving: variable-length prompts are
dispatched FCFS to persistent model servers (warm jit caches = warm
UM-Bridge servers) vs naive per-request servers.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import configs
from repro.launch.serve import serve_benchmark


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b",
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()

    for persistent in (True, False):
        out = serve_benchmark(args.arch, n_requests=args.requests,
                              max_new=args.max_new,
                              n_workers=args.workers, persistent=persistent,
                              max_len=128, reduced=True)
        s = out["summary"]
        mode = "persistent (HQ)" if persistent else "per-request (naive)"
        print(f"{mode:22s}: wall {out['wall']:6.2f}s  "
              f"cpu {s.total_cpu_time:6.2f}s  "
              f"{out['tokens']} tokens generated")


if __name__ == "__main__":
    main()
