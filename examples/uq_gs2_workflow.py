"""End-to-end UQ workflow driver (the paper's target use case, §III/§VI).

    PYTHONPATH=src python examples/uq_gs2_workflow.py [--n-sims 24]

Pipeline (all scheduled through the persistent-worker load balancer):
  1. Latin-hypercube sample the 7 GS2 inputs (Table II ranges).
  2. Run the GS2-proxy linear-stability solves — genuinely variable
     runtimes — as load-balanced tasks; compare HQ vs naive backends.
  3. Train the GP surrogate (growth rate, frequency) on the results.
  4. Compute the quasilinear QoI integral (eq. 5) two ways:
     direct quadrature on the surrogate, and adaptive Bayesian quadrature
     with *dependent* tasks (each new node conditions on all previous) —
     the paper's 'loosely dependent tasks' future workload.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import EvalRequest, Executor, LambdaModel, metrics
from repro.uq import gp as gp_lib
from repro.uq import gs2_proxy, qoi, sampling

RESOLUTION = 48            # proxy field-line resolution (CPU-friendly)


def gs2_factory():
    solver = gs2_proxy.make_solver(m=RESOLUTION)   # per-server jit cache

    def fn(parameters, config):
        g, f = solver(np.asarray(parameters[0], np.float32))
        return [[g, f]]

    return LambdaModel(
        "gs2", fn, 7, 2,
        warmup_fn=lambda: solver(np.full(7, 0.5, np.float32)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-sims", type=int, default=24)
    ap.add_argument("--workers", type=int, default=4)
    args = ap.parse_args()

    # 1. seeded LHS over Table II ranges ------------------------------
    thetas = sampling.latin_hypercube(args.n_sims, seed=11)

    # 2. schedule the simulations -------------------------------------
    print(f"== scheduling {args.n_sims} GS2-proxy solves ==")
    outputs = {}
    for persistent, label in ((True, "HQ (persistent workers)"),
                              (False, "naive (fresh server per task)")):
        t0 = time.monotonic()
        with Executor({"gs2": gs2_factory}, n_workers=args.workers,
                      persistent_servers=persistent,
                      straggler_factor=6.0) as ex:
            reqs = [EvalRequest("gs2", [t.tolist()]) for t in thetas]
            results = ex.run_all(reqs, timeout=900)
            s = metrics.summarize("gs2", label, ex.records())
        wall = time.monotonic() - t0
        print(f"{label:32s} wall {wall:6.2f}s  cpu {s.total_cpu_time:6.2f}s  "
              f"init-share {1 - s.total_compute / max(s.total_cpu_time, 1e-9):.1%}")
        if persistent:
            outputs = {r.task_id: r.value[0] for r in results}
            order = [r.task_id for r in results]

    y = np.array([outputs[t] for t in order])
    print(f"\ngrowth rates: min {y[:, 0].min():.3f} max {y[:, 0].max():.3f} "
          f"({(y[:, 0] > 0).sum()}/{len(y)} unstable)")

    # 3. GP surrogate ---------------------------------------------------
    post = gp_lib.fit(thetas, y, steps=150)
    mean, var = gp_lib.predict(post, thetas[:4])
    err = float(np.max(np.abs(np.asarray(mean) - y[:4])))
    print(f"GP surrogate trained: max train-point error {err:.4f}")

    # 4. QoI integral (eq. 5) ------------------------------------------
    base = thetas[0]

    def surrogate(x):
        m, _ = gp_lib.predict(post, x[None])
        return float(m[0, 0]), float(m[0, 1])

    t0 = time.monotonic()
    direct = qoi.quadrature(surrogate, base, n_ky=8, n_theta0=8)
    t_direct = time.monotonic() - t0
    t0 = time.monotonic()
    bq = qoi.bayesian_quadrature(surrogate, base, n_init=6, n_adaptive=8)
    t_bq = time.monotonic() - t0
    print(f"\nQoI (direct quadrature, {direct.n_evals} nodes): "
          f"{direct.value:.5f}  [{t_direct:.2f}s]")
    print(f"QoI (Bayesian quadrature, {bq.n_evals} nodes):  "
          f"{bq.value:.5f} +/- {bq.uncertainty:.5f}  [{t_bq:.2f}s]")
    print("\nworkflow complete.")


if __name__ == "__main__":
    main()
