"""Quickstart: UM-Bridge-style models behind the HQ load balancer.

    PYTHONPATH=src python examples/quickstart.py

Registers two forward models (an eigenproblem and a GP surrogate), runs a
batch of evaluation requests through the persistent-worker load balancer,
and prints the scheduling metrics the paper is about.
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import EvalRequest, LoadBalancer, metrics
from repro.uq import gp as gp_lib
from repro.uq import sampling
from repro.uq.eigen import EigenModel


def gp_model_factory():
    """A small GP surrogate of the GS2 growth rate (trained on synthetic
    observations here; examples/uq_gs2_workflow.py trains on the real
    proxy)."""
    from repro.core.task import LambdaModel
    thetas = sampling.latin_hypercube(32, seed=0)
    y = np.sin(thetas[:, 6] * 3) * thetas[:, 3] * 0.1
    post = gp_lib.fit(thetas, y, steps=60)

    def fn(parameters, config):
        mean, var = gp_lib.predict(post, np.asarray(parameters, np.float32))
        return [[float(mean[0, 0]), float(var[0, 0])]]

    return LambdaModel("gp-surrogate", fn, 7, 2,
                       warmup_fn=lambda: fn([thetas[0].tolist()], None))


def main():
    with LoadBalancer(backend="hq", n_workers=4) as lb:
        lb.register_model("eigen-100", lambda: EigenModel(100))
        lb.register_model("gp-surrogate", gp_model_factory)
        print("registered models:",
              {k: (v.input_sizes, v.output_sizes)
               for k, v in lb.models().items()})

        # one-off synchronous call (the umbridge client pattern)
        out = lb.evaluate("eigen-100", [[0]])
        print(f"eigen-100([[0]]) -> spectral abscissa {out[0][0]:.4f}")

        # a batch of mixed-cost requests, first-come-first-served
        thetas = sampling.latin_hypercube(16, seed=1)
        reqs = [EvalRequest("gp-surrogate", [t.tolist()]) for t in thetas]
        reqs += [EvalRequest("eigen-100", [[0]]) for _ in range(8)]
        t0 = time.monotonic()
        results = lb.run_all(reqs, timeout=300)
        wall = time.monotonic() - t0

        ok = sum(r.status == "ok" for r in results)
        summary = metrics.summarize("quickstart", "hq", lb.records())
        print(f"\n{ok}/{len(results)} evaluations ok in {wall:.2f}s wall")
        print(f"total cpu  : {summary.total_cpu_time:.2f}s")
        print(f"overhead   : {summary.scheduling_overhead:.3f}s "
              f"(median/task {summary.overhead_stats['median'] * 1e3:.1f}ms)")
        print(f"SLR        : {summary.slr:.2f}")


if __name__ == "__main__":
    main()
