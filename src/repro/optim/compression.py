"""Gradient compression with error feedback (int8 block quantization).

A distributed-optimization trick for cross-pod (DCN) gradient reduction:
quantize each gradient leaf to int8 with a per-block scale before the slow
inter-pod reduction, carrying the quantization error into the next step
(error feedback keeps convergence unbiased in expectation).  On a real
multi-pod deployment the int8 payload is what crosses the DCN; here the
quantize/dequantize pair is applied to the gradient tree inside train_step
(flag-gated), and tests assert the error-feedback invariant.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def init_compression_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


CompressionState = Any


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_with_feedback(grads, err_state):
    """-> (decompressed grads, new error state).  Round-trips through int8."""
    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _quantize(corrected)
        deq = _dequantize(q, scale, g.shape)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree.map(leaf, grads, err_state)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e
