from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               abstract_opt_state, opt_state_axes,
                               cosine_schedule, global_norm)
from repro.optim.compression import (CompressionState, compress_with_feedback,
                                     init_compression_state)
