"""AdamW in pure JAX with sharding-aware state trees.

Moments inherit the parameter logical axes (so FSDP/TP sharding of the
optimizer state is automatic), and their dtype is a config knob — bf16
moments halve optimizer HBM for the 671B config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"


def cosine_schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moments_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(abstract_params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moments_dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {"m": jax.tree.map(sds, abstract_params),
            "v": jax.tree.map(sds, abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_state_axes(params_axes) -> Dict[str, Any]:
    """Moments share the parameter logical axes; step is replicated."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    return {"m": params_axes, "v": params_axes, "step": ()}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = cosine_schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    mdt = jnp.dtype(cfg.moments_dtype)

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
