"""Deterministic fault injection + hardened recovery (`repro.chaos`).

Failure is a *testable input* here, not an accident: a seeded
`FaultPlan` declares a schedule of fault events (worker crashes,
SLURM-style allocation preemptions with a grace-period drain, slow-node
degradation, task-result corruption, transient surrogate outages,
journal torn-writes) and a `ChaosInjector` fires them at the shared
`LifecycleStepper` choke point — so `simulate_cluster` and the live
`Executor` replay observe *identical* fault sequences and the PR-4
parity harness extends to faulted runs (`run_parity(...,
fault_plan=...)` stays exact).

The recovery side is hardened in `repro.core`/`repro.cluster`
(`RetryPolicy` backoff + seeded jitter, poison-task quarantine,
speculative re-execution of p95 stragglers, preemption-aware drain
migration); this package supplies the plan, the injector, the shared
straggler detector, and the conservation `InvariantChecker` that any
traced run must satisfy (gated by `benchmarks/chaos.py`).
"""
from repro.chaos.inject import ChaosInjector, attach_chaos
from repro.chaos.invariants import InvariantChecker, InvariantReport
from repro.chaos.plan import FAULT_KINDS, FaultEvent, FaultPlan
from repro.chaos.speculate import find_stragglers, straggler_cutoff

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultPlan",
    "ChaosInjector",
    "attach_chaos",
    "InvariantChecker",
    "InvariantReport",
    "find_stragglers",
    "straggler_cutoff",
]
