"""`ChaosInjector`: fires a `FaultPlan` at the stepper choke point.

The injector owns no cluster state.  Drivers register per-kind handlers
(`on("worker_crash", fn)`); `LifecycleStepper.step` calls `fire(now)` at
the top of every step, which dispatches every event with ``t <= now`` to
its handler in plan order and emits one ``chaos.fire`` instant per event
— identical in sim and live because both drivers step the same stepper
at the same virtual times (fault fire times are event-time candidates in
both loops, so ``now`` lands exactly on each ``t``).

Two kinds are stateful rather than handled:

* ``corrupt_result`` increments a pending counter; the driver consumes
  it with `take_corruption()` at its next real (non-surrogate)
  completion, turning that completion into a fatal failed attempt.
* ``slow_node`` records a per-worker ``(factor, until)`` entry; drivers
  multiply compute by `slow_factor(wid, now)` at dispatch.  The victim
  worker id is resolved by the driver's handler (sorted running real
  workers) and registered via `set_slow`.

`attach_chaos` is the *best-effort* adapter for a threaded live
`Executor` (wall clock, non-deterministic interleaving): crashes set
`Worker.crashed`, preemptions clip-and-drain the victim allocation,
corruption consumes the same counter inside `_complete`.  Exactness is
the replay harness's contract, not the threaded one's.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.chaos.plan import FaultEvent, FaultPlan


class ChaosInjector:
    """Deterministic fault pump over one `FaultPlan`."""

    def __init__(self, plan: FaultPlan, *, tracer: Any = None):
        self.plan = plan
        self.tracer = tracer
        self._i = 0
        self._corrupt_pending = 0
        self._slow: Dict[int, Tuple[float, float]] = {}   # wid -> (f, until)
        self._handlers: Dict[str, Callable[[FaultEvent, float], None]] = {}
        self.fired: List[FaultEvent] = []

    def on(self, kind: str,
           fn: Callable[[FaultEvent, float], None]) -> "ChaosInjector":
        self._handlers[kind] = fn
        return self

    # -- event-time plumbing ---------------------------------------------
    def next_time(self) -> Optional[float]:
        """Fire time of the next unfired event (an event-loop candidate:
        drivers must not step past it)."""
        if self._i < len(self.plan.events):
            return self.plan.events[self._i].t
        return None

    def pending_times(self) -> List[float]:
        return [e.t for e in self.plan.events[self._i:]]

    def fire(self, now: float) -> int:
        """Dispatch every due event; returns how many fired."""
        n = 0
        events = self.plan.events
        while self._i < len(events) and events[self._i].t <= now:
            ev = events[self._i]
            self._i += 1
            n += 1
            self.fired.append(ev)
            if self.tracer is not None:
                self.tracer.instant(
                    "chaos.fire", ts=now,
                    args={"kind": ev.kind, "target": ev.target})
            if ev.kind == "corrupt_result":
                self._corrupt_pending += 1
                continue
            fn = self._handlers.get(ev.kind)
            if fn is not None:
                fn(ev, now)
        return n

    # -- stateful kinds ---------------------------------------------------
    def take_corruption(self) -> bool:
        """Consume one pending result corruption (driver calls this at
        each real completion, in deterministic completion order)."""
        if self._corrupt_pending > 0:
            self._corrupt_pending -= 1
            return True
        return False

    def set_slow(self, wid: int, factor: float, until: float) -> None:
        self._slow[wid] = (float(factor), float(until))

    def slow_factor(self, wid: int, now: float) -> float:
        """Compute multiplier for worker ``wid`` at ``now`` (1.0 when
        healthy); expired slowdowns are dropped in passing."""
        entry = self._slow.get(wid)
        if entry is None:
            return 1.0
        factor, until = entry
        if now >= until:
            del self._slow[wid]
            return 1.0
        return factor


def attach_chaos(executor: Any, plan: FaultPlan, *,
                 journal: Any = None) -> ChaosInjector:
    """Wire a `FaultPlan` into a *threaded* live `Executor` (the
    `ServiceBroker` path).  Crashes flip `Worker.crashed` (the worker
    dies at its next dispatch), preemptions clip the victim allocation's
    walltime to the grace window and drain it, `journal_torn` arms the
    journal's torn-write flag; `slow_node` is a no-op on real hardware.
    Corruption is consumed by `Executor._complete` via the injector the
    executor now carries as ``_chaos``."""
    inj = ChaosInjector(plan, tracer=getattr(executor, "tracer", None))

    def _crash(ev: FaultEvent, now: float) -> None:
        workers = [w for w in getattr(executor, "workers", ())
                   if w.is_alive() and not w.crashed]
        if workers:
            workers[ev.target % len(workers)].crashed = True

    def _preempt(ev: FaultEvent, now: float) -> None:
        broker = getattr(executor, "_broker", None)
        if broker is None:
            return
        allocs = sorted((a for a in broker.allocations()
                         if not a.virtual and a.state == "running"),
                        key=lambda a: a.alloc_id)
        if not allocs:
            return
        victim = allocs[ev.target % len(allocs)]
        deadline = now + ev.duration_s
        if deadline < victim.expiry_t:
            victim.walltime_s = deadline - victim.grant_t
        broker.drain_allocation(victim.alloc_id, now)

    def _torn(ev: FaultEvent, now: float) -> None:
        if journal is not None:
            journal.torn_next = True

    def _outage(ev: FaultEvent, now: float) -> None:
        sur = getattr(getattr(executor, "_broker", None), "surrogate", None)
        if sur is not None and hasattr(sur, "set_degraded"):
            sur.set_degraded(now, now + ev.duration_s, "outage")

    inj.on("worker_crash", _crash)
    inj.on("preempt", _preempt)
    inj.on("journal_torn", _torn)
    inj.on("surrogate_outage", _outage)
    executor._chaos = inj
    stepper = getattr(executor, "_stepper", None)
    if stepper is not None:
        stepper.chaos = inj
    return inj
