"""Conservation invariants any traced run must satisfy (`repro.chaos`).

The checker consumes exactly what a run already produces — terminal
`TaskRecord`s, `AllocationRecord`s, and the tracer's event stream — and
asserts that faults *moved* work around without creating, destroying, or
double-counting it:

1.  **Terminal uniqueness** — every task reaches exactly one terminal
    state (one record, one terminal trace instant), and that state is in
    the closed set {ok, failed, timeout, quarantined}; zero tasks lost.
2.  **Billing conservation** — node-seconds billed as busy across real
    allocations equal the work accounted to attempts: completed-attempt
    init+compute (trace `task.init`/`task.run` spans on non-virtual
    tracks) plus the burned partial work of every killed / requeued /
    quarantined / hedge-cancelled attempt (`ts - since` on the
    corresponding instants).  Crashes, preemptions, corruption, and
    speculation all bill through these two channels and nowhere else.
3.  **No orphaned workers** — every execution span lies inside its
    allocation's [running, expired] window: no work on nodes that were
    never granted or already released.
4.  **Allocation closure** — every allocation record ends expired
    (nothing still held after the run).
5.  **Attempt sanity** — every terminal record claims >= 1 attempt.

`benchmarks/chaos.py` gates CI on zero violations across a whole
fault-intensity sweep; the journal-recovery invariant (zero lost tasks
across kill/recover cycles) lives with the service tests, which own a
journal directory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set

TERMINAL_STATUSES = ("ok", "failed", "timeout", "quarantined")
_BURN_INSTANTS = ("task.requeue", "task.killed", "task.quarantined",
                  "task.hedge_cancel")
_TERMINAL_INSTANTS = tuple(f"task.{s}" for s in TERMINAL_STATUSES) + \
    ("task.lost",)


@dataclasses.dataclass
class InvariantReport:
    violations: List[str]
    measures: Dict[str, float]

    @property
    def ok(self) -> bool:
        return not self.violations

    def assert_ok(self) -> None:
        if self.violations:
            raise AssertionError(
                "invariant violations:\n  " + "\n  ".join(self.violations))


class InvariantChecker:
    """Run the conservation checks over one traced run."""

    def __init__(self, tol: float = 1e-6):
        self.tol = float(tol)

    def check(self, *, records: Sequence[Any],
              allocations: Sequence[Any] = (),
              events: Iterable[Any] = (),
              expected_tasks: Optional[Iterable[str]] = None
              ) -> InvariantReport:
        v: List[str] = []
        events = list(events)

        # 1. terminal uniqueness over records
        seen: Set[str] = set()
        n_lost = 0
        by_status: Dict[str, int] = {}
        for r in records:
            if r.task_id in seen:
                v.append(f"task {r.task_id}: more than one terminal record")
            seen.add(r.task_id)
            by_status[r.status] = by_status.get(r.status, 0) + 1
            if r.status == "lost":
                n_lost += 1
            elif r.status not in TERMINAL_STATUSES:
                v.append(f"task {r.task_id}: unknown terminal status "
                         f"{r.status!r}")
            if r.status != "lost" and r.attempts < 1:
                v.append(f"task {r.task_id}: terminal with attempts="
                         f"{r.attempts}")
        if n_lost:
            v.append(f"{n_lost} task(s) lost (never served)")
        if expected_tasks is not None:
            expected = set(expected_tasks)
            if expected != seen:
                missing = sorted(expected - seen)[:5]
                extra = sorted(seen - expected)[:5]
                v.append(f"terminal set mismatch: missing {missing}, "
                         f"unexpected {extra}")

        # terminal uniqueness over the trace
        term_count: Dict[str, int] = {}
        for ts, ph, name, pid, tid, dur, args in events:
            if ph == "i" and name in _TERMINAL_INSTANTS and args:
                t = args.get("task")
                if t is not None:
                    term_count[t] = term_count.get(t, 0) + 1
        for t, n in term_count.items():
            if n != 1:
                v.append(f"task {t}: {n} terminal trace instants")

        # virtual (zero-billed) tracks, alloc lifecycle windows
        virtual_pids: Set[int] = set()
        running_t: Dict[int, float] = {}
        expired_t: Dict[int, float] = {}
        for ts, ph, name, pid, tid, dur, args in events:
            if ph == "B" and name in ("alloc.queued", "alloc.running") \
                    and args and args.get("virtual"):
                virtual_pids.add(pid)
            if ph == "B" and name == "alloc.running":
                running_t.setdefault(pid, ts)
            elif ph == "i" and name == "alloc.expired":
                expired_t[pid] = ts

        # 2. billing conservation + 3. orphaned workers
        accounted = 0.0
        for ts, ph, name, pid, tid, dur, args in events:
            if ph == "X" and name in ("task.init", "task.run") \
                    and pid not in virtual_pids and pid > 0:
                a = args or {}
                accounted += float(a.get("init", a.get("compute", dur)))
                start = running_t.get(pid)
                if start is None:
                    v.append(f"{name} span for {a.get('task')} on alloc "
                             f"{pid - 1} that never ran")
                elif ts < start - self.tol:
                    v.append(f"{name} span for {a.get('task')} starts "
                             f"{start - ts:.3f}s before alloc {pid - 1} "
                             f"was granted")
                end = expired_t.get(pid)
                if end is not None and ts + dur > end + self.tol:
                    v.append(f"{name} span for {a.get('task')} outlives "
                             f"alloc {pid - 1} by {ts + dur - end:.3f}s")
            elif ph == "i" and name in _BURN_INSTANTS and args:
                accounted += max(ts - float(args.get("since", ts)), 0.0)
        billed = sum(a.busy_t for a in allocations)
        if abs(billed - accounted) > max(self.tol,
                                         self.tol * max(billed, 1.0)):
            v.append(f"billing not conserved: allocations billed "
                     f"{billed:.6f} busy-seconds, attempts account for "
                     f"{accounted:.6f}")

        # 4. allocation closure
        for a in allocations:
            if a.state != "expired":
                v.append(f"alloc {a.alloc_id}: final state {a.state!r} "
                         f"(still held after the run)")

        measures = {
            "n_records": float(len(records)),
            "n_lost": float(n_lost),
            "n_quarantined": float(by_status.get("quarantined", 0)),
            "billed_busy_s": billed,
            "accounted_busy_s": accounted,
        }
        return InvariantReport(violations=v, measures=measures)
