"""Shared straggler detection (`repro.chaos.speculate`).

One p95 ladder for both drivers: `Executor._straggler_check` and
`simulate_cluster` call `find_stragglers` with the same candidate and
completion views, so a parity replay flags (and hedges) exactly the same
tasks at the same virtual times.

The ladder, per model (a pooled p95 misfires on heterogeneous models —
the fast model's p95 would re-issue every healthy task of a slow one):
predictor quantile when the predictor has seen enough of THIS model,
else a scan of this model's completions, else the pooled estimate, so a
model with too few completions of its own still gets straggler
protection.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple


def _scan_p95(xs: List[float]) -> float:
    xs = sorted(xs)
    return xs[int(0.95 * (len(xs) - 1))]


def straggler_cutoff(model: str, *, factor: float,
                     done_by_model: Dict[str, List[float]],
                     pooled: float, predictor: Any = None,
                     min_n: int = 5) -> float:
    """Re-issue cutoff (seconds in flight) for one model."""
    p95: Optional[float] = None
    n_obs = getattr(predictor, "n_observed", None)
    if predictor is not None and callable(n_obs) and n_obs(model) >= min_n:
        p95 = predictor.quantile(0.95, model)
    if p95 is None:
        ts = done_by_model.get(model)
        if ts is not None and len(ts) >= min_n:
            p95 = _scan_p95(ts)
    if p95 is None:
        p95 = pooled
    return factor * max(p95, 1e-3)


def find_stragglers(now: float,
                    candidates: Iterable[Tuple[str, str, float]],
                    completions: Iterable[Tuple[str, float]], *,
                    predictor: Any = None, factor: float,
                    min_n: int = 5) -> List[str]:
    """Task ids (in candidate order) running past their model's cutoff.

    ``candidates`` are ``(task_id, model, mark_t)`` for in-flight real
    attempts not yet hedged; ``completions`` are ``(model, compute_t)``
    for real (non-surrogate) successful attempts — the driver filters
    both, the ladder is shared."""
    if factor <= 0.0:
        return []
    done_by_model: Dict[str, List[float]] = {}
    for model, compute_t in completions:
        done_by_model.setdefault(model, []).append(compute_t)
    done = [t for ts in done_by_model.values() for t in ts]
    if len(done) < min_n:
        return []
    pooled = predictor.quantile(0.95) if predictor is not None else None
    if pooled is None:
        pooled = _scan_p95(done)
    out: List[str] = []
    cutoffs: Dict[str, float] = {}
    for task_id, model, mark_t in candidates:
        cutoff = cutoffs.get(model)
        if cutoff is None:
            cutoff = cutoffs[model] = straggler_cutoff(
                model, factor=factor, done_by_model=done_by_model,
                pooled=pooled, predictor=predictor, min_n=min_n)
        if now - mark_t > cutoff:
            out.append(task_id)
    return out
