"""Declarative, seeded fault schedules (`FaultPlan`).

A plan is data, not behaviour: a sorted tuple of `FaultEvent`s with
virtual-clock fire times.  The same plan object is handed to the sim and
to the live replay, and because every event names its victim by a
deterministic index (resolved against sorted driver state at fire time,
never by RNG at fire time), both drivers observe the same faults at the
same virtual instants — the property the faulted parity suite pins.

`FaultPlan.generate` draws a plan from per-kind Poisson rates with
`numpy.random.default_rng(seed)`, so fault *schedules* are reproducible
across hosts; everything downstream of the plan is RNG-free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

FAULT_KINDS = (
    "worker_crash",       # kill one busy worker's process (in-flight dies)
    "preempt",            # SLURM-style preemption: grace-period drain
    "slow_node",          # degrade one node by `factor` for `duration_s`
    "corrupt_result",     # next real completion returns garbage (fatal)
    "surrogate_outage",   # surrogate backend down for `duration_s`
    "journal_torn",       # next journal publish is torn mid-write
)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``target`` is a deterministic victim *index*, resolved at fire time
    against the driver's sorted candidate list (busy workers for
    crashes, open real allocations for preemptions, running nodes for
    slowdowns) via ``target % len(candidates)`` — index resolution, not
    RNG, so sim and live pick the same victim.  ``duration_s`` is the
    preemption grace window, outage length, or slowdown length;
    ``factor`` is the slow-node compute multiplier.
    """
    t: float
    kind: str
    target: int = 0
    duration_s: float = 0.0
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(choose from {FAULT_KINDS})")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A sorted, immutable schedule of `FaultEvent`s."""
    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        ordered = tuple(sorted(self.events,
                               key=lambda e: (e.t, FAULT_KINDS.index(e.kind),
                                              e.target)))
        object.__setattr__(self, "events", ordered)

    def __len__(self) -> int:
        return len(self.events)

    def kinds(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def to_dicts(self) -> List[dict]:
        return [dataclasses.asdict(e) for e in self.events]

    @staticmethod
    def from_dicts(rows: Sequence[dict]) -> "FaultPlan":
        return FaultPlan(tuple(FaultEvent(**row) for row in rows))

    @staticmethod
    def generate(seed: int = 0, horizon_s: float = 600.0,
                 rates: Optional[Dict[str, float]] = None, *,
                 grace_s: float = 60.0, slow_factor: float = 3.0,
                 slow_duration_s: float = 120.0,
                 outage_s: float = 120.0) -> "FaultPlan":
        """Draw a seeded plan: per-kind Poisson counts over the horizon,
        uniform fire times, uniform victim indices.  ``rates`` maps
        fault kind -> expected events per second (missing kinds fire
        zero events)."""
        import numpy as np
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for kind in FAULT_KINDS:                   # fixed draw order
            rate = float((rates or {}).get(kind, 0.0))
            if rate <= 0.0:
                continue
            n = int(rng.poisson(rate * horizon_s))
            for _ in range(n):
                t = float(rng.uniform(0.0, horizon_s))
                target = int(rng.integers(0, 1 << 16))
                if kind == "preempt":
                    events.append(FaultEvent(t, kind, target,
                                             duration_s=grace_s))
                elif kind == "slow_node":
                    events.append(FaultEvent(t, kind, target,
                                             duration_s=slow_duration_s,
                                             factor=slow_factor))
                elif kind == "surrogate_outage":
                    events.append(FaultEvent(t, kind, target,
                                             duration_s=outage_s))
                else:
                    events.append(FaultEvent(t, kind, target))
        return FaultPlan(tuple(events))
