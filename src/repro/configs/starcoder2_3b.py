"""starcoder2-3b [dense] — GQA, RoPE.

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152 [arXiv:2402.19173; hf].
gelu two-matrix MLP per the released model.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    mlp_kind="gelu",
    rope_theta=100_000.0,
    accum_steps=1,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=128, dtype="float32", remat=False,
)
