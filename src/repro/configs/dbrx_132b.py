"""dbrx-132b [moe] — 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752(per expert) vocab=100352
MoE 16e top-4 [hf:databricks/dbrx-base; unverified].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    moe_top_k=4,
    moe_d_ff=10752,
    rope_theta=500_000.0,
    fsdp_pod=True,
    accum_steps=4,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=128, n_experts=4, moe_top_k=2, moe_d_ff=128, fsdp_pod=False,
    dtype="float32", remat=False, accum_steps=1,
)
