"""Architecture registry: the 10 assigned configs + reduced smoke variants.

`get(name)` / `get_reduced(name)` accept the public dashed ids
(e.g. "deepseek-v3-671b").  `cells()` enumerates the 40 assigned
(arch x shape) dry-run cells, flagging the long_500k skips for pure
full-attention architectures per the brief.
"""
from __future__ import annotations

import importlib
from typing import Dict, Iterator, List, Tuple

from repro.models.config import LM_SHAPES, ModelConfig, ShapeConfig

_MODULES: Dict[str, str] = {
    "musicgen-large": "repro.configs.musicgen_large",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "yi-34b": "repro.configs.yi_34b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_4b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "zamba2-2.7b": "repro.configs.zamba2_2b",
}

ARCH_NAMES: Tuple[str, ...] = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name])


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _module(name).REDUCED


def shapes() -> Tuple[ShapeConfig, ...]:
    return LM_SHAPES


def cells() -> List[Tuple[str, ShapeConfig, bool]]:
    """All 40 assigned (arch, shape, runnable) cells."""
    out = []
    for arch in ARCH_NAMES:
        cfg = get(arch)
        for shp in LM_SHAPES:
            out.append((arch, shp, cfg.runnable(shp)))
    return out
