"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend.

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct; hf].  The CLIP vision tower is a
STUB per the brief: `input_specs()` provides precomputed patch+text
embeddings [B,S,D]; this config is the transformer backbone.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    input_mode="embeddings",
    accum_steps=1,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
    dtype="float32", remat=False,
)
