"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168 128H d_ff=2048(per routed expert) vocab=129280
MoE 256e top-8 [arXiv:2412.19437; hf].  MLA dims per the paper: q_lora=1536,
kv_lora=512, qk_nope=128, qk_rope=64, v_head=128.  First 3 layers dense with
d_ff=18432.  MTP depth 1.  bf16 optimizer moments + ZeRO over the pod axis so
the 671B state fits 16 GB/chip (recorded in EXPERIMENTS.md §Dry-run).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    attn_kind="mla",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    first_k_dense=3,
    dense_d_ff=18432,
    router_kind="sigmoid",
    mtp_depth=1,
    fsdp_pod=True,
    moments_dtype="bfloat16",
    accum_steps=8,
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
    q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
    v_head_dim=16, n_experts=4, moe_top_k=2, moe_d_ff=64, first_k_dense=1,
    dense_d_ff=128, fsdp_pod=False, moments_dtype="float32",
    dtype="float32", remat=False, accum_steps=1,
)
