"""rwkv6-3b [ssm] — Finch, attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536 [arXiv:2404.05892; hf].
Sub-quadratic: the long_500k cell RUNS for this arch (O(1) recurrent state).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    block_kind="rwkv6",
    attn_kind="none",
    n_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_dim=64,          # 40 heads
    rwkv_decay_lora=64,
    rwkv_mix_lora=32,
    subquadratic=True,
    accum_steps=1,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=128, d_ff=256, vocab_size=128, rwkv_head_dim=32,
    rwkv_decay_lora=16, rwkv_mix_lora=8, dtype="float32", remat=False,
)
