"""zamba2-2.7b [hybrid] — Mamba2 backbone + weight-shared attention blocks.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf].  One shared transformer block (attn + MLP, single
weight copy) applied after every 6 Mamba2 layers.  Sub-quadratic: long_500k
RUNS (SSM state is O(1); the shared-attn KV caches at 524288 x batch 1 are
sequence-sharded over the model axis).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    block_kind="mamba2",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,           # d_inner=5120 -> 80 ssd heads
    ssm_expand=2,
    shared_attn_every=6,
    subquadratic=True,
    accum_steps=1,
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=32, shared_attn_every=2,
    dtype="float32", remat=False,
)
