"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf].  The EnCodec/text frontend is a STUB per the brief:
`input_specs()` provides precomputed frame embeddings [B,S,D]; the backbone
(this config) is the deliverable.  Hardware adaptation: sinusoidal positions
replaced by RoPE (framework standard), gelu MLP kept.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp_kind="gelu",
    input_mode="embeddings",
    accum_steps=2,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
    dtype="float32", remat=False, accum_steps=1,
)
