"""Paper Table III: per-benchmark resource requests + seeded runtimes.

                     eigen-100  eigen-5000   gs2      GP
SLURM alloc (min)        1          5        240       1
HQ alloc (min)          10         60      36000      10
HQ time request (min)    1          5         15       1
HQ time limit (min)      5         10        240       5
CPUs                     1          1          8       1
RAM (GB)                 4          4         32       4
Expected tts (min)     0.01         2     [1,180]    0.1

Runtime tables are seeded: eigen/GP runtimes are near-constant with
hardware jitter (same matrix / same GP every evaluation); GS2 runtimes
come from the GS2-proxy runtime model over the seeded Latin-hypercube
inputs (minutes -> hours, long tail).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import numpy as np

from repro.core.simulator import Workload

N_EVALS = 100                       # paper: 100 evaluations per benchmark
HW_JITTER_SIGMA = 0.05              # hardware/cluster-load noise (lognormal)


def _jittered(base: float, n: int, seed: int) -> Tuple[float, ...]:
    rng = np.random.default_rng(seed)
    return tuple(float(base * np.exp(HW_JITTER_SIGMA * z))
                 for z in rng.standard_normal(n))


@functools.lru_cache(maxsize=None)
def _gs2_runtimes(n: int, seed: int) -> Tuple[float, ...]:
    from repro.uq import gs2_proxy, sampling
    thetas = sampling.latin_hypercube(n, seed=seed)
    return tuple(gs2_proxy.runtime_table(thetas).tolist())


@functools.lru_cache(maxsize=None)
def make_workload(name: str, n_evals: int = N_EVALS, seed: int = 0) -> Workload:
    if name == "eigen-100":
        return Workload(name=name, runtimes=_jittered(0.6, n_evals, seed),
                        n_cpus=1, slurm_alloc=60.0, hq_alloc=600.0,
                        time_request=60.0, time_limit=300.0)
    if name == "eigen-5000":
        return Workload(name=name, runtimes=_jittered(120.0, n_evals, seed),
                        n_cpus=1, slurm_alloc=300.0, hq_alloc=3600.0,
                        time_request=300.0, time_limit=600.0)
    if name == "gs2":
        return Workload(name=name, runtimes=_gs2_runtimes(n_evals, seed + 42),
                        n_cpus=8, slurm_alloc=14400.0, hq_alloc=2_160_000.0,
                        time_request=900.0, time_limit=14400.0)
    if name == "gp":
        return Workload(name=name, runtimes=_jittered(6.0, n_evals, seed),
                        n_cpus=1, slurm_alloc=60.0, hq_alloc=600.0,
                        time_request=60.0, time_limit=300.0)
    raise KeyError(name)


BENCHMARKS: Tuple[str, ...] = ("eigen-100", "eigen-5000", "gs2", "gp")
QUEUE_DEPTHS: Tuple[int, ...] = (2, 10)


def resource_table() -> Dict[str, Dict[str, float]]:
    """Table III as data (for the benchmark harness / README)."""
    out = {}
    for name in BENCHMARKS:
        w = make_workload(name)
        out[name] = {
            "slurm_alloc_min": w.slurm_alloc / 60,
            "hq_alloc_min": w.hq_alloc / 60,
            "hq_time_request_min": w.time_request / 60,
            "hq_time_limit_min": w.time_limit / 60,
            "cpus": w.n_cpus,
            "expected_tts_min": (float(np.mean(w.runtimes)) / 60),
        }
    return out
