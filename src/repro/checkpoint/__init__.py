from repro.checkpoint.checkpoint import (CheckpointManager, load_pytree,
                                         save_pytree, latest_step)
from repro.checkpoint.journal import Journal
