from repro.checkpoint.checkpoint import (CheckpointManager, load_pytree,
                                         save_pytree, latest_step)
