"""Crash-safe JSON journal: atomic-publish snapshots for the broker
service.

The same machinery `save_pytree` uses for model checkpoints — write to a
tmpfile in the destination directory, fsync, `os.replace` — applied to
small JSON state snapshots (queue contents, predictor state, billing).
The invariant the SIGKILL test pins: a crash at ANY instant leaves the
directory holding either the previous journal set intact or the new
file complete; a torn write is impossible to observe through `latest()`
because the tmpfile never matches the journal name pattern and the
rename is atomic on POSIX.

Unlike `repro.checkpoint.checkpoint` this module is numpy/jax-free:
journal state is plain JSON, and the broker service must be importable
on a login node that has no accelerator stack.

Recovery contract (`latest()`): newest LOADABLE journal wins.  Files
that fail to parse — e.g. hand-truncated by an operator, or written by
a pre-crash process on a filesystem without rename atomicity — are
skipped, not fatal: the service falls back to the previous snapshot
rather than refusing to start.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

_JOURNAL_RE = re.compile(r"journal_(\d+)\.json$")


class Journal:
    """Keep-N sequence of atomically-published JSON snapshots."""

    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.keep = int(keep)
        self.dir.mkdir(parents=True, exist_ok=True)
        latest = self.latest_seq()
        self._seq = latest if latest is not None else 0
        # fault injection (repro.chaos `journal_torn`): the NEXT write
        # publishes a half-written payload directly under the journal
        # name — simulating a pre-rename-era torn write / non-atomic
        # filesystem — which `latest()` must skip on recovery
        self.torn_next = False

    def _path(self, seq: int) -> Path:
        return self.dir / f"journal_{seq:08d}.json"

    def seqs(self) -> List[int]:
        """Published sequence numbers, ascending."""
        out = []
        for p in self.dir.iterdir():
            m = _JOURNAL_RE.fullmatch(p.name)
            if m is not None:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_seq(self) -> Optional[int]:
        seqs = self.seqs()
        return seqs[-1] if seqs else None

    # -- writes ----------------------------------------------------------
    def write(self, state: Dict[str, Any]) -> Path:
        """Atomically publish one snapshot as the next sequence number.

        The payload is serialised BEFORE the tmpfile opens (a state dict
        that isn't JSON-able must fail loudly, not leave debris), fsynced
        before the rename (the rename must never become durable ahead of
        the data it points at), and garbage collection of old sequences
        runs only after the publish."""
        payload = json.dumps({"seq": self._seq + 1, "state": state})
        self._seq += 1
        path = self._path(self._seq)
        if self.torn_next:
            self.torn_next = False
            with open(path, "w") as f:
                f.write(payload[:max(len(payload) // 2, 1)])
            return path
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        # the rename is durable only once the DIRECTORY entry is synced:
        # without this, a power cut after os.replace can resurface the
        # old name (or neither), and recovery silently loses the newest
        # published snapshot
        self._fsync_dir()
        self._gc()
        return path

    def _fsync_dir(self) -> None:
        try:
            dfd = os.open(self.dir, os.O_RDONLY)
        except OSError:
            return                             # platform without dir-open
        try:
            os.fsync(dfd)
        except OSError:
            pass                               # fs without dir fsync
        finally:
            os.close(dfd)

    def _gc(self) -> None:
        if self.keep <= 0:
            return
        for seq in self.seqs()[:-self.keep]:
            try:
                self._path(seq).unlink()
            except OSError:
                pass                           # a racing gc got it first

    # -- reads -----------------------------------------------------------
    def load(self, seq: int) -> Dict[str, Any]:
        with open(self._path(seq)) as f:
            doc = json.load(f)
        return doc["state"]

    def latest(self) -> Optional[Tuple[int, Dict[str, Any]]]:
        """(seq, state) of the newest loadable journal; None when the
        directory holds nothing recoverable."""
        for seq in reversed(self.seqs()):
            try:
                return seq, self.load(seq)
            except (OSError, ValueError, KeyError):
                continue                       # torn/corrupt: fall back
        return None
