"""Sharded checkpoint save/restore with mesh-shape-agnostic resharding.

Design for 1000+-node fault tolerance:
  * every leaf is stored under its flattened key path in one .npz per
    step (on a real pod: one shard file per host, same layout);
  * restore is *resharding*: arrays are device_put against whatever mesh
    the restoring job runs — a job restarted on 2 pods can restore a
    1-pod checkpoint and vice versa (elastic down/up-scaling);
  * `CheckpointManager` writes asynchronously (a background thread
    serialises the host copy while training continues), keeps the last
    `keep` steps, and atomically publishes via tmpfile+rename so a crash
    mid-write never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        # npz has no bf16/fp8 codecs: stage such leaves as f32 on disk;
        # load_pytree casts back to the dtype of the `like` tree.
        if arr.dtype.kind not in "fiub?c":
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_pytree(path: os.PathLike, tree, step: Optional[int] = None,
                extra: Optional[Dict[str, Any]] = None) -> Path:
    """Atomic single-file save (tmpfile + rename)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    meta = {"step": step, "extra": extra or {}}
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_pytree(path: os.PathLike, like, *, shardings=None):
    """Restore into the structure of `like`; reshard onto `shardings`
    (a matching pytree of NamedSharding) when given."""
    with np.load(Path(path), allow_pickle=False) as data:
        flat = {k: data[k] for k in data.files if k != "__meta__"}
        meta = json.loads(str(data["__meta__"]))
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: hasattr(s, "spec"))
        if shardings is not None else [None] * len(paths))
    leaves = []
    for (path_keys, leaf), sh in zip(paths, shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_keys)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        if arr.dtype != want_dtype:
            arr = np.asarray(jnp.asarray(arr).astype(want_dtype))
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def latest_step(ckpt_dir: os.PathLike) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for p in ckpt_dir.iterdir()
             if (m := _STEP_RE.search(p.name))]
    return max(steps) if steps else None


class CheckpointManager:
    """Async, retention-managed checkpointing for the training loop."""

    def __init__(self, ckpt_dir: os.PathLike, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    def _path(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}.npz"

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        # snapshot to host memory synchronously (cheap), write async
        host = _flatten(tree)

        def _write():
            save_pytree(self._path(step), host, step=step, extra=extra)
            self._gc()

        self.wait()
        if self.async_save:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(int(_STEP_RE.search(p.name).group(1))
                       for p in self.dir.iterdir()
                       if _STEP_RE.search(p.name))
        for s in steps[:-self.keep]:
            try:
                self._path(s).unlink()
            except OSError:
                pass

    def restore_latest(self, like, *, shardings=None):
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None, None
        tree, meta = load_pytree(self._path(step), like, shardings=shardings)
        return tree, meta

    def restore(self, step: int, like, *, shardings=None):
        self.wait()
        return load_pytree(self._path(step), like, shardings=shardings)
