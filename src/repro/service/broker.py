"""Multi-tenant broker service: the always-on front-end over the
Executor.

This is the Balsam-shaped layer the ROADMAP calls for: the `Executor`
stays a single-process scheduling engine, and `ServiceBroker` turns it
into a *service* — a task-ingestion API multiple tenants share, with

  * fair-share dispatch: every allocation queue is a `FairSharePolicy`
    (weighted deficit round robin over the registered inner policy), so
    tenants split CPU-seconds by configured weight whenever they
    compete, and nobody starves (`repro.sched.policy.FairSharePolicy`);
  * bounded-queue backpressure per tenant: `submit` blocks (or raises
    `Backpressure`) while a tenant is at its quota of OPEN tasks —
    submitted but not yet terminal — so one tenant's firehose cannot
    grow the broker's memory or queue latency without bound;
  * per-tenant SLO accounting: tenant-labelled counters in the
    `MetricsRegistry` (tasks submitted/done by status, CPU-seconds
    billed, deadline totals and misses) and a `billing()` view;
  * a crash-safe journal (`repro.checkpoint.Journal`): queue contents,
    predictor state (engine backend + conditioning set) and billing are
    snapshotted on the lifecycle-tick cadence via atomic
    tmpfile+fsync+rename publishes.  `ServiceBroker.recover` restarts
    from the newest loadable journal with ZERO lost tasks — pending
    work is resubmitted, completed results are pre-filled, the
    predictor resumes with the same surrogate backend.  Re-running
    tasks that finished after the last snapshot is allowed
    (at-least-once semantics); losing one is not.

Mechanically this is the third adapter around the same
`LifecycleStepper` that drives `simulate_cluster` and the bare cluster
Executor: the service installs the fair-share policy per allocation
through the same `Broker`, hangs its journal cadence on the canonical
stepper tick, and so inherits the parity harness's guarantee that
sim-validated fair-share pop order is exactly what dispatches live.

Locking: the service lock is always LEAF.  `submit` releases it before
entering the executor; executor-held paths (`_on_result`, the stepper
tick) may take it.  The reverse order never occurs, so the service
cannot deadlock against the dispatch lock.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.journal import Journal
from repro.cluster.broker import Broker
from repro.core.executor import Executor
from repro.core.task import DEFAULT_TENANT, EvalRequest, EvalResult
from repro.obs.registry import MetricsRegistry
from repro.sched.policy import FairSharePolicy
from repro.sched.registry import make_predictor


class Backpressure(RuntimeError):
    """A tenant is at its open-task quota and `submit` was non-blocking
    (or timed out)."""

    def __init__(self, tenant: str, open_tasks: int, quota: int):
        super().__init__(
            f"tenant {tenant!r} at quota: {open_tasks}/{quota} tasks open")
        self.tenant = tenant
        self.open_tasks = open_tasks
        self.quota = quota


class ServiceBroker:
    """Crash-safe, fair-share multi-tenant scheduling service.

    Parameters
    ----------
    model_factories: the executor's model registry.
    weights:         per-tenant fair-share weights (unlisted tenants
                     weigh 1.0; weight 4 gets 4x the CPU-second share of
                     weight 1 whenever both are backlogged).
    quotas:          per-tenant cap on OPEN tasks (admission control;
                     unlisted tenants are uncapped).
    inner_policy:    registered policy name each tenant's private queue
                     runs ("fcfs", "sjf", "pack", ...).
    predictor:       runtime-predictor spec shared by all tenants.
    quantum_s:       fair-share quantum (cost-seconds credited per
                     tenant-weight unit per round).
    journal_dir:     enable crash-safe journaling into this directory
                     (None = stateless service).
    journal_every_s: journal cadence on the executor's clock.
    journal_keep:    journals retained (keep-N gc).
    registry:        `MetricsRegistry` for tenant-labelled series (one
                     is created when omitted).
    fault_plan:      optional `repro.chaos.FaultPlan` wired into the
                     live executor via `attach_chaos` (crash drills,
                     torn-journal tests); None = no fault injection.
    executor_kw:     everything else (`n_workers`, `autoalloc`, `clock`,
                     `monitor_interval`, `tracer`, ...) is passed to the
                     `Executor` — a virtual-clock service for tests is
                     just ``clock=..., monitor_interval=None``.
    """

    def __init__(self, model_factories: Dict[str, Callable], *,
                 weights: Optional[Dict[str, float]] = None,
                 quotas: Optional[Dict[str, int]] = None,
                 inner_policy: str = "fcfs",
                 predictor: Any = None,
                 quantum_s: float = 1.0,
                 journal_dir: Optional[str] = None,
                 journal_every_s: float = 5.0,
                 journal_keep: int = 3,
                 registry: Optional[MetricsRegistry] = None,
                 fault_plan: Any = None,
                 **executor_kw):
        self.weights = {str(t): float(w)
                        for t, w in (weights or {}).items()}
        self.quotas = {str(t): int(q) for t, q in (quotas or {}).items()}
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._open: Dict[str, int] = {}        # tenant -> open tasks
        self._tenant_of: Dict[str, str] = {}   # open task id -> tenant
        self._billing: Dict[str, float] = {}   # tenant -> cpu-seconds
        self._journal = Journal(journal_dir, keep=journal_keep) \
            if journal_dir is not None else None
        self.journal_every_s = float(journal_every_s)
        self._last_journal_t: Optional[float] = None
        self._killed = False
        # async journal writer: the stepper tick (under the dispatch
        # lock) only BUILDS the state dict; serialisation + fsync happen
        # on this thread so checkpoint IO never stalls dispatch
        self._wcv = threading.Condition()
        self._wstate: Optional[Dict[str, Any]] = None
        self._writer: Optional[threading.Thread] = None

        w, q, qu, sub = self.weights, self.quotas, quantum_s, inner_policy
        broker = Broker(
            predictor=make_predictor(predictor),
            policy=lambda: FairSharePolicy(policy=sub, weights=w,
                                           quotas=q, quantum_s=qu))
        self.broker = broker
        self._ex = Executor(model_factories, cluster=broker,
                            metrics_registry=self.registry,
                            on_result=self._on_result,
                            on_tick=self._on_tick,
                            **executor_kw)
        if self._journal is not None:
            self._last_journal_t = self._ex._clock()
            self._writer = threading.Thread(target=self._writer_loop,
                                            daemon=True)
            self._writer.start()
        self.chaos = None
        if fault_plan is not None and len(fault_plan):
            from repro.chaos.inject import attach_chaos
            self.chaos = attach_chaos(self._ex, fault_plan,
                                      journal=self._journal)

    # ------------------------------------------------------------------
    # ingestion
    # ------------------------------------------------------------------
    def submit(self, req: EvalRequest, *, block: bool = True,
               timeout: Optional[float] = None) -> str:
        """Admit one request under its tenant's quota.

        At quota, `block=True` waits for a slot (bounded by `timeout`
        wall seconds); `block=False` raises `Backpressure` immediately.
        The admission ledger counts OPEN tasks — submitted and not yet
        terminal — so queue depth AND in-flight work both press back."""
        tenant = getattr(req, "tenant", "") or DEFAULT_TENANT
        quota = self.quotas.get(tenant)
        with self._cv:
            if quota is not None:
                deadline = (time.monotonic() + timeout
                            if timeout is not None else None)
                while self._open.get(tenant, 0) >= quota:
                    if not block:
                        raise Backpressure(tenant,
                                           self._open.get(tenant, 0), quota)
                    left = None if deadline is None \
                        else deadline - time.monotonic()
                    if left is not None and left <= 0:
                        raise Backpressure(tenant,
                                           self._open.get(tenant, 0), quota)
                    self._cv.wait(0.01 if left is None else min(left, 0.01))
            self._open[tenant] = self._open.get(tenant, 0) + 1
            self._tenant_of[req.task_id] = tenant
            self.registry.inc("tasks_submitted",
                              labels={"tenant": tenant})
        # OUTSIDE the service lock: the executor takes its dispatch lock
        # in submit, and executor-held paths call back into this lock —
        # holding both here would be the ABBA deadlock
        return self._ex.submit(req)

    def result(self, task_id: str, timeout: float = 300.0) -> EvalResult:
        return self._ex.result(task_id, timeout)

    def run_all(self, reqs, timeout: float = 600.0) -> List[EvalResult]:
        ids = [self.submit(r) for r in reqs]
        return [self.result(t, timeout) for t in ids]

    # ------------------------------------------------------------------
    # accounting (executor hooks — run under the dispatch lock, O(1))
    # ------------------------------------------------------------------
    def _on_result(self, req: EvalRequest, res: EvalResult) -> None:
        tenant = getattr(req, "tenant", "") or DEFAULT_TENANT
        labels = {"tenant": tenant}
        with self._cv:
            # billed per stored result: actual resource use, attempts
            # and superseded speculative results included
            self._billing[tenant] = self._billing.get(tenant, 0.0) \
                + float(res.cpu_time)
            self.registry.inc("cpu_seconds", v=float(res.cpu_time),
                              labels=labels)
            # admission slot frees on the FIRST terminal result only: a
            # "timeout" may later be superseded by a speculative "ok",
            # and that second store must not double-decrement
            if req.task_id in self._tenant_of:
                del self._tenant_of[req.task_id]
                self._open[tenant] = max(self._open.get(tenant, 0) - 1, 0)
                self.registry.inc(f"tasks_{res.status}", labels=labels)
                if req.deadline is not None:
                    self.registry.inc("deadline_total", labels=labels)
                    if res.end_t > req.deadline:
                        self.registry.inc("deadline_missed", labels=labels)
                self._cv.notify_all()

    def _on_tick(self, now: float) -> None:
        """Journal cadence, hung on the canonical stepper tick."""
        if self._journal is None or self._last_journal_t is None:
            return
        if now - self._last_journal_t < self.journal_every_s:
            return
        self._last_journal_t = now
        state = self._state()                  # dict building only
        with self._wcv:
            self._wstate = state               # newest snapshot wins
            self._wcv.notify()

    def _writer_loop(self) -> None:
        while True:
            with self._wcv:
                while self._wstate is None:
                    if self._killed:
                        return
                    self._wcv.wait(0.05)
                state, self._wstate = self._wstate, None
            try:
                self._journal.write(state)
            except Exception:  # noqa: BLE001 — journaling is best-effort;
                pass           # the next tick retries with fresher state

    # ------------------------------------------------------------------
    # journaling / recovery
    # ------------------------------------------------------------------
    def _state(self) -> Dict[str, Any]:
        snap = self._ex.snapshot()
        with self._cv:
            billing = dict(self._billing)
        return {"t": self._ex._clock(), "snapshot": snap,
                "billing": billing, "weights": dict(self.weights),
                "quotas": dict(self.quotas)}

    def checkpoint(self) -> Optional[str]:
        """Synchronously publish a journal snapshot now (tests, graceful
        shutdown); returns the published path."""
        if self._journal is None:
            return None
        return str(self._journal.write(self._state()))

    @classmethod
    def recover(cls, model_factories: Dict[str, Callable], *,
                journal_dir: str, **kw) -> "ServiceBroker":
        """Restart from the newest loadable journal in `journal_dir`.

        Completed results are pre-filled, the predictor reloads its
        persisted state (same engine backend, same conditioning set),
        billing resumes, and every pending task is resubmitted through
        normal admission — zero lost tasks.  An empty/absent journal
        directory yields a fresh service."""
        probe = Journal(journal_dir, keep=kw.get("journal_keep", 3))
        loaded = probe.latest()
        state = loaded[1] if loaded is not None else None
        if state is not None:
            kw.setdefault("weights", state.get("weights"))
            kw.setdefault("quotas", state.get("quotas"))
        svc = cls(model_factories, journal_dir=journal_dir, **kw)
        if state is None:
            return svc
        snap = state.get("snapshot", {})
        pred_state = snap.get("predictor")
        if pred_state and svc._ex.predictor is not None:
            loader = getattr(svc._ex.predictor, "load_state", None)
            if callable(loader):
                loader(pred_state)
        completed = snap.get("completed", {})
        with svc._ex._lock:
            for tid, r in completed.items():
                svc._ex._results[tid] = EvalResult(
                    task_id=tid, value=r["value"], status=r["status"])
        with svc._cv:
            svc._billing = {t: float(v)
                            for t, v in state.get("billing", {}).items()}
        done = {tid for tid, r in completed.items()
                if r["status"] in ("ok", "failed")}
        for p in snap.get("pending", []):
            if p["task_id"] in done:
                continue                       # finished before the crash
            svc.submit(EvalRequest(**p))
        return svc

    # ------------------------------------------------------------------
    # introspection / lifecycle
    # ------------------------------------------------------------------
    def billing(self) -> Dict[str, float]:
        """CPU-seconds billed per tenant (attempts included)."""
        with self._cv:
            return dict(self._billing)

    def open_tasks(self) -> Dict[str, int]:
        """Open (admitted, not yet terminal) tasks per tenant."""
        with self._cv:
            return {t: n for t, n in self._open.items() if n > 0}

    def records(self):
        return self._ex.records()

    def metrics(self) -> Dict[str, Any]:
        out = self._ex.metrics()
        out["billing"] = self.billing()
        out["open_tasks"] = self.open_tasks()
        out["tenant_backlogs"] = self.broker.tenant_backlogs()
        return out

    def step(self) -> None:
        """Pump one lifecycle tick (virtual-clock drivers)."""
        self._ex.step()

    def kill(self) -> None:
        """Crash simulation: hard-stop workers and the journal writer
        with NO final checkpoint and no allocation wind-down — what a
        SIGKILL leaves behind, minus the process exit.  Recovery must
        work from whatever the journal last published."""
        self._killed = True
        self._ex._stopping = True
        for worker in self._ex.workers:
            worker.alive = False
        with self._wcv:
            self._wcv.notify_all()

    def shutdown(self, *, final_checkpoint: bool = True) -> None:
        if self._journal is not None and not self._killed \
                and final_checkpoint:
            self.checkpoint()
        self._killed = True
        with self._wcv:
            self._wcv.notify_all()
        self._ex.shutdown()
        if self._writer is not None:
            self._writer.join(timeout=1.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
