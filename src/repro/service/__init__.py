"""`repro.service`: the multi-tenant broker service.

`ServiceBroker` wraps the cluster `Executor` in an always-on,
crash-safe, fair-share front-end: per-tenant quotas with bounded-queue
backpressure (`Backpressure`), weighted deficit-round-robin dispatch
(`repro.sched.FairSharePolicy` per allocation), tenant-labelled SLO
accounting, and an atomically-published state journal
(`repro.checkpoint.Journal`) that restarts lose zero tasks from.
"""
from repro.service.broker import Backpressure, ServiceBroker

__all__ = ["Backpressure", "ServiceBroker"]
