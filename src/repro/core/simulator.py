"""Deterministic discrete-event cluster simulator.

Reproduces the paper's scheduler-comparison experiments (Figs. 3, 4, 5, 6)
quantitatively: 100 evaluations per benchmark, a fixed number of jobs
(2 or 10) kept in flight — "mimicking a user submitting jobs one after the
other up to a predefined threshold" — on either a naive-SLURM, UM-Bridge-
SLURM, or HQ backend spec.

Queue waits on the shared Hamilton8 cluster are irreproducible wall-clock
facts; they are modelled as seeded lognormal delays whose medians scale
with the requested allocation time (longer requests queue longer), with
constants calibrated so the paper's headline numbers emerge:
  * >= 3 orders of magnitude lower median per-job scheduling overhead (HQ),
  * ~38 % lower GS2 makespan at queue depths 2 and 10,
  * HQ *loses* CPU time on sub-second tasks (the ~1 s server init),
  * HQ SLR ~ 1, SLURM SLR >> 1 for short tasks,
  * UM-Bridge SLURM backend shows no gain over naive SLURM (Appendix A).

The simulator is seeded end-to-end: same seed -> identical schedules.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.backends import BackendSpec, lognormal as _lognormal
from repro.core.metrics import TaskRecord
from repro.core.task import EvalRequest
from repro.sched import make_policy, make_predictor
from repro.sched.policy import WorkerView


@dataclasses.dataclass(frozen=True)
class Workload:
    """One benchmark column of the paper's Table III (seconds)."""
    name: str
    runtimes: Tuple[float, ...]      # per-task application compute times
    n_cpus: int = 1
    slurm_alloc: float = 60.0        # SLURM per-job time limit
    hq_alloc: float = 600.0          # HQ bulk allocation length
    time_request: float = 60.0       # HQ per-job time request (packing hint)
    time_limit: float = 300.0        # HQ per-job time limit (kill bound)

    @property
    def n_tasks(self) -> int:
        return len(self.runtimes)


PRELIM_COMPUTE = 0.05                # readiness-probe compute seconds


def simulate(spec: BackendSpec, workload: Workload, queue_depth: int,
             seed: int = 0, node_cores: int = 128,
             include_preliminary: bool = True) -> List[TaskRecord]:
    """Run one benchmark (all tasks) under one backend; return records."""
    rng = np.random.default_rng(seed)
    records: List[TaskRecord] = []

    per_job_limit = (workload.time_limit if spec.bulk_allocation
                     else workload.slurm_alloc)
    alloc_request = (workload.hq_alloc if spec.bulk_allocation
                     else workload.slurm_alloc)
    wait_median = spec.queue_wait_median(alloc_request, workload.n_cpus)
    env_median = spec.env_reinit_median(workload.slurm_alloc)

    # ---- bulk allocation (HQ): one queue wait up front -----------------
    if spec.bulk_allocation:
        ready = _lognormal(rng, wait_median, spec.queue_wait_sigma)
    else:
        ready = 0.0

    # in-flight window: list of (start, end) of running jobs
    inflight: List[Tuple[float, float]] = []
    t_user = 0.0                      # next submission opportunity

    def submit_one(idx: str, compute: float, is_prelim: bool) -> TaskRecord:
        nonlocal t_user, inflight
        if len(inflight) >= queue_depth:
            # wait for a slot: the earliest-finishing in-flight job
            t_done = min(end for _, end in inflight)
            inflight = [(s, e) for s, e in inflight if e != t_done] + \
                [(s, e) for s, e in inflight if e == t_done][1:]
            t_user = max(t_user, t_done)
        submit = t_user
        if spec.bulk_allocation:
            # persistent workers: only dispatch latency per task, but the
            # allocation itself must be up before anything runs
            start = max(submit + spec.dispatch_latency, ready)
            env = 0.0
            factor = 1.0
            worker = f"hq-worker-{len(inflight)}"
        else:
            # fresh per-job allocation: queue wait + env re-init +
            # co-residency contention (SLURM packs this user's jobs while
            # queue_depth * n_cpus fits one node)
            wait = _lognormal(rng, wait_median, spec.queue_wait_sigma)
            start = submit + spec.dispatch_latency + wait
            env = _lognormal(rng, env_median, spec.env_reinit_sigma)
            packed = (queue_depth * workload.n_cpus) <= node_cores
            cojobs = sum(1 for s, e in inflight if s <= start < e) if packed \
                else 0
            factor = 1.0 + spec.contention_per_cojob * cojobs
            worker = "node-0" if packed else f"node-{len(inflight)}"
        run = compute * factor
        cpu = env + spec.server_init + run
        status = "preliminary" if is_prelim else "ok"
        if cpu > per_job_limit and not is_prelim:
            cpu = per_job_limit
            status = "timeout"
        end = start + cpu
        inflight.append((start, end))
        rec = TaskRecord(task_id=idx, submit_t=submit, start_t=start,
                         end_t=end, cpu_time=cpu,
                         compute_t=(compute if status != "timeout"
                                    else max(per_job_limit - env
                                             - spec.server_init, 0.0)),
                         worker=worker, status=status)
        records.append(rec)
        return rec

    # ---- preliminary readiness jobs (load-balancer design, §V) ---------
    if include_preliminary and spec.preliminary_jobs:
        for p in range(spec.preliminary_jobs):
            submit_one(f"{workload.name}-prelim-{p}", PRELIM_COMPUTE, True)

    for i, r in enumerate(workload.runtimes):
        submit_one(f"{workload.name}-{i}", float(r), False)

    return records


def simulate_policy(spec: BackendSpec, workload: Workload,
                    n_workers: int = 2, policy: Any = "fcfs",
                    predictor: Any = None, seed: int = 0,
                    hints: Any = "workload",
                    parameters: Optional[Sequence[Sequence[float]]] = None,
                    model_names: Optional[Sequence[str]] = None
                    ) -> List[TaskRecord]:
    """Policy-driven discrete-event run: the SAME `SchedulingPolicy` /
    `RuntimePredictor` objects that drive the live `Executor` schedule a
    seeded virtual worker pool, so predicted-vs-actual schedules are
    comparable deterministically (same seed + same policy -> identical
    records).

    Where `simulate` reproduces the paper's queue-depth submission model
    verbatim, this models THIS repo's executor: all tasks are submitted up
    front, `n_workers` workers pull from the policy, and under a bulk
    allocation servers stay warm per worker (persistent-server semantics),
    with the allocation renewed — new queue wait, cold servers — when it
    runs out.  Per-job backends pay a queue wait + env re-init per task,
    exactly as in `simulate`.

    `hints` controls the HQ-style time-request hint on each request:
    "workload" (the static per-workload request — what the paper's users
    provide), "oracle" (the true runtime — perfect hints), None, or a
    per-task sequence.  `parameters` optionally attaches input-parameter
    vectors so a GP predictor can learn the runtime surface; `model_names`
    optionally labels tasks with distinct model servers (multi-model UQ
    campaigns) so per-model predictors and locality-aware policies have
    something to discriminate on.
    """
    rng = np.random.default_rng(seed)
    pol = make_policy(policy, make_predictor(predictor))

    per_job_limit = (workload.time_limit if spec.bulk_allocation
                     else workload.slurm_alloc)
    alloc_request = (workload.hq_alloc if spec.bulk_allocation
                     else workload.slurm_alloc)
    wait_median = spec.queue_wait_median(alloc_request, workload.n_cpus)
    env_median = spec.env_reinit_median(workload.slurm_alloc)

    runtimes = {}
    for i, r in enumerate(workload.runtimes):
        if hints == "oracle":
            hint: Optional[float] = float(r)
        elif hints == "workload":
            hint = workload.time_request
        elif hints is None:
            hint = None
        else:
            hint = float(hints[i])
        req = EvalRequest(
            model_name=(model_names[i] if model_names is not None
                        else workload.name),
            parameters=([list(map(float, parameters[i]))] if parameters
                        is not None else [[float(i)]]),
            time_request=hint, time_limit=workload.time_limit,
            n_cpus=workload.n_cpus, task_id=f"{workload.name}-{i}")
        runtimes[req.task_id] = float(r)
        pol.push(req, 1)

    ready = (_lognormal(rng, wait_median, spec.queue_wait_sigma)
             if spec.bulk_allocation else 0.0)
    workers = [{"free": ready, "warm": set(),
                "alloc_end": ready + workload.hq_alloc}
               for _ in range(n_workers)]
    # completions not yet visible to the predictor: (end_t, req, compute)
    to_observe: List[Tuple[float, int, EvalRequest, float]] = []
    obs_tick = 0
    records: List[TaskRecord] = []

    while len(pol):
        wid = min(range(n_workers), key=lambda j: workers[j]["free"])
        w = workers[wid]
        if spec.bulk_allocation and w["free"] >= w["alloc_end"]:
            # allocation exhausted: renew (one more queue wait, cold start)
            # and RE-SELECT — another worker may now be free earlier
            w["free"] += _lognormal(rng, wait_median, spec.queue_wait_sigma)
            w["alloc_end"] = w["free"] + workload.hq_alloc
            w["warm"].clear()
            continue
        now = w["free"]
        if pol.predictor is not None:          # completions up to `now`
            while to_observe and to_observe[0][0] <= now:
                _, _, done_req, done_compute = heapq.heappop(to_observe)
                pol.predictor.observe(done_req, done_compute)
        budget = (w["alloc_end"] - now) if spec.bulk_allocation else None
        view = WorkerView(wid=wid, warm_models=frozenset(w["warm"]),
                          budget_left=budget)
        item = pol.pop(view)
        if item is None:
            break
        req, _ = item
        compute = runtimes[req.task_id]
        if spec.bulk_allocation:
            start = now + spec.dispatch_latency
            env = 0.0
            init = (0.0 if req.model_name in w["warm"] else spec.server_init)
            w["warm"].add(req.model_name)
        else:
            start = (now + spec.dispatch_latency
                     + _lognormal(rng, wait_median, spec.queue_wait_sigma))
            env = _lognormal(rng, env_median, spec.env_reinit_sigma)
            init = spec.server_init
        cpu = env + init + compute
        status = "ok"
        if cpu > per_job_limit:
            cpu = per_job_limit
            status = "timeout"
            compute = max(per_job_limit - env - init, 0.0)
        end = start + cpu
        w["free"] = end
        if pol.predictor is not None and status == "ok":
            obs_tick += 1
            heapq.heappush(to_observe, (end, obs_tick, req, compute))
        records.append(TaskRecord(
            task_id=req.task_id, submit_t=0.0, start_t=start, end_t=end,
            cpu_time=cpu, compute_t=compute, worker=f"sim-worker-{wid}",
            status=status))
    return records


def eval_records(records: Sequence[TaskRecord]) -> List[TaskRecord]:
    """Drop the preliminary readiness probes (kept for makespan realism,
    excluded from CPU-time statistics like the paper's 'blend into the
    typical runtime range' remark)."""
    return [r for r in records if r.status != "preliminary"]
