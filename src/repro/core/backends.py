"""Scheduling-backend specifications (SLURM-naive / UM-Bridge-SLURM / HQ).

A `BackendSpec` captures the *mechanism* of each backend as the paper
describes it; the numeric fields are overhead-model parameters calibrated
against the paper's Hamilton8 measurements (queue waits, env re-init,
~1 s model-server init, ms-level HQ dispatch).  The same spec drives both
the discrete-event simulator (quantitative reproduction of Figs 3-6) and
the live JAX executor (which realises the mechanisms — persistent vs
per-task model servers — with real compile/runtimes).

Mechanism summary (paper §II-C):
  * SLURM (naive):  one native allocation *per job*.  Every job pays a
    queue wait, a full environment re-initialisation (inside CPU time),
    and possible node co-residency contention (SLURM packs jobs).
  * UM-Bridge SLURM backend: the load balancer submits one sbatch per
    model server — same per-job costs plus the ~1 s server init; the
    paper's Appendix A shows no gain over naive SLURM.
  * HQ: ONE bulk allocation up front (a single queue wait), persistent
    workers on dedicated nodes, ms-level task dispatch; each task still
    pays the ~1 s model-server init (the paper's reported negative result
    for very short tasks), tasks are packed by *time request* while the
    *time limit* only bounds runaway jobs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

# Schedulers bucket long requests, so queue wait saturates at the
# partition's max walltime (4 h on the testbed's shared queue): a 600 h
# bulk allocation does not wait 150x longer than a 4 h job.
QUEUE_WAIT_SATURATION_S = 14400.0


def lognormal(rng, median: float, sigma: float) -> float:
    """One seeded lognormal draw parameterised by its median (the form
    every overhead model in this repo uses); degenerate cases collapse
    to the median so sigma=0 specs stay exactly deterministic."""
    if median <= 0:
        return 0.0
    if sigma <= 0:
        return median
    return float(median * math.exp(sigma * rng.standard_normal()))


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    # --- allocation structure -----------------------------------------
    bulk_allocation: bool            # one queue wait total vs one per job
    dedicated_nodes: bool            # workers own their nodes (no packing)
    # --- overhead model (seconds; lognormal medians + sigma) -----------
    # median queue wait = floor + coef * alloc^power * cpus^cpu_power:
    # tiny requests backfill in seconds; multi-hour multi-core requests
    # wait tens of minutes on a busy shared cluster.
    queue_wait_coef: float
    queue_wait_power: float
    queue_wait_cpu_power: float
    queue_wait_floor: float          # + constant floor
    queue_wait_sigma: float          # lognormal sigma (spread)
    env_reinit_frac_of_alloc: float  # env re-init median ~ frac * alloc time
    env_reinit_floor: float
    env_reinit_sigma: float
    server_init: float               # UM-Bridge model-server startup per job
    dispatch_latency: float          # per-task dispatch (HQ: milliseconds)
    contention_per_cojob: float      # CPU-time inflation per co-resident job
    # --- policy ---------------------------------------------------------
    uses_time_request: bool = False  # HQ packs by expected runtime
    preliminary_jobs: int = 0        # readiness-check jobs before first eval

    def queue_wait_median(self, alloc_request_s: float,
                          n_cpus: int = 1) -> float:
        """Median queue wait for one allocation request: floor + coef *
        min(walltime, saturation)^power * cpus^cpu_power.  The single
        overhead model shared by `simulate`, `simulate_policy`, and the
        `repro.cluster` allocation lifecycle."""
        return (self.queue_wait_floor
                + self.queue_wait_coef
                * min(alloc_request_s, QUEUE_WAIT_SATURATION_S)
                ** self.queue_wait_power
                * n_cpus ** self.queue_wait_cpu_power)

    def env_reinit_median(self, slurm_alloc_s: float) -> float:
        """Median environment re-initialisation cost for a per-job
        allocation of the given length."""
        return (self.env_reinit_floor
                + self.env_reinit_frac_of_alloc * slurm_alloc_s)

    def draw_queue_wait(self, rng, alloc_request_s: float,
                        n_cpus: int = 1) -> float:
        """One seeded queue-wait sample for an allocation request."""
        return lognormal(rng, self.queue_wait_median(alloc_request_s, n_cpus),
                         self.queue_wait_sigma)

    def describe(self) -> str:
        alloc = "bulk" if self.bulk_allocation else "per-job"
        return (f"{self.name}: {alloc} allocation, "
                f"server_init={self.server_init:.2f}s, "
                f"dispatch={self.dispatch_latency * 1e3:.1f}ms")


def slurm_naive() -> BackendSpec:
    """The predominant GS2-user method: a Python script pseudo-balancing
    batches of individual sbatch submissions."""
    return BackendSpec(
        name="slurm",
        bulk_allocation=False,
        dedicated_nodes=False,
        queue_wait_coef=0.011,
        queue_wait_power=1.2,
        queue_wait_cpu_power=0.4,
        queue_wait_floor=2.0,
        queue_wait_sigma=0.6,
        env_reinit_frac_of_alloc=0.01,
        env_reinit_floor=0.2,
        env_reinit_sigma=0.4,
        server_init=0.0,              # runs the app directly, no UM-Bridge
        dispatch_latency=0.5,         # sbatch submission latency
        contention_per_cojob=0.012,
    )


def umbridge_slurm() -> BackendSpec:
    """UM-Bridge's simpler SLURM backend: per-server sbatch through the
    load balancer.  Same core scheduling mechanism as naive SLURM (the
    paper's Appendix A: no performance gain), plus the server init."""
    base = slurm_naive()
    return dataclasses.replace(
        base, name="umb-slurm", server_init=1.0, dispatch_latency=0.6,
        preliminary_jobs=5)


def hyperqueue() -> BackendSpec:
    """HQ as a plugin meta-scheduler: one bulk allocation, persistent
    workers, millisecond dispatch, time-request-aware packing."""
    return BackendSpec(
        name="hq",
        bulk_allocation=True,
        dedicated_nodes=True,
        queue_wait_coef=0.011,            # the single allocation still queues
        queue_wait_power=1.2,
        queue_wait_cpu_power=0.4,
        queue_wait_floor=2.0,
        queue_wait_sigma=0.6,
        env_reinit_frac_of_alloc=0.0,     # env persists for the allocation
        env_reinit_floor=0.0,
        env_reinit_sigma=0.0,
        server_init=1.0,                  # per-task model-server startup
        dispatch_latency=0.008,           # ms-level HQ dispatch
        contention_per_cojob=0.0,         # dedicated nodes
        uses_time_request=True,
        preliminary_jobs=5,
    )


BACKENDS = {
    "slurm": slurm_naive,
    "umb-slurm": umbridge_slurm,
    "hq": hyperqueue,
}


def get(name: str) -> BackendSpec:
    return BACKENDS[name]()
