"""The paper's primary contribution: dynamic load balancing / task
scheduling for UQ workflows — backend specs, a calibrated discrete-event
cluster simulator (quantitative reproduction of the paper's Figs. 3-6),
and a live persistent-worker executor scheduling real JAX work with fault
tolerance, straggler mitigation and elastic scaling."""
from repro.core import backends, metrics
from repro.core.balancer import LoadBalancer
from repro.core.executor import Executor
from repro.core.metrics import (AllocationRecord, TaskRecord,
                                allocation_utilization, makespan,
                                node_seconds, slr, summarize)
from repro.core.simulator import (Workload, simulate, simulate_policy,
                                  eval_records)
from repro.core.task import EvalRequest, EvalResult, LambdaModel, Model
