"""UM-Bridge load balancer for the live executor.

The paper's C++ load balancer sits between UQ clients and model servers:
it registers servers, runs readiness checks (the 'at least five additional
jobs' of §V that verify input/output dimensions before the first real
evaluation), health-checks them periodically, and routes requests
first-come-first-served, spawning servers on demand through a scheduling
backend (SLURM or HQ).

Here the backend choice maps onto the Executor's two server-lifecycle
modes, and the readiness/health machinery is kept verbatim in spirit:
registration probes really do instantiate a server and compare declared
vs. observed dimensions, and health checks really do round-trip a probe
evaluation through the scheduler.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.executor import Executor
from repro.core.metrics import TaskRecord
from repro.core.task import EvalRequest, EvalResult, Model

READINESS_PROBES = 5                 # paper §V: preliminary verification jobs


@dataclasses.dataclass
class ModelInfo:
    name: str
    input_sizes: List[int]
    output_sizes: List[int]
    registered_t: float
    probes_run: int = 0
    healthy: bool = True
    last_health_t: float = 0.0


class LoadBalancer:
    """Language-agnostic facade: register models, evaluate through the
    scheduler, monitor health."""

    def __init__(self, backend: str = "hq", n_workers: int = 2, *,
                 policy: Any = "fcfs", predictor: Any = None,
                 cluster: Any = None, autoalloc: Any = None,
                 **executor_kw):
        """`policy` / `predictor` select the `repro.sched` scheduling
        policy and online runtime predictor by registered name (or
        instance); `cluster` / `autoalloc` hand over a `repro.cluster`
        `Broker` / `AutoAllocConfig` for allocation-backed elasticity.
        All four pass straight through to the `Executor` — e.g.
        ``LoadBalancer("hq", policy="pack", predictor="gp",
        autoalloc=AutoAllocConfig(walltime_s=600))``."""
        assert backend in ("hq", "slurm"), backend
        self.backend = backend
        self._factories: Dict[str, Callable[[], Model]] = {}
        self._info: Dict[str, ModelInfo] = {}
        self._executor_kw = dict(executor_kw)
        self._executor_kw.setdefault("persistent_servers", backend == "hq")
        # honour an injected clock (virtual-time replays): the balancer's
        # own timestamps (registration, health checks) must come off the
        # same clock as the executor's, or parity traces mix time bases
        self._clock: Callable[[], float] = \
            self._executor_kw.get("clock") or time.monotonic
        self._executor_kw["policy"] = policy
        self._executor_kw["predictor"] = predictor
        if cluster is not None:
            self._executor_kw["cluster"] = cluster
        if autoalloc is not None:
            self._executor_kw["autoalloc"] = autoalloc
        self._n_workers = n_workers
        self.executor: Optional[Executor] = None

    @property
    def policy(self):
        """The live scheduling-policy object (None before start())."""
        return self.executor.policy if self.executor else None

    @property
    def predictor(self):
        """The live runtime predictor (None before start() / if unset)."""
        return self.executor.predictor if self.executor else None

    # ------------------------------------------------------------------
    def register_model(self, name: str, factory: Callable[[], Model],
                       verify: bool = True) -> ModelInfo:
        """Register a model server factory; run the readiness probes the
        paper describes (instantiate, query dims, compare declared)."""
        self._factories[name] = factory
        probe = factory()
        ins = probe.get_input_sizes()
        outs = probe.get_output_sizes()
        info = ModelInfo(name=name, input_sizes=ins, output_sizes=outs,
                         registered_t=self._clock())
        if verify:
            for _ in range(READINESS_PROBES):
                i2 = probe.get_input_sizes()
                o2 = probe.get_output_sizes()
                if i2 != ins or o2 != outs:
                    raise RuntimeError(
                        f"model {name!r} readiness check failed: "
                        f"dims changed {ins}/{outs} -> {i2}/{o2}")
                info.probes_run += 1
        self._info[name] = info
        if self.executor is not None:
            self.executor.model_factories[name] = factory
        return info

    def start(self) -> "LoadBalancer":
        if self.executor is None:
            self.executor = Executor(self._factories, self._n_workers,
                                     name=self.backend, **self._executor_kw)
        return self

    # ------------------------------------------------------------------
    def submit(self, req: EvalRequest) -> str:
        assert self.executor is not None, "call start() first"
        if req.model_name not in self._factories:
            raise KeyError(f"unregistered model {req.model_name!r}")
        return self.executor.submit(req)

    def evaluate(self, model_name: str, parameters, config=None,
                 timeout: float = 300.0):
        self.start()
        return self.executor.evaluate(model_name, parameters, config,
                                      timeout)

    def run_all(self, reqs: Sequence[EvalRequest], timeout: float = 600.0
                ) -> List[EvalResult]:
        self.start()
        return self.executor.run_all(reqs, timeout)

    # ------------------------------------------------------------------
    def health_check(self, model_name: str, probe_parameters,
                     timeout: float = 60.0) -> bool:
        """Round-trip a probe evaluation through the scheduler; mark the
        model unhealthy on failure (the balancer's periodic monitor)."""
        info = self._info[model_name]
        try:
            self.evaluate(model_name, probe_parameters, timeout=timeout)
            info.healthy = True
        except Exception:  # noqa: BLE001
            info.healthy = False
        info.last_health_t = self._clock()
        return info.healthy

    def models(self) -> Dict[str, ModelInfo]:
        return dict(self._info)

    def records(self) -> List[TaskRecord]:
        return self.executor.records() if self.executor else []

    def shutdown(self):
        if self.executor is not None:
            self.executor.shutdown()
            self.executor = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.shutdown()
