"""UM-Bridge model protocol and evaluation-request types.

The paper's abstraction: a model is a map F: R^n -> R^m, served behind a
language-agnostic interface; the UQ client sends evaluation requests
{F(theta_i)} and the load balancer distributes them.  Here the HTTP layer
is replaced by in-process calls (documented assumption change in
DESIGN.md) but the protocol surface is kept: models declare input/output
sizes, are queried for readiness before first use, and may expose a cost
hint (the analogue of HQ's per-job *time request* — a scheduling hint,
distinct from the *time limit* safety bound).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence

_task_counter = itertools.count()

# Tenant every request belongs to unless it says otherwise.  Single-owner
# deployments never see any other value.
DEFAULT_TENANT = "default"


class Model:
    """Base class mirroring umbridge.Model."""

    def __init__(self, name: str):
        self.name = name

    def get_input_sizes(self, config: Optional[Dict] = None) -> List[int]:
        raise NotImplementedError

    def get_output_sizes(self, config: Optional[Dict] = None) -> List[int]:
        raise NotImplementedError

    def __call__(self, parameters: Sequence[Sequence[float]],
                 config: Optional[Dict] = None) -> List[List[float]]:
        raise NotImplementedError

    def supports_evaluate(self) -> bool:
        return True

    # --- scheduling extensions (this paper) ---------------------------
    def cost_hint(self, parameters, config: Optional[Dict] = None
                  ) -> Optional[float]:
        """Expected compute seconds (HQ 'time request' analogue); None if
        unpredictable — the GS2 case the paper is built around."""
        return None

    def warmup(self) -> None:
        """Server initialisation (compile caches etc.).  The ~1 s per-job
        model-server init the paper measures corresponds to this running
        per job on the naive backend vs once per worker on HQ."""


@dataclasses.dataclass
class LambdaModel(Model):
    """Wrap a plain callable as a Model."""

    def __init__(self, name: str, fn: Callable, input_size: int,
                 output_size: int, cost_fn: Optional[Callable] = None,
                 warmup_fn: Optional[Callable] = None):
        super().__init__(name)
        self._fn = fn
        self._in = input_size
        self._out = output_size
        self._cost_fn = cost_fn
        self._warmup_fn = warmup_fn

    def get_input_sizes(self, config=None):
        return [self._in]

    def get_output_sizes(self, config=None):
        return [self._out]

    def __call__(self, parameters, config=None):
        return self._fn(parameters, config)

    def cost_hint(self, parameters, config=None):
        return self._cost_fn(parameters, config) if self._cost_fn else None

    def warmup(self):
        if self._warmup_fn:
            self._warmup_fn()


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Hardened requeue semantics for one request (repro.chaos).

    Requeues after a *fatal* attempt (worker crash, corrupted result) are
    released ``backoff_s`` seconds later instead of immediately, with
    exponential growth per attempt and a deterministic seeded jitter —
    ``backoff_s(task_id, attempt, seed)`` is a pure function, so the sim
    and the live replay compute byte-identical release times (pinned by
    the parity suite).  ``quarantine_after`` caps fatal failures: once a
    task has killed that many workers it is quarantined (terminal
    ``quarantined`` record) instead of crash-looping forever.

    The default-constructed policy (all zeros, no quarantine) is
    semantically identical to ``retry=None`` for timing, so traces stamped
    with it stay comparable to legacy runs.
    """
    base_s: float = 0.0              # first-retry backoff (0 = immediate)
    factor: float = 2.0              # exponential growth per attempt
    max_s: float = 60.0              # backoff ceiling
    jitter: float = 0.0              # +/- fraction of the backoff, seeded
    quarantine_after: Optional[int] = None   # fatal failures before terminal

    def backoff_s(self, task_id: str, attempt: int, seed: int = 0) -> float:
        """Deterministic backoff before re-releasing `attempt`'s requeue.

        The jitter draw hashes (seed, task_id, attempt) — not global RNG
        state — so any driver, in any completion order, on any host,
        computes the same delay."""
        if self.base_s <= 0.0:
            return 0.0
        raw = self.base_s * (self.factor ** max(attempt - 1, 0))
        delay = min(raw, self.max_s)
        if self.jitter > 0.0:
            digest = hashlib.blake2b(
                f"{seed}:{task_id}:{attempt}".encode(),
                digest_size=8).digest()
            u = int.from_bytes(digest, "big") / 2.0 ** 64   # [0, 1)
            delay *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return max(delay, 0.0)


@dataclasses.dataclass
class EvalRequest:
    """One F(theta) evaluation travelling through the load balancer."""
    model_name: str
    parameters: Any
    config: Dict = dataclasses.field(default_factory=dict)
    # HQ-style scheduling fields (seconds):
    time_request: Optional[float] = None     # expected runtime (hint)
    time_limit: Optional[float] = None       # hard kill bound
    n_cpus: int = 1
    task_id: str = ""
    submit_t: float = 0.0
    max_attempts: int = 3
    # dependency edges (MCMC-style chains): ids that must finish first
    depends_on: Sequence[str] = ()
    # absolute completion deadline on the scheduler's clock (drives the
    # "edf" policy; None = no SLO, sorts after every deadlined task)
    deadline: Optional[float] = None
    # owning tenant (multi-tenant broker service); the default tenant
    # keeps every single-owner code path byte-for-byte identical —
    # fair-share scheduling, quotas, and per-tenant SLO accounting only
    # engage when requests carry distinct tenants
    tenant: str = DEFAULT_TENANT
    # hardened requeue semantics (None = legacy immediate requeue); a
    # plain dict (journal round trip) is rehydrated into a RetryPolicy
    retry: Optional[Any] = None

    def __post_init__(self):
        if not self.task_id:
            self.task_id = f"task-{next(_task_counter)}"
        if isinstance(self.retry, dict):
            self.retry = RetryPolicy(**self.retry)
        # submit_t is stamped by whoever owns the clock: `Executor.submit`
        # (its injected clock) or the simulator (trace arrival time).  A
        # wall-clock default here would leak `time.monotonic` into
        # virtual-clock parity replays.


@dataclasses.dataclass
class EvalResult:
    task_id: str
    value: Any = None
    status: str = "ok"            # ok | failed | timeout | quarantined
    error: Optional[str] = None
    worker: str = ""
    attempts: int = 1
    submit_t: float = 0.0
    dispatch_t: float = 0.0
    start_t: float = 0.0
    end_t: float = 0.0
    compute_t: float = 0.0                    # pure application time
    init_t: float = 0.0                       # server-init share

    @property
    def cpu_time(self) -> float:
        return self.init_t + self.compute_t

    @property
    def queue_wait(self) -> float:
        return max(self.start_t - self.submit_t, 0.0)
