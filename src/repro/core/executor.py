"""Live execution engine: persistent-worker task scheduling over real JAX.

This realises the paper's mechanism with *real* costs instead of simulated
ones: a pool of persistent workers (threads; on a TPU pod, one per mesh
slice) pulls evaluation requests from a pluggable `repro.sched` scheduling
policy (FCFS by default; SJF/LPT/cost-aware packing/work stealing by
name), with an optional online runtime predictor learning task costs from
completions.

  * HQ semantics (`persistent_servers=True`): each worker instantiates a
    model server ONCE and reuses it — the jit-compile / warmup cost (the
    real analogue of the paper's ~1 s model-server init + SLURM env
    re-init) is paid once per (worker, model).
  * naive-SLURM semantics (`persistent_servers=False`): every task gets a
    fresh model server — re-init/re-compile every time, which is exactly
    why the naive backend loses on anything short.

Production features beyond the paper's prototype:
  * fault tolerance: worker death or task exception -> requeue up to
    `max_attempts`; queue state snapshot/restore (checkpoint-restart);
  * straggler mitigation: speculative re-issue of tasks running longer
    than `straggler_factor` x the p95 of completed runtimes, first result
    wins (generalising HQ's time-request/time-limit split);
  * elastic scaling: `scale_to(n)` while running; an optional autoscaler
    grows the pool when backlog exceeds `autoscale_backlog` (HQ's
    worker-per-alloc on-demand allocation);
  * dependent tasks: requests with `depends_on` wait until their
    predecessors complete (MCMC-style chains, adaptive GP loops);
  * time limits: tasks observed to exceed `time_limit` are marked
    "timeout" (the limit bounds runaway jobs; the *time_request* hint is
    used only for dispatch ordering when `pack_by_cost=True`).
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.metrics import TaskRecord
from repro.core.task import EvalRequest, EvalResult, Model
from repro.sched import make_policy, make_predictor
from repro.sched.policy import SchedulingPolicy, WorkerView

_STOP = object()


class _Server:
    """One instantiated model server on one worker.  `init_t` is the cost
    of the FIRST instantiation and is never overwritten — warm reuses
    report 0 per dispatch while the warmup-cost record survives."""

    def __init__(self, model: Model, init_t: float):
        self.model = model
        self.init_t = init_t
        self.n_evals = 0


class Worker(threading.Thread):
    def __init__(self, pool: "Executor", wid: int):
        super().__init__(name=f"worker-{wid}", daemon=True)
        self.pool = pool
        self.wid = wid
        self.alive = True
        self.servers: Dict[str, _Server] = {}
        self.crashed = False

    def view(self) -> WorkerView:
        """What the scheduling policy may know about this worker.  The
        allocation budget is populated only when the executor was given
        an `allocation_s` (emulating HQ's bulk-allocation length) —
        without one, budget-aware packing degrades to plain LPT order."""
        budget = None
        if self.pool.allocation_s is not None:
            budget = max(self.pool.allocation_s
                         - (time.monotonic() - self.pool._t0), 0.0)
        return WorkerView(wid=self.wid, warm_models=frozenset(self.servers),
                          budget_left=budget)

    def _get_server(self, name: str) -> Tuple[_Server, float]:
        """Return (server, init seconds paid by THIS dispatch: 0 on reuse)."""
        if self.pool.persistent_servers and name in self.servers:
            return self.servers[name], 0.0
        t0 = time.monotonic()
        model = self.pool.model_factories[name]()
        model.warmup()
        init_t = time.monotonic() - t0
        server = _Server(model, init_t)
        self.pool._note_server_init(init_t)
        if self.pool.persistent_servers:
            self.servers[name] = server
        return server, init_t

    def run(self):
        while self.alive:
            try:
                item = self.pool._queue_get(timeout=0.02, worker=self)
            except IndexError:
                continue
            if item is _STOP:
                break
            req, attempt = item
            if self.pool._already_done(req.task_id):
                continue
            self.pool._mark_running(req, self)
            dispatch_t = time.monotonic()
            try:
                if self.crashed:
                    raise RuntimeError(f"worker-{self.wid} crashed")
                fail_n = int(req.config.get("fail_attempts", 0))
                if attempt <= fail_n:
                    raise RuntimeError("injected failure")
                server, init_t = self._get_server(req.model_name)
                t0 = time.monotonic()
                value = server.model(req.parameters, req.config)
                compute_t = time.monotonic() - t0
                server.n_evals += 1
                status = "ok"
                if req.time_limit and compute_t > req.time_limit:
                    status = "timeout"
                res = EvalResult(
                    task_id=req.task_id, value=value, status=status,
                    worker=self.name, attempts=attempt,
                    submit_t=req.submit_t, dispatch_t=dispatch_t,
                    start_t=dispatch_t, end_t=time.monotonic(),
                    compute_t=compute_t, init_t=init_t)
                self.pool._complete(req, res)
            except Exception as e:  # noqa: BLE001 — any task failure requeues
                self.pool._fail(req, attempt, repr(e), self)
                if self.crashed:
                    self.alive = False
                    self.pool._on_worker_death(self)


class Executor:
    """Persistent-worker executor with pluggable scheduling, fault
    tolerance and elastic scaling.

    `policy` selects how queued tasks are ordered/routed (a registered
    name — "fcfs", "sjf", "lpt", "pack", "steal" — or a configured
    `SchedulingPolicy` instance); `predictor` supplies online per-task
    cost estimates ("quantile", "gp", or a `RuntimePredictor`).  Every
    successful completion is fed back to the predictor, so cost-aware
    policies sharpen as the run progresses.  The legacy `pack_by_cost`
    flag maps onto `policy="sjf"` (ordering by the static time request,
    exactly the old inline-heap behaviour).

    `allocation_s` emulates HQ's bulk-allocation length for the live
    pool: workers then advertise their remaining budget to the policy,
    which is what makes `policy="pack"` allocation-aware here (without
    it, pack orders like LPT — budget fitting only applies where a
    budget exists, as in `simulate_policy`).
    """

    def __init__(self, model_factories: Dict[str, Callable[[], Model]],
                 n_workers: int = 2, *, persistent_servers: bool = True,
                 max_attempts: int = 3, backlog_limit: Optional[int] = None,
                 pack_by_cost: bool = False,
                 policy: Any = "fcfs",
                 predictor: Any = None,
                 straggler_factor: float = 0.0,
                 straggler_min_completed: int = 5,
                 autoscale_backlog: Optional[int] = None,
                 max_workers: int = 32,
                 allocation_s: Optional[float] = None,
                 name: str = "hq"):
        self.model_factories = dict(model_factories)
        self.persistent_servers = persistent_servers
        self.max_attempts = max_attempts
        self.backlog_limit = backlog_limit
        self.pack_by_cost = pack_by_cost
        self.straggler_factor = straggler_factor
        self.straggler_min_completed = straggler_min_completed
        self.autoscale_backlog = autoscale_backlog
        self.max_workers = max_workers
        self.name = name

        if pack_by_cost and policy in (None, "fcfs"):
            policy = "sjf"
        self.policy: SchedulingPolicy = make_policy(policy,
                                                    make_predictor(predictor))
        # completions feed the predictor the policy actually READS — if a
        # policy instance arrived with its own, that binding wins and any
        # `predictor=` kwarg is superseded (no split-brain feedback loop)
        self.predictor = self.policy.predictor
        self.allocation_s = allocation_s

        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._waiting: List[Tuple[EvalRequest, int]] = []   # unmet deps
        self._running: Dict[str, Tuple[EvalRequest, Worker, float]] = {}
        self._results: Dict[str, EvalResult] = {}
        self._requests: Dict[str, EvalRequest] = {}
        self._init_total_t = 0.0               # cumulative server-init cost
        self._init_count = 0
        self._t0 = time.monotonic()
        self.workers: List[Worker] = []
        self._stopping = False
        for i in range(n_workers):
            self._add_worker()
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         daemon=True)
        self._monitor.start()

    # ------------------------------------------------------------------
    # queue plumbing
    # ------------------------------------------------------------------
    def _queue_get(self, timeout: float, worker: Optional[Worker] = None):
        view = worker.view() if worker is not None else None
        with self._cv:
            if not len(self.policy):
                self._cv.wait(timeout)
            item = self.policy.pop(view)
            if item is None:
                raise IndexError
            return item

    def _push(self, req: EvalRequest, attempt: int):
        with self._cv:
            self.policy.push(req, attempt)
            self._cv.notify()

    def _already_done(self, task_id: str) -> bool:
        with self._lock:
            return task_id in self._results and \
                self._results[task_id].status == "ok"

    def _mark_running(self, req: EvalRequest, worker: Worker):
        with self._lock:
            self._running[req.task_id] = (req, worker, time.monotonic())

    def _note_server_init(self, init_t: float):
        with self._lock:
            self._init_total_t += init_t
            self._init_count += 1

    def _complete(self, req: EvalRequest, res: EvalResult):
        if res.status == "ok" and self.predictor is not None:
            # outside the scheduler lock: a GP refit must not stall dispatch
            try:
                self.predictor.observe(req, res.compute_t)
            except Exception:  # noqa: BLE001 — prediction is best-effort
                pass
        with self._cv:
            self._running.pop(req.task_id, None)
            prev = self._results.get(req.task_id)
            if prev is None or prev.status != "ok":    # first success wins
                self._results[req.task_id] = res
            self._release_dependents()
            self._cv.notify_all()

    def _fail(self, req: EvalRequest, attempt: int, error: str,
              worker: Worker):
        with self._cv:
            self._running.pop(req.task_id, None)
            if self._already_done(req.task_id):
                return
            if attempt < self.max_attempts:
                self._cv.notify_all()
                self._push(req, attempt + 1)
            else:
                self._results[req.task_id] = EvalResult(
                    task_id=req.task_id, status="failed", error=error,
                    worker=worker.name, attempts=attempt,
                    submit_t=req.submit_t, end_t=time.monotonic())
                self._release_dependents()
                self._cv.notify_all()

    def _release_dependents(self):
        still = []
        for req, attempt in self._waiting:
            if all(d in self._results for d in req.depends_on):
                self._push(req, attempt)
            else:
                still.append((req, attempt))
        self._waiting = still

    def _on_worker_death(self, worker: Worker):
        """Requeue whatever a dead worker was running (fault tolerance);
        the policy reflows any per-worker queue state it held."""
        with self._cv:
            if worker in self.workers:
                self.workers.remove(worker)
            self.policy.remove_worker(worker.wid)
            dead = [tid for tid, (_, w, _) in self._running.items()
                    if w is worker]
            for tid in dead:
                req, _, _ = self._running.pop(tid)
                self._push(req, 1)
            self._cv.notify_all()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, req: EvalRequest) -> str:
        with self._cv:
            if self.backlog_limit is not None:
                while len(self.policy) >= self.backlog_limit:
                    self._cv.wait(0.01)
            req.submit_t = time.monotonic()
            self._requests[req.task_id] = req
            if req.depends_on and not all(d in self._results
                                          for d in req.depends_on):
                self._waiting.append((req, 1))
            else:
                self._push(req, 1)
        return req.task_id

    def result(self, task_id: str, timeout: float = 300.0) -> EvalResult:
        deadline = time.monotonic() + timeout
        with self._cv:
            while task_id not in self._results:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TimeoutError(task_id)
                self._cv.wait(min(left, 0.05))
            return self._results[task_id]

    def run_all(self, reqs: Sequence[EvalRequest], timeout: float = 600.0
                ) -> List[EvalResult]:
        ids = [self.submit(r) for r in reqs]
        return [self.result(t, timeout) for t in ids]

    def evaluate(self, model_name: str, parameters, config=None,
                 timeout: float = 300.0):
        """Synchronous UM-Bridge-style call through the scheduler."""
        req = EvalRequest(model_name=model_name, parameters=parameters,
                          config=config or {})
        self.submit(req)
        res = self.result(req.task_id, timeout)
        if res.status != "ok":
            raise RuntimeError(f"{model_name} failed: {res.error}")
        return res.value

    # ------------------------------------------------------------------
    # elasticity / fault injection / introspection
    # ------------------------------------------------------------------
    def _add_worker(self):
        wid = getattr(self, "_wid_counter", 0)
        self._wid_counter = wid + 1
        w = Worker(self, wid)
        self.workers.append(w)
        w.start()

    def scale_to(self, n: int):
        with self._lock:
            n = min(n, self.max_workers)
            while len(self.workers) < n:
                self._add_worker()
            while len(self.workers) > n:
                w = self.workers.pop()
                w.alive = False
                self.policy.remove_worker(w.wid)

    def kill_worker(self, idx: int = 0):
        """Fault injection: hard-kill one worker (tests, chaos drills)."""
        with self._lock:
            if idx < len(self.workers):
                self.workers[idx].crashed = True

    def backlog(self) -> int:
        with self._lock:
            return len(self.policy)

    def n_workers(self) -> int:
        return len([w for w in self.workers if w.alive])

    def _monitor_loop(self):
        while not self._stopping:
            time.sleep(0.05)
            # autoscaling
            if self.autoscale_backlog is not None:
                if self.backlog() > self.autoscale_backlog and \
                        len(self.workers) < self.max_workers:
                    self.scale_to(len(self.workers) + 1)
            # straggler re-issue (speculative execution): the p95 comes
            # from the online predictor when one is configured, else from
            # a scan over completed results
            if self.straggler_factor > 0:
                with self._lock:
                    done = [r.compute_t for r in self._results.values()
                            if r.status == "ok"]
                    if len(done) >= self.straggler_min_completed:
                        p95 = (self.predictor.quantile(0.95)
                               if self.predictor is not None else None)
                        if p95 is None:
                            done.sort()
                            p95 = done[int(0.95 * (len(done) - 1))]
                        cutoff = self.straggler_factor * max(p95, 1e-3)
                        now = time.monotonic()
                        for tid, (req, w, t_start) in list(
                                self._running.items()):
                            if now - t_start > cutoff and \
                                    not req.config.get("_speculated"):
                                req.config["_speculated"] = True
                                self._push(req, 1)

    # ------------------------------------------------------------------
    # checkpoint / restart
    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Serialisable queue state: done ids + pending request payloads."""
        with self._lock:
            pending = [req for req, _ in self.policy.pending()]
            pending += [req for req, _ in self._waiting]
            pending += [req for req, _, _ in self._running.values()]
            return {
                "completed": {tid: {"value": r.value, "status": r.status}
                              for tid, r in self._results.items()},
                "pending": [{
                    "model_name": r.model_name, "parameters": r.parameters,
                    "config": {k: v for k, v in r.config.items()
                               if not k.startswith("_")},
                    "task_id": r.task_id,
                    "time_request": r.time_request,
                    "time_limit": r.time_limit,
                    "depends_on": list(r.depends_on),
                } for r in pending],
            }

    @classmethod
    def restore(cls, snap: Dict[str, Any],
                model_factories: Dict[str, Callable[[], Model]],
                **kw) -> "Executor":
        ex = cls(model_factories, **kw)
        with ex._lock:
            for tid, r in snap["completed"].items():
                ex._results[tid] = EvalResult(task_id=tid, value=r["value"],
                                              status=r["status"])
        for p in snap["pending"]:
            ex.submit(EvalRequest(**p))
        return ex

    # ------------------------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        """Executor-level counters.  `server_init_total_t` is the true
        cumulative warmup cost across all server instantiations — visible
        even though warm reuses report `init_t == 0` per result."""
        with self._lock:
            by_status: Dict[str, int] = {}
            for r in self._results.values():
                by_status[r.status] = by_status.get(r.status, 0) + 1
            return {
                "server_init_total_t": self._init_total_t,
                "server_inits": self._init_count,
                "policy": self.policy.name,
                "backlog": len(self.policy),
                "running": len(self._running),
                "waiting_on_deps": len(self._waiting),
                "workers_alive": self.n_workers(),
                "results_by_status": by_status,
            }

    def records(self) -> List[TaskRecord]:
        with self._lock:
            out = []
            for r in self._results.values():
                out.append(TaskRecord(
                    task_id=r.task_id, submit_t=r.submit_t,
                    start_t=r.start_t, end_t=r.end_t,
                    cpu_time=r.cpu_time, compute_t=r.compute_t,
                    worker=r.worker, attempts=r.attempts, status=r.status))
            return out

    def shutdown(self):
        self._stopping = True
        with self._cv:
            for w in self.workers:
                w.alive = False
            self._cv.notify_all()
        for w in self.workers:
            w.join(timeout=1.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
