"""Live execution engine: persistent-worker task scheduling over real JAX.

This realises the paper's mechanism with *real* costs instead of simulated
ones: a pool of persistent workers (threads; on a TPU pod, one per mesh
slice) pulls evaluation requests from a pluggable `repro.sched` scheduling
policy (FCFS by default; SJF/LPT/cost-aware packing/work stealing by
name), with an optional online runtime predictor learning task costs from
completions.

  * HQ semantics (`persistent_servers=True`): each worker instantiates a
    model server ONCE and reuses it — the jit-compile / warmup cost (the
    real analogue of the paper's ~1 s model-server init + SLURM env
    re-init) is paid once per (worker, model).
  * naive-SLURM semantics (`persistent_servers=False`): every task gets a
    fresh model server — re-init/re-compile every time, which is exactly
    why the naive backend loses on anything short.

Production features beyond the paper's prototype:
  * fault tolerance: worker death or task exception -> requeue up to
    `max_attempts`; queue state snapshot/restore (checkpoint-restart);
  * straggler mitigation: speculative re-issue of tasks running longer
    than `straggler_factor` x the p95 of completed runtimes, first result
    wins (generalising HQ's time-request/time-limit split);
  * elastic scaling: `scale_to(n)` while running; worker groups are
    allocation-backed (`repro.cluster`) — an optional `AutoAllocator`
    submits and drains whole allocations from backlog *cost* (seconds of
    queued work), reproducing HQ's autoalloc; the legacy count-based
    `autoscale_backlog` kwarg is an alias routed through the same
    allocator;
  * dependent tasks: requests with `depends_on` wait until their
    predecessors complete (MCMC-style chains, adaptive GP loops);
  * time limits: tasks observed to exceed `time_limit` are marked
    "timeout" (the limit bounds runaway jobs; the *time_request* hint is
    used only for dispatch ordering when `pack_by_cost=True`).
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.chaos.speculate import find_stragglers
from repro.core.metrics import TaskRecord
from repro.core.task import EvalRequest, EvalResult, Model
from repro.sched import make_policy, make_predictor
from repro.sched.policy import SchedulingPolicy, WorkerView

_STOP = object()


class _Server:
    """One instantiated model server on one worker.  `init_t` is the cost
    of the FIRST instantiation and is never overwritten — warm reuses
    report 0 per dispatch while the warmup-cost record survives."""

    def __init__(self, model: Model, init_t: float):
        self.model = model
        self.init_t = init_t
        self.n_evals = 0


class Worker(threading.Thread):
    def __init__(self, pool: "Executor", wid: int, alloc=None):
        super().__init__(name=f"worker-{wid}", daemon=True)
        self.pool = pool
        self.wid = wid
        self.alloc = alloc                     # owning repro.cluster Allocation
        self.alive = True
        self.servers: Dict[str, _Server] = {}
        self.crashed = False

    def view(self) -> WorkerView:
        """What the scheduling policy may know about this worker.  Every
        worker belongs to an `Allocation`; the budget is that group's
        remaining walltime (None when unbounded — budget-aware packing
        then degrades to plain LPT order, as documented)."""
        budget = alloc_id = None
        if self.alloc is not None:
            budget = self.alloc.budget_left(self.pool._clock())
            alloc_id = self.alloc.alloc_id
        return WorkerView(wid=self.wid, warm_models=frozenset(self.servers),
                          budget_left=budget, alloc_id=alloc_id)

    def _get_server(self, name: str) -> Tuple[_Server, float]:
        """Return (server, init seconds paid by THIS dispatch: 0 on reuse)."""
        if self.pool.persistent_servers and name in self.servers:
            return self.servers[name], 0.0
        t0 = self.pool._clock()
        model = self.pool.model_factories[name]()
        model.warmup()
        init_t = self.pool._clock() - t0
        server = _Server(model, init_t)
        self.pool._note_server_init(init_t)
        if self.pool.persistent_servers:
            self.servers[name] = server
        return server, init_t

    def run(self):
        while self.alive:
            try:
                item = self.pool._queue_get(timeout=0.02, worker=self)
            except IndexError:
                continue
            if item is _STOP:
                break
            req, attempt = item
            if self.pool._already_done(req.task_id):
                continue
            self.pool._mark_running(req, self, attempt)
            dispatch_t = self.pool._clock()
            surrogate = (self.pool._surrogate()
                         if req.config.get("_surrogate") else None)
            surrogate_failed = False
            try:
                if self.crashed:
                    raise RuntimeError(f"worker-{self.wid} crashed")
                fail_n = int(req.config.get("fail_attempts", 0))
                if attempt <= fail_n:
                    raise RuntimeError("injected failure")
                if surrogate is not None:
                    # offload path: one GP predict, no model server
                    t0 = self.pool._clock()
                    try:
                        value = surrogate.evaluate(req.parameters)
                    except Exception:
                        surrogate_failed = True
                        raise
                    compute_t = self.pool._clock() - t0
                    init_t = 0.0
                    wname = f"{self.name}-surrogate"
                else:
                    server, init_t = self._get_server(req.model_name)
                    t0 = self.pool._clock()
                    value = server.model(req.parameters, req.config)
                    compute_t = self.pool._clock() - t0
                    server.n_evals += 1
                    wname = self.name
                status = "ok"
                if req.time_limit and compute_t > req.time_limit:
                    status = "timeout"
                res = EvalResult(
                    task_id=req.task_id, value=value, status=status,
                    worker=wname, attempts=attempt,
                    submit_t=req.submit_t, dispatch_t=dispatch_t,
                    start_t=dispatch_t, end_t=self.pool._clock(),
                    compute_t=compute_t, init_t=init_t)
                self.pool._complete(req, res)
            except Exception as e:  # noqa: BLE001 — any task failure requeues
                if surrogate_failed:
                    # a broken SURROGATE must not fail the task: PIN the
                    # retry to the real path (just dropping the flag is
                    # not enough — the requeue re-decides and would
                    # re-route to the same broken surrogate) and refund
                    # the "CPU seconds avoided" credit.  Failures raised
                    # before evaluate() (worker crash, injected failure)
                    # are NOT the surrogate's fault: the retry may still
                    # take the offload the gates approved.
                    req.config.pop("_surrogate", None)
                    req.config["_no_surrogate"] = True
                    surrogate.rollback(req)
                self.pool._fail(req, attempt, repr(e), self)
                if self.crashed:
                    self.alive = False
                    self.pool._on_worker_death(self)


class Executor:
    """Persistent-worker executor with pluggable scheduling, fault
    tolerance and elastic scaling.

    `policy` selects how queued tasks are ordered/routed (a registered
    name — "fcfs", "sjf", "lpt", "pack", "steal" — or a configured
    `SchedulingPolicy` instance); `predictor` supplies online per-task
    cost estimates ("quantile", "gp", or a `RuntimePredictor`).  Every
    successful completion is fed back to the predictor, so cost-aware
    policies sharpen as the run progresses.  The legacy `pack_by_cost`
    flag maps onto `policy="sjf"` (ordering by the static time request,
    exactly the old inline-heap behaviour).

    Worker groups are allocation-backed (`repro.cluster.Allocation`):
    `allocation_s` bounds the initial group's walltime (workers then
    advertise their remaining budget to the policy, which is what makes
    `policy="pack"` allocation-aware here).  `cluster=` accepts a
    configured `Broker` (one policy per allocation, cluster-level
    routing) and `autoalloc=` an `AutoAllocConfig` / `AutoAllocator`
    that submits and drains allocations from backlog cost — the same
    objects `simulate_cluster` drives on a virtual clock.  The legacy
    count-based `autoscale_backlog` is an alias routed through that
    allocator (one single-worker allocation per step, and idle groups
    can now be drained — the old loop could only grow).

    In cluster mode the allocation lifecycle is driven by the shared
    `repro.cluster.stepper.LifecycleStepper` — the same rules (and rule
    ORDER) `simulate_cluster` runs on a virtual clock; `_cluster_step`
    is just the monitor-thread adapter around one `stepper.step()`.
    `clock` injects the time source (default `time.monotonic`) and
    `monitor_interval=None` disables the monitor thread — together they
    let the differential parity harness (`repro.cluster.parity`) drive
    this executor deterministically on a virtual clock via `step()`.
    """

    def __init__(self, model_factories: Dict[str, Callable[[], Model]],
                 n_workers: int = 2, *, persistent_servers: bool = True,
                 max_attempts: int = 3, backlog_limit: Optional[int] = None,
                 pack_by_cost: bool = False,
                 policy: Any = "fcfs",
                 predictor: Any = None,
                 straggler_factor: float = 0.0,
                 straggler_min_completed: int = 5,
                 autoscale_backlog: Optional[int] = None,
                 max_workers: Optional[int] = 32,
                 allocation_s: Optional[float] = None,
                 cluster: Any = None,
                 autoalloc: Any = None,
                 clock: Optional[Callable[[], float]] = None,
                 monitor_interval: Optional[float] = 0.05,
                 tracer: Any = None,
                 metrics_registry: Any = None,
                 calibration: Any = None,
                 on_result: Optional[Callable[[EvalRequest, EvalResult],
                                              None]] = None,
                 on_tick: Optional[Callable[[float], None]] = None,
                 name: str = "hq"):
        from repro.cluster.allocation import Allocation
        from repro.cluster.autoalloc import AutoAllocConfig, AutoAllocator
        from repro.cluster.broker import Broker
        from repro.cluster.stepper import LifecycleStepper
        self._clock = clock if clock is not None else time.monotonic
        # opt-in observability (repro.obs): spans/instants stamped with
        # THIS executor's injected clock, so virtual-clock replays
        # produce traces comparable with the simulator's
        self.tracer = tracer
        self.registry = metrics_registry
        # optional repro.obs.calib.CalibrationMonitor: fed the observed
        # per-attempt overheads (and, in cluster mode, granted queue
        # waits via the stepper) so model-vs-reality drift raises alarms
        # while the run is live
        self.calibration = calibration
        if tracer is not None:
            tracer.bind_clock(self._clock)
        self.model_factories = dict(model_factories)
        self.persistent_servers = persistent_servers
        self.max_attempts = max_attempts
        self.backlog_limit = backlog_limit
        self.pack_by_cost = pack_by_cost
        self.straggler_factor = straggler_factor
        self.straggler_min_completed = straggler_min_completed
        self.autoscale_backlog = autoscale_backlog
        self.max_workers = max_workers
        self.name = name
        # terminal-result hook (repro.service billing/SLO accounting):
        # fired once per stored result, UNDER the dispatch lock — must be
        # O(1) and must never call back into this executor
        self.on_result = on_result

        if pack_by_cost and policy in (None, "fcfs"):
            policy = "sjf"
        pred = make_predictor(predictor)
        wants_cluster = (cluster is not None or autoalloc is not None
                         or autoscale_backlog is not None)
        if cluster is not None:
            if not isinstance(cluster, Broker):
                raise TypeError(f"cluster= expects a Broker, got {cluster!r}")
            self.policy: SchedulingPolicy = cluster.bind(pred)
        elif wants_cluster and not isinstance(policy, Broker):
            if isinstance(policy, SchedulingPolicy):
                raise TypeError(
                    "autoalloc/autoscale need one policy instance PER "
                    "allocation: pass the policy by registered name (or a "
                    "Broker via cluster=), not a shared instance")
            # policy="broker" here means "use brokered dispatch", not
            # "nest a broker per allocation" — map it to the default
            self.policy = Broker(predictor=pred,
                                 policy="fcfs" if policy == "broker"
                                 else policy)
        else:
            self.policy = make_policy(policy, pred)
        # completions feed the predictor the policy actually READS — if a
        # policy instance arrived with its own, that binding wins and any
        # `predictor=` kwarg is superseded (no split-brain feedback loop)
        self.predictor = self.policy.predictor
        self.allocation_s = allocation_s
        self._cluster_mode = isinstance(self.policy, Broker)
        if tracer is not None:
            if self._cluster_mode:
                # BEFORE the initial allocation registers, so its whole
                # lifecycle is on the trace
                self.policy.set_tracer(tracer)
            else:
                sur = self._surrogate()
                if sur is not None:
                    sur.tracer = tracer

        if autoalloc is not None:
            self.autoalloc = (autoalloc if isinstance(autoalloc,
                                                      AutoAllocator)
                              else AutoAllocator(
                                  autoalloc if isinstance(autoalloc,
                                                          AutoAllocConfig)
                                  else AutoAllocConfig(**autoalloc)))
        elif autoscale_backlog is not None:
            # deprecated count-based path, now an alias reproducing the
            # old ABSOLUTE "backlog() > N tasks" trigger exactly:
            # count_tasks ignores cost hints, per_worker=False skips the
            # capacity division the legacy loop never did; served by
            # single-worker allocations up to max_workers
            cap = max_workers if max_workers is not None else 32
            self.autoalloc = AutoAllocator(AutoAllocConfig(
                workers_per_alloc=1, walltime_s=None,
                backlog_high_s=float(autoscale_backlog),
                backlog_low_s=1.0, per_worker=False, count_tasks=True,
                max_pending=cap,
                max_allocations=max(cap - n_workers + 1, 1),
                min_allocations=1, idle_drain_s=30.0, hysteresis_s=0.05))
        else:
            self.autoalloc = None
        if self.autoalloc is not None and max_workers is not None:
            # the allocator must see the pool cap or it churns grants the
            # monitor can only cancel (zero-headroom submit loops).  An
            # uncapped pool (max_workers=None) preserves any caller-set
            # worker_cap — exactly as `simulate_cluster` does, so a
            # shared allocator instance behaves identically on both paths
            self.autoalloc.worker_cap = max_workers

        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._waiting: List[Tuple[EvalRequest, int]] = []   # unmet deps
        # task_id -> (request, worker, start time, attempt number)
        self._running: Dict[str, Tuple[EvalRequest, Worker, float, int]] = {}
        # second in-flight copy of a speculatively re-executed task
        # (first completion wins; the loser is cancelled and billed)
        self._hedges: Dict[str, Tuple[EvalRequest, Worker, float, int]] = {}
        # worker-killing failures per task (quarantine threshold), for
        # the threaded path; the replay/sim path counts in the stepper
        self._fail_counts: Dict[str, int] = {}
        self.retry_seed = 0                    # backoff-jitter seed
        self._results: Dict[str, EvalResult] = {}
        self._requests: Dict[str, EvalRequest] = {}
        self._init_total_t = 0.0               # cumulative server-init cost
        self._init_count = 0
        self._t0 = self._clock()
        self.workers: List[Worker] = []
        self._retired_allocs: List[Any] = []   # for allocation_records()
        self._stopping = False
        # the shared lifecycle state machine (cluster mode): exactly the
        # rules, in exactly the order, `simulate_cluster` runs
        self._stepper = None
        if self._cluster_mode:
            self._stepper = LifecycleStepper(
                self.policy, self.autoalloc, now=self._clock,
                spawn_workers=self._spawn_group,
                retire_workers=self._retire_group,
                busy_count=self._busy_by_alloc,
                worker_count=self._n_real_workers,
                record_failed=self._record_expired,
                record_quarantined=self._record_quarantined,
                max_workers=max_workers, max_attempts=max_attempts,
                retired=self._retired_allocs,
                tracer=tracer, registry=metrics_registry,
                calibration=calibration, on_tick=on_tick)
        # the initial worker group: one allocation, granted immediately
        # (thread startup is the live analogue of the queue wait).  In
        # cluster mode n_workers=0 means "bootstrap from the allocator"
        # — zero standing capacity, exactly like the elastic simulator —
        # and the group is granted THROUGH the stepper, so even the
        # initial spawn takes the canonical capped QUEUED->RUNNING path.
        self._initial_alloc = None
        if not self._cluster_mode or n_workers > 0:
            alloc_id = (self.policy.next_alloc_id() if self._cluster_mode
                        else 0)
            self._initial_alloc = Allocation(alloc_id, n_workers,
                                             allocation_s)
            self._initial_alloc.submit(self._t0, 0.0)
            if self._cluster_mode:
                self.policy.add_allocation(self._initial_alloc)
            else:
                self._initial_alloc.tick(self._t0)
                if tracer is not None:
                    tracer.alloc_state(self._initial_alloc)
                for i in range(n_workers):
                    self._add_worker(self._initial_alloc)
        if self._cluster_mode:
            self._cluster_step()               # grant + spawn at t0
        self._monitor = None
        if monitor_interval is not None and monitor_interval > 0:
            self._monitor_interval = monitor_interval
            self._monitor = threading.Thread(target=self._monitor_loop,
                                             daemon=True)
            self._monitor.start()

    # ------------------------------------------------------------------
    # queue plumbing
    # ------------------------------------------------------------------
    def _queue_get(self, timeout: float, worker: Optional[Worker] = None):
        view = worker.view() if worker is not None else None
        with self._cv:
            if not len(self.policy):
                self._cv.wait(timeout)
            item = self.policy.pop(view)
            if item is None:
                raise IndexError
            return item

    def _push(self, req: EvalRequest, attempt: int):
        with self._cv:
            if self.tracer is not None and not self._cluster_mode:
                # cluster mode: the Broker's own push emits this
                self.tracer.task_queued(req.task_id, attempt, req=req)
            self.policy.push(req, attempt)
            self._cv.notify()

    def _already_done(self, task_id: str) -> bool:
        """Terminal states whose stale queued copies must be dropped at
        pop: a quarantined or terminally failed task can still have a
        hedge or requeued copy sitting in the queue."""
        with self._lock:
            return task_id in self._results and \
                self._results[task_id].status in ("ok", "failed",
                                                  "quarantined")

    def _mark_running(self, req: EvalRequest, worker: Worker, attempt: int):
        with self._lock:
            entry = (req, worker, self._clock(), attempt)
            if req.task_id in self._running:
                # a second copy of a hedged task: first completion wins
                self._hedges[req.task_id] = entry
            else:
                self._running[req.task_id] = entry

    def _note_server_init(self, init_t: float):
        with self._lock:
            self._init_total_t += init_t
            self._init_count += 1

    def _surrogate(self):
        """The surrogate-offload engine, when the policy carries one
        (`SurrogateOffloadPolicy` or a `Broker` with ``surrogate=``)."""
        return getattr(self.policy, "surrogate", None)

    def _complete(self, req: EvalRequest, res: EvalResult):
        # derived from the RESULT, not req.config: the shared config is
        # re-stamped by every re-push decision (speculation, requeues)
        # and may have changed while this attempt was in flight
        offloaded = res.worker.endswith("-surrogate")
        if res.status == "ok" and not offloaded:
            # outside the scheduler lock: a GP refit must not stall
            # dispatch.  Offloaded completions are skipped: milliseconds
            # of GP predict must not teach the runtime predictor what the
            # REAL model costs at this theta.
            if self.predictor is not None:
                if self.registry is not None:
                    # residual BEFORE observe: the prediction this run's
                    # dispatch actually used, not the sharpened one
                    try:
                        pred = self.predictor.predict(req)
                        if pred is not None:
                            self.registry.observe(
                                "predictor_abs_residual",
                                abs(pred - res.compute_t))
                    except Exception:  # noqa: BLE001 — best-effort
                        pass
                try:
                    self.predictor.observe(req, res.compute_t)
                except Exception:  # noqa: BLE001 — prediction is best-effort
                    pass
            sur = self._surrogate()
            if sur is not None:
                # a real run is ground truth for the QoI surrogate too:
                # conditioning on it widens the trusted region
                try:
                    sur.observe(req.parameters, res.value,
                                model_name=req.model_name)
                except Exception:  # noqa: BLE001 — enrichment is best-effort
                    pass
        with self._cv:
            # the completing ATTEMPT picks its own slot: a hedged task
            # has two in-flight copies keyed by the same task_id, and
            # billing/teardown must hit the copy that actually finished
            entry = self._running.get(req.task_id)
            hedge = self._hedges.get(req.task_id)
            if hedge is not None and hedge[3] == res.attempts and \
                    (entry is None or entry[3] != res.attempts):
                entry = self._hedges.pop(req.task_id)
            elif entry is not None:
                self._running.pop(req.task_id)
            # busy billing happens HERE, under the lock, keyed on still
            # being in flight: a task whose allocation expired was
            # already billed (partial, up to the kill) by the stepper and
            # removed by _retire_group, so no double count is possible
            if entry is not None:
                w = entry[1]
                if w is not None and w.alloc is not None \
                        and w.alloc.state != "expired":
                    w.alloc.note_busy(res.cpu_time)
            prev = self._results.get(req.task_id)
            # first success wins; "failed"/"quarantined" are TERMINAL
            # (recorded only once every attempt is spent — e.g. an
            # allocation-expiry kill at max_attempts, after which the
            # orphaned thread may still finish; matching
            # simulate_cluster, its late result is void)
            if prev is None or prev.status not in ("ok", "failed",
                                                   "quarantined"):
                self._results[req.task_id] = res
                # first-completion-wins: any OTHER copy of this task
                # still in flight lost the race — cancel it, billing the
                # partial work where it ran
                self._cancel_copies(req.task_id)
                if self.tracer is not None and entry is not None:
                    w = entry[1]
                    aid = (w.alloc.alloc_id if w.alloc is not None else 0)
                    self.tracer.task_attempt(
                        req.task_id, aid, w.wid, res.dispatch_t,
                        res.start_t, res.init_t, res.end_t,
                        res.attempts, res.status,
                        model=req.model_name, compute=res.compute_t)
                if self.calibration is not None and entry is not None \
                        and not offloaded:
                    self.calibration.observe_attempt(
                        req.model_name,
                        dispatch_s=res.start_t - res.dispatch_t,
                        init_s=res.init_t, compute_s=res.compute_t,
                        now=res.end_t)
                self._notify_result(req, res)
            self._release_dependents()
            self._cv.notify_all()

    def _cancel_copies(self, task_id: str, t: Optional[float] = None):
        """A task just reached a terminal state: cancel any other
        in-flight copy (the loser of a speculative hedge, or a copy
        orphaned by quarantine), billing its partial work where it ran.
        Runs under the dispatch lock."""
        if t is None:
            t = self._clock()
        for table in (self._running, self._hedges):
            other = table.pop(task_id, None)
            if other is None:
                continue
            _oreq, ow, ot, oattempt = other
            if ow is not None and ow.alloc is not None \
                    and ow.alloc.state != "expired":
                ow.alloc.note_busy(max(t - ot, 0.0))
            if self.tracer is not None:
                self.tracer.task_hedge_cancel(task_id, oattempt, t, ot)

    def _pop_inflight(self, task_id: str, attempt: int):
        """Remove (and return) the in-flight entry for one specific
        attempt of a task, whichever table it landed in."""
        entry = self._running.get(task_id)
        if entry is not None and entry[3] == attempt:
            return self._running.pop(task_id)
        hedge = self._hedges.get(task_id)
        if hedge is not None and hedge[3] == attempt:
            return self._hedges.pop(task_id)
        return self._running.pop(task_id, None)

    def _fail(self, req: EvalRequest, attempt: int, error: str,
              worker: Worker):
        with self._cv:
            entry = self._pop_inflight(req.task_id, attempt)
            if self._already_done(req.task_id):
                return
            # hardened recovery (threaded path; the replay/sim path runs
            # the same rules through the shared stepper): worker-killing
            # failures count toward the task's quarantine threshold, and
            # retried attempts honour the policy's deterministic backoff
            retry = getattr(req, "retry", None)
            fatal = worker is not None and getattr(worker, "crashed", False)
            if retry is not None and fatal \
                    and retry.quarantine_after is not None:
                n = self._fail_counts.get(req.task_id, 0) + 1
                self._fail_counts[req.task_id] = n
                if n >= retry.quarantine_after:
                    now = self._clock()
                    self._results[req.task_id] = EvalResult(
                        task_id=req.task_id, status="quarantined",
                        error=error, worker=worker.name, attempts=attempt,
                        submit_t=req.submit_t, start_t=now, end_t=now)
                    if self.tracer is not None:
                        since = entry[2] if entry is not None else now
                        self.tracer.task_quarantined(req.task_id, attempt,
                                                     now, since)
                    self._cancel_copies(req.task_id, now)
                    self._notify_result(req, self._results[req.task_id])
                    self._release_dependents()
                    self._cv.notify_all()
                    return
            # attempts are bounded by BOTH the executor-wide limit and the
            # request's own max_attempts (which simulate_cluster honours —
            # live and sim must agree on when a task is spent)
            if attempt < min(self.max_attempts, req.max_attempts):
                self._cv.notify_all()
                if retry is not None and retry.base_s > 0.0 \
                        and self._stepper is not None:
                    # deferred requeue: the monitor's next step() past
                    # the release time pushes it (exponential backoff
                    # with the policy's seeded jitter)
                    release = self._clock() + retry.backoff_s(
                        req.task_id, attempt, seed=self.retry_seed)
                    self._stepper.defer_push(req, attempt + 1, release)
                else:
                    self._push(req, attempt + 1)
            else:
                # terminal shape matches the sim's killed_task_record:
                # start_t == end_t (the failure instant), zero cpu time
                now = self._clock()
                self._results[req.task_id] = EvalResult(
                    task_id=req.task_id, status="failed", error=error,
                    worker=worker.name, attempts=attempt,
                    submit_t=req.submit_t, start_t=now, end_t=now)
                if self.tracer is not None:
                    self.tracer.task_failed(req.task_id, attempt, ts=now)
                self._notify_result(req, self._results[req.task_id])
                self._release_dependents()
                self._cv.notify_all()

    def _release_dependents(self):
        still = []
        for req, attempt in self._waiting:
            if all(d in self._results for d in req.depends_on):
                self._push(req, attempt)
            else:
                still.append((req, attempt))
        self._waiting = still

    def _on_worker_death(self, worker: Worker):
        """Requeue whatever a dead worker was running (fault tolerance);
        the policy reflows any per-worker queue state it held."""
        with self._cv:
            if worker in self.workers:
                self.workers.remove(worker)
            self.policy.remove_worker(worker.wid)
            for table in (self._running, self._hedges):
                dead = [tid for tid, (_, w, _, _) in table.items()
                        if w is worker]
                for tid in dead:
                    req, _, _, attempt = table.pop(tid)
                    self._push(req, attempt)   # the crash was not its fault
            if worker.alloc is not None and worker.alloc.virtual \
                    and worker.alloc.state == "running":
                # the surrogate queue is served ONLY by virtual workers
                # (routing/stealing exclude it): a dead one must be
                # replaced or trusted tasks would queue there forever
                self._add_worker(worker.alloc)
            self._cv.notify_all()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, req: EvalRequest) -> str:
        with self._cv:
            if self.backlog_limit is not None:
                while len(self.policy) >= self.backlog_limit:
                    self._cv.wait(0.01)
            req.submit_t = self._clock()
            self._requests[req.task_id] = req
            if req.depends_on and not all(d in self._results
                                          for d in req.depends_on):
                self._waiting.append((req, 1))
            else:
                self._push(req, 1)
        return req.task_id

    def result(self, task_id: str, timeout: float = 300.0) -> EvalResult:
        deadline = time.monotonic() + timeout
        with self._cv:
            while task_id not in self._results:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TimeoutError(task_id)
                self._cv.wait(min(left, 0.05))
            return self._results[task_id]

    def run_all(self, reqs: Sequence[EvalRequest], timeout: float = 600.0
                ) -> List[EvalResult]:
        ids = [self.submit(r) for r in reqs]
        return [self.result(t, timeout) for t in ids]

    def evaluate(self, model_name: str, parameters, config=None,
                 timeout: float = 300.0):
        """Synchronous UM-Bridge-style call through the scheduler."""
        req = EvalRequest(model_name=model_name, parameters=parameters,
                          config=config or {})
        self.submit(req)
        res = self.result(req.task_id, timeout)
        if res.status != "ok":
            raise RuntimeError(f"{model_name} failed: {res.error}")
        return res.value

    # ------------------------------------------------------------------
    # elasticity / fault injection / introspection
    # ------------------------------------------------------------------
    # real threads serve the queue; the parity harness flips this off and
    # plays the worker objects deterministically on a virtual clock
    _threaded = True

    def _add_worker(self, alloc=None):
        wid = getattr(self, "_wid_counter", 0)
        self._wid_counter = wid + 1
        w = Worker(self, wid, alloc=alloc if alloc is not None
                   else self._initial_alloc)
        self.workers.append(w)
        if self._threaded:
            w.start()

    def scale_to(self, n: int):
        """Resize the pool by hand (autoalloc-managed groups are the
        allocator's business — scale those via its config).  New workers
        join the oldest OPEN allocation; if every group has been drained
        away (autoalloc with min_allocations=0), a fresh unbounded one is
        brought up — workers must never be pinned to a retired group the
        broker no longer routes to."""
        from repro.cluster.allocation import Allocation
        with self._lock:
            if self.max_workers is not None:
                n = min(n, self.max_workers)
            target = self._initial_alloc
            if self._cluster_mode:
                open_allocs = [a for a in self.policy.allocations()
                               if a.state == "running" and not a.virtual]
                if open_allocs:
                    target = open_allocs[0]
                elif self._n_real_workers() < n:   # all groups gone: new one
                    now = self._clock()
                    target = Allocation(self.policy.next_alloc_id(), 0,
                                        None)
                    target.submit(now, 0.0)
                    target.tick(now)
                    self.policy.add_allocation(target)
            now = self._clock()
            while self._n_real_workers() < n:
                self._add_worker(target)
                target.resize(target.n_workers + 1, now)
            while self._n_real_workers() > n:
                # shrink pops the newest REAL worker; the virtual
                # surrogate server is not capacity and stays up
                w = next(w for w in reversed(self.workers)
                         if w.alloc is None or not w.alloc.virtual)
                self.workers.remove(w)
                w.alive = False
                self.policy.remove_worker(w.wid)
                if w.alloc is not None:        # time-weighted billing
                    w.alloc.resize(w.alloc.n_workers - 1, now)

    def kill_worker(self, idx: int = 0):
        """Fault injection: hard-kill one worker (tests, chaos drills)."""
        with self._lock:
            if idx < len(self.workers):
                self.workers[idx].crashed = True

    def backlog(self) -> int:
        with self._lock:
            return len(self.policy)

    def n_workers(self) -> int:
        return len([w for w in self.workers if w.alive])

    def _n_real_workers(self) -> int:
        """Workers on real allocations (virtual surrogate servers are not
        capacity and never count against `max_workers`)."""
        return len([w for w in self.workers
                    if w.alloc is None or not w.alloc.virtual])

    def _cluster_step(self):
        """One canonical lifecycle tick (monitor thread): the shared
        `LifecycleStepper` — the SAME state machine `simulate_cluster`
        drives on a virtual clock — runs here against this executor's
        clock, with thread spawn/teardown as its mechanism callbacks."""
        with self._cv:
            self._stepper.step(self._clock())
            self._cv.notify_all()

    # -- stepper mechanism callbacks (all run under the dispatch lock) --
    def _spawn_group(self, alloc):
        for _ in range(alloc.n_workers):
            self._add_worker(alloc)

    def _retire_group(self, alloc):
        """Tear down an allocation's worker threads; hand the stepper the
        in-flight tasks that died with them (it bills their partial busy
        time and decides requeue-vs-fail — the one walltime-kill rule)."""
        killed = []
        for w in [w for w in self.workers if w.alloc is alloc]:
            w.alive = False
            self.workers.remove(w)
            self.policy.remove_worker(w.wid)
            for table in (self._running, self._hedges):
                for tid in [tid for tid, (_, rw, _, _) in table.items()
                            if rw is w]:
                    req, _, t_start, attempt = table.pop(tid)
                    killed.append((req, attempt, t_start))
        return killed

    def _busy_by_alloc(self) -> Dict[int, int]:
        busy: Dict[int, int] = {}
        for table in (self._running, self._hedges):
            for _req, w, _t, _a in table.values():
                if w is not None and w.alloc is not None:
                    busy[w.alloc.alloc_id] = busy.get(w.alloc.alloc_id,
                                                      0) + 1
        return busy

    def _worker_busy(self, worker: Worker) -> bool:
        return any(e[1] is worker for e in self._running.values()) or \
            any(e[1] is worker for e in self._hedges.values())

    def _record_expired(self, req, attempt, alloc, now: float):
        """Terminal record for a walltime-killed task with every attempt
        spent — the canonical `metrics.killed_task_record` shape."""
        if self._already_done(req.task_id):
            return
        self._results[req.task_id] = EvalResult(
            task_id=req.task_id, status="failed",
            error="allocation expired", worker=f"alloc{alloc.alloc_id}",
            attempts=attempt, submit_t=req.submit_t,
            start_t=now, end_t=now)
        self._cancel_copies(req.task_id, now)
        self._notify_result(req, self._results[req.task_id])
        self._release_dependents()

    def _record_quarantined(self, req, attempt, alloc, now: float):
        """Terminal record for a task quarantined by the stepper's
        retry rule (N worker-killing failures): canonical killed shape
        with status 'quarantined'."""
        if self._already_done(req.task_id):
            return
        self._results[req.task_id] = EvalResult(
            task_id=req.task_id, status="quarantined",
            error="quarantined after repeated worker-killing failures",
            worker=f"alloc{alloc.alloc_id}", attempts=attempt,
            submit_t=req.submit_t, start_t=now, end_t=now)
        self._cancel_copies(req.task_id, now)
        self._notify_result(req, self._results[req.task_id])
        self._release_dependents()

    def _notify_result(self, req: EvalRequest, res: EvalResult):
        """Fire the `on_result` hook for a just-stored result.  Runs
        under the dispatch lock; the hook is best-effort — accounting
        failures must never take dispatch down with them."""
        if self.on_result is not None:
            try:
                self.on_result(req, res)
            except Exception:  # noqa: BLE001
                pass

    def _monitor_loop(self):
        while not self._stopping:
            time.sleep(self._monitor_interval)
            self.step()

    def step(self):
        """One monitor pass: lifecycle tick (cluster mode) + straggler
        re-issue.  Public so a virtual-clock driver (`repro.cluster.
        parity`) can pump the executor without the monitor thread."""
        if self._cluster_mode:
            self._cluster_step()
        if self.straggler_factor > 0:
            self._straggler_check(self._clock())

    def _straggler_check(self, now: float):
        """Speculatively re-issue tasks running far beyond their MODEL'S
        p95 (`repro.chaos.find_stragglers` — the one ladder the simulator
        also runs, so a parity replay hedges the same tasks at the same
        times).  A pooled p95 misfires on heterogeneous models: the fast
        model's p95 re-issues every healthy task of a slow model, doubling
        exactly the work that is already the bottleneck.

        Cluster mode is capacity-gated: hedges launch only when the queue
        is drained and idle real workers exist (at most one hedge per
        idle worker per tick), and the copy runs as ``attempt + 1`` so
        its trace span is distinguishable from the original's.  The
        plain-pool path keeps the legacy ungated behaviour."""
        with self._lock:
            if self.straggler_factor <= 0.0:
                return
            completions = []
            for tid, r in self._results.items():
                if r.status != "ok" or r.worker.endswith("-surrogate"):
                    continue       # ms-scale surrogate hits would crater p95
                r_req = self._requests.get(tid)
                if r_req is not None:
                    completions.append((r_req.model_name, r.compute_t))
            idle_n = None
            if self._cluster_mode:
                if len(self.policy):
                    return         # hedge on SPARE capacity only
                idle_n = len([w for w in self.workers
                              if w.alloc is not None and not w.alloc.virtual
                              and w.alloc.state == "running"
                              and not self._worker_busy(w)])
                if idle_n == 0:
                    return
            cands = sorted(((tid, req.model_name, t_start, attempt)
                            for tid, (req, _w, t_start, attempt)
                            in self._running.items()
                            if not req.config.get("_speculated")
                            and not req.config.get("_surrogate")),
                           key=lambda c: (c[2], c[0]))
            ids = find_stragglers(
                now, [(c[0], c[1], c[2]) for c in cands], completions,
                predictor=self.predictor, factor=self.straggler_factor,
                min_n=self.straggler_min_completed)
            if idle_n is not None:
                ids = ids[:idle_n]
            by_id = {c[0]: c for c in cands}
            for tid in ids:
                _, _, t_start, attempt = by_id[tid]
                req = self._running[tid][0]
                req.config["_speculated"] = True
                # the copy must duplicate the SAME work: re-deciding the
                # serving path here could stamp _surrogate on the shared
                # config while the real attempt is in flight, and a
                # first-to-finish GP answer would silently replace (and
                # discard) the real result
                req.config["_no_surrogate"] = True
                if self._cluster_mode:
                    if self.tracer is not None:
                        self.tracer.task_speculate(tid, attempt + 1, now,
                                                   t_start)
                    self._push(req, attempt + 1)
                else:
                    self._push(req, 1)

    # ------------------------------------------------------------------
    # checkpoint / restart
    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Serialisable queue state: done ids + pending request payloads
        + the predictor's learned state (where it supports persistence —
        engine backend name and conditioning set included, so a restored
        broker re-costs with the SAME surrogate backend instead of
        silently falling back to a cold default)."""
        with self._lock:
            pending = [req for req, _ in self.policy.pending()]
            pending += [req for req, _ in self._waiting]
            pending += [req for req, _, _, _ in self._running.values()]
            sd = getattr(self.predictor, "state_dict", None)
            return {
                "completed": {tid: {"value": r.value, "status": r.status}
                              for tid, r in self._results.items()},
                "pending": [{
                    "model_name": r.model_name, "parameters": r.parameters,
                    "config": {k: v for k, v in r.config.items()
                               if not k.startswith("_")},
                    "task_id": r.task_id,
                    "time_request": r.time_request,
                    "time_limit": r.time_limit,
                    "n_cpus": r.n_cpus,
                    "max_attempts": r.max_attempts,
                    "deadline": r.deadline,
                    "tenant": r.tenant,
                    "retry": (dataclasses.asdict(r.retry)
                              if r.retry is not None else None),
                    "depends_on": list(r.depends_on),
                } for r in pending],
                "predictor": sd() if callable(sd) else None,
            }

    @classmethod
    def restore(cls, snap: Dict[str, Any],
                model_factories: Dict[str, Callable[[], Model]],
                **kw) -> "Executor":
        ex = cls(model_factories, **kw)
        pred_state = snap.get("predictor")
        if pred_state and ex.predictor is not None:
            # before any resubmission, so the very first re-costing pass
            # already uses the persisted posterior
            ls = getattr(ex.predictor, "load_state", None)
            if callable(ls):
                ls(pred_state)
        with ex._lock:
            for tid, r in snap["completed"].items():
                ex._results[tid] = EvalResult(task_id=tid, value=r["value"],
                                              status=r["status"])
        for p in snap["pending"]:
            ex.submit(EvalRequest(**p))
        return ex

    # ------------------------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        """Executor-level counters.  `server_init_total_t` is the true
        cumulative warmup cost across all server instantiations — visible
        even though warm reuses report `init_t == 0` per result."""
        with self._lock:
            by_status: Dict[str, int] = {}
            for r in self._results.values():
                by_status[r.status] = by_status.get(r.status, 0) + 1
            sur = self._surrogate()
            offload = (dataclasses.asdict(sur.stats())
                       if sur is not None else None)
            attribution = None
            if self.tracer is not None:
                from repro.obs.attribution import attribute_overhead
                attribution = attribute_overhead(
                    self.tracer.events())["totals"]
            return {
                "offload": offload,
                "stepper_events": (list(self._stepper.events)
                                   if self._stepper is not None else []),
                "overhead_attribution": attribution,
                "server_init_total_t": self._init_total_t,
                "server_inits": self._init_count,
                "policy": self.policy.name,
                "backlog": len(self.policy),
                "running": len(self._running),
                "waiting_on_deps": len(self._waiting),
                "workers_alive": self.n_workers(),
                "results_by_status": by_status,
                # real allocations only: the virtual surrogate allocation
                # is invisible to every other capacity metric too
                "allocations_open": (len([a for a in
                                          self.policy.allocations()
                                          if a.open and not a.virtual])
                                     if self._cluster_mode else 1),
                "allocations_total": (len([a for a in
                                           self.policy.allocations()
                                           if not a.virtual])
                                      + len([a for a in self._retired_allocs
                                             if not a.virtual])
                                      if self._cluster_mode else 1),
            }

    def allocation_records(self) -> List[Any]:
        """`AllocationRecord`s for every allocation this executor owned
        (retired ones first) — feeds `metrics.node_seconds` /
        `metrics.allocation_utilization` exactly like `simulate_cluster`."""
        now = self._clock()
        with self._lock:
            live = (self.policy.allocations() if self._cluster_mode
                    else [self._initial_alloc])
            out = [a.record() for a in self._retired_allocs]
            out += [a.record(now) for a in live if a is not None]
            return sorted(out, key=lambda r: r.alloc_id)

    def records(self) -> List[TaskRecord]:
        with self._lock:
            out = []
            for r in self._results.values():
                out.append(TaskRecord(
                    task_id=r.task_id, submit_t=r.submit_t,
                    start_t=r.start_t, end_t=r.end_t,
                    cpu_time=r.cpu_time, compute_t=r.compute_t,
                    worker=r.worker, attempts=r.attempts, status=r.status))
            return out

    def shutdown(self):
        self._stopping = True
        now = self._clock()
        with self._cv:
            for w in self.workers:
                w.alive = False
            allocs = (self.policy.allocations() if self._cluster_mode
                      else [self._initial_alloc])
            for a in allocs:
                if a is not None:
                    a.terminate(now)           # close the billing window
            if self._cluster_mode:             # states changed out-of-band
                self.policy.invalidate_allocations()
            self._cv.notify_all()
        for w in self.workers:
            if w.ident is not None:            # never-started replay workers
                w.join(timeout=1.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
