"""Scheduling metrics from the paper (§IV-A).

The total runtime of a job (makespan) is treated as separable into two
mutually exclusive additive parts: scheduling overhead and CPU time.
Queueing time is deliberately part of the overhead (the scheduler's
responsibility is to allocate resources regardless of system utilisation).

SLR (Schedule Length Ratio, Topcuoglu et al. 2002):
    SLR = makespan / sum_i C_i
where C_i is the compute time of task i.  SLR == 1.0 is the zero-overhead
lower bound when tasks run strictly sequentially on one worker; with W
workers the work-conserving bound is max(1/W, ...) — the paper reports the
sequential-sum form, so we do too.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence


@dataclasses.dataclass
class TaskRecord:
    """Per-task timestamps (all in seconds on one clock).

    submit_t   — when the task entered the scheduler queue
    start_t    — when its job began occupying resources (CPU timer start)
    end_t      — when it finished
    cpu_time   — CPU-occupancy time of the *job* (init + compute), per the
                 paper's definition ("the timer begins when the job starts")
    compute_t  — the application's own compute time C_i (for SLR)
    """
    task_id: str
    submit_t: float
    start_t: float
    end_t: float
    cpu_time: float
    compute_t: float
    worker: str = ""
    attempts: int = 1
    status: str = "ok"

    @property
    def overhead(self) -> float:
        """Per-task scheduling overhead = (end - submit) - cpu_time, >= 0."""
        return max((self.end_t - self.submit_t) - self.cpu_time, 0.0)


def killed_task_record(task_id: str, submit_t: float, now: float,
                       alloc_id: int, attempts: int) -> TaskRecord:
    """The canonical terminal record for a task killed at allocation
    expiry with every attempt spent: ``start_t == end_t == now`` (the
    kill instant) and zero cpu/compute time — the partial work it burned
    is billed to the allocation's ``busy_t``, never to the task.  Both
    `simulate_cluster` and the live `Executor` emit exactly this shape
    (asserted by the differential parity suite in `tests/test_parity.py`)."""
    return TaskRecord(
        task_id=task_id, submit_t=submit_t, start_t=now, end_t=now,
        cpu_time=0.0, compute_t=0.0, worker=f"alloc{alloc_id}",
        attempts=attempts, status="failed")


def quarantined_task_record(task_id: str, submit_t: float, now: float,
                            alloc_id: int, attempts: int) -> TaskRecord:
    """Terminal record for a poison task quarantined after repeatedly
    killing workers (`RetryPolicy.quarantine_after`): same canonical
    killed shape as `killed_task_record` — zero cpu/compute, the burned
    partial work billed to the allocation — but a distinct terminal
    status so quarantines are countable and never retried."""
    return TaskRecord(
        task_id=task_id, submit_t=submit_t, start_t=now, end_t=now,
        cpu_time=0.0, compute_t=0.0, worker=f"alloc{alloc_id}",
        attempts=attempts, status="quarantined")


@dataclasses.dataclass
class AllocationRecord:
    """One bulk allocation's lifetime (the `repro.cluster` analogue of
    `TaskRecord`).

    start_t/end_t are NaN while the allocation never reached that point
    (e.g. cancelled while still queued); `node_seconds`/`utilization`
    treat those as zero node-seconds held.
    """
    alloc_id: int
    n_workers: int                   # group size at record time
    submit_t: float
    start_t: float                   # nodes granted (NaN if never)
    end_t: float                     # nodes released (NaN if still held)
    state: str = "expired"           # final lifecycle state
    queue_wait: float = 0.0
    busy_t: float = 0.0              # summed worker-busy seconds
    # time-weighted billed node-seconds (resize-aware); negative means
    # "not provided, derive from n_workers x held_s"
    node_s: float = -1.0

    @property
    def held_s(self) -> float:
        """Wall seconds the node group was actually held."""
        if math.isnan(self.start_t) or math.isnan(self.end_t):
            return 0.0
        return max(self.end_t - self.start_t, 0.0)

    @property
    def node_seconds(self) -> float:
        if self.node_s >= 0.0:
            return self.node_s
        return self.n_workers * self.held_s


@dataclasses.dataclass
class OffloadStats:
    """What surrogate-offload routing did to a run (`repro.sched.offload`).

    n_considered        — routing decisions taken (every push);
    n_offloaded         — tasks sent down the surrogate path;
    n_surrogate_evals   — surrogate evaluations actually served;
    cpu_seconds_avoided — predicted compute seconds the offloaded tasks
                          would have burned on the real model (estimate:
                          the same cost the router gated on);
    sd_histogram        — histogram of the normalised posterior sd at
                          every variance-gated decision point
                          ({"edges": [n_bins+1], "counts": [n_bins]}) —
                          how often the surrogate was trusted, and by
                          what margin.
    """
    n_considered: int = 0
    n_offloaded: int = 0
    n_surrogate_evals: int = 0
    cpu_seconds_avoided: float = 0.0
    sd_histogram: Dict[str, List[float]] = dataclasses.field(
        default_factory=lambda: {"edges": [], "counts": []})

    @property
    def offload_rate(self) -> float:
        return self.n_offloaded / self.n_considered if self.n_considered \
            else 0.0


def sd_histogram(sds: Sequence[float], n_bins: int = 10
                 ) -> Dict[str, List[float]]:
    """Fixed-width histogram of posterior-sd observations (pure python —
    runs under the dispatch lock, so no array-library round trips)."""
    if not sds:
        return {"edges": [], "counts": []}
    lo, hi = min(sds), max(sds)
    if hi <= lo:
        hi = lo + 1e-9
    width = (hi - lo) / n_bins
    counts = [0.0] * n_bins
    for s in sds:
        counts[min(int((s - lo) / width), n_bins - 1)] += 1.0
    return {"edges": [lo + i * width for i in range(n_bins + 1)],
            "counts": counts}


def node_seconds(allocs: Sequence[AllocationRecord]) -> float:
    """Total node-seconds billed across allocations: what an elastic
    policy is trying to minimise at bounded makespan cost."""
    return sum(a.node_seconds for a in allocs)


def allocation_utilization(allocs: Sequence[AllocationRecord]) -> float:
    """Busy fraction of billed node-seconds, in [0, 1]; 0 if nothing was
    ever held (so idle static pools read as the waste they are)."""
    total = node_seconds(allocs)
    if total <= 0:
        return 0.0
    return min(sum(a.busy_t for a in allocs) / total, 1.0)


@dataclasses.dataclass(frozen=True)
class BenchmarkSummary:
    name: str
    scheduler: str
    n_tasks: int
    makespan: float
    total_cpu_time: float
    total_compute: float
    scheduling_overhead: float
    slr: float
    cpu_time_stats: Dict[str, float]
    overhead_stats: Dict[str, float]


def _stats(xs: Sequence[float]) -> Dict[str, float]:
    if not xs:
        return {k: 0.0 for k in ("min", "q1", "median", "q3", "max", "mean")}
    s = sorted(xs)
    n = len(s)

    def q(p: float) -> float:
        i = p * (n - 1)
        lo, hi = int(math.floor(i)), int(math.ceil(i))
        return s[lo] + (s[hi] - s[lo]) * (i - lo)

    return {"min": s[0], "q1": q(0.25), "median": q(0.5), "q3": q(0.75),
            "max": s[-1], "mean": sum(s) / n}


def makespan(records: Sequence[TaskRecord]) -> float:
    if not records:
        return 0.0
    return max(r.end_t for r in records) - min(r.submit_t for r in records)


def total_cpu_time(records: Sequence[TaskRecord]) -> float:
    return sum(r.cpu_time for r in records)


def scheduling_overhead(records: Sequence[TaskRecord]) -> float:
    """Makespan minus the *critical-path share* of CPU time.

    The paper derives overhead by subtracting CPU time from makespan per
    job and clamping at zero (SLURM's 1 s log granularity can make it
    negative).  Aggregated the same way: sum of per-task overheads."""
    return sum(r.overhead for r in records)


def slr(records: Sequence[TaskRecord]) -> float:
    total_c = sum(r.compute_t for r in records)
    if total_c <= 0:
        return float("inf")
    return makespan(records) / total_c


def summarize(name: str, scheduler: str,
              records: Sequence[TaskRecord]) -> BenchmarkSummary:
    return BenchmarkSummary(
        name=name,
        scheduler=scheduler,
        n_tasks=len(records),
        makespan=makespan(records),
        total_cpu_time=total_cpu_time(records),
        total_compute=sum(r.compute_t for r in records),
        scheduling_overhead=scheduling_overhead(records),
        slr=slr(records),
        cpu_time_stats=_stats([r.cpu_time for r in records]),
        overhead_stats=_stats([r.overhead for r in records]),
    )


def comparison_row(a: BenchmarkSummary, b: BenchmarkSummary) -> Dict[str, float]:
    """Headline ratios used in EXPERIMENTS.md (a = baseline, b = candidate)."""
    def ratio(x, y):
        return x / y if y else float("inf")

    return {
        "makespan_reduction": 1.0 - ratio(b.makespan, a.makespan),
        "cpu_time_reduction": 1.0 - ratio(b.total_cpu_time, a.total_cpu_time),
        "overhead_ratio": ratio(a.scheduling_overhead,
                                max(b.scheduling_overhead, 1e-9)),
        "slr_a": a.slr,
        "slr_b": b.slr,
    }
