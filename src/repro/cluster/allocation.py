"""Allocation lifecycle: the unit of elasticity HQ manages beside SLURM.

The paper's decisive mechanism is that HyperQueue keeps *bulk allocations*
alive next to the native scheduler: a worker group is granted for a
walltime, serves many tasks with warm model servers, and dies as a unit —
taking its warm servers with it.  Before this module the repo faked that
with a single static ``allocation_s`` float on the executor; here the
allocation is a first-class object with the full lifecycle

    pending  -> queued  -> running -> draining -> expired
    (created)   (submitted, (nodes    (no new     (walltime up /
                 waiting in  granted)  tasks)      drained dry)
                 the queue)

and its queue wait drawn from the same `BackendSpec` overhead model that
calibrates the discrete-event simulator — so `simulate_cluster` and the
live `Executor` share one notion of what an allocation costs to obtain.

Allocations are clock-agnostic: every transition takes ``now`` explicitly,
so the same object works on the simulator's virtual clock and the live
executor's ``time.monotonic()`` clock.

This module owns the *states*; the rules for WHEN transitions are driven
(grant-time worker spawn under the `max_workers` cap, walltime-kill
requeue/fail, drained-dry termination, autoalloc ordering) live once in
`repro.cluster.stepper.LifecycleStepper` — never call `tick`/`terminate`
from a new driving loop; adapt the stepper instead.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.metrics import AllocationRecord

PENDING = "pending"
QUEUED = "queued"
RUNNING = "running"
DRAINING = "draining"
EXPIRED = "expired"


class Allocation:
    """One bulk allocation: a group of `n_workers` workers granted for
    `walltime_s` seconds after a queue wait.

    `queue_wait` is fixed at submission (drawn from a `BackendSpec` by the
    caller — `AutoAllocator.submit` — or 0.0 for live pools where the
    "queue" is just thread startup).  `busy_t` accumulates worker-busy
    seconds so utilisation is computable per allocation.
    """

    def __init__(self, alloc_id: int, n_workers: int,
                 walltime_s: Optional[float] = None, *,
                 virtual: bool = False):
        self.alloc_id = alloc_id
        self.n_workers = n_workers
        self.walltime_s = (float(walltime_s) if walltime_s is not None
                           else math.inf)
        # virtual allocations model a zero-cost service (the GP-surrogate
        # path): no node-seconds are ever billed and no busy time accrues,
        # so elasticity metrics stay about REAL capacity
        self.virtual = virtual
        self.state = PENDING
        self.queue_wait = 0.0
        self.submit_t: Optional[float] = None
        self.ready_t: Optional[float] = None   # when nodes were granted
        self.end_t: Optional[float] = None     # when the group terminated
        self.busy_t = 0.0                      # summed worker-busy seconds
        # worker-second accounting across resizes: node-seconds accrued
        # before `_ws_mark` live in `_ws_accum`; after it, bill at the
        # CURRENT n_workers (so a late resize never rewrites history)
        self._ws_accum = 0.0
        self._ws_mark: Optional[float] = None  # defaults to ready_t

    # -- lifecycle ------------------------------------------------------
    def submit(self, now: float, queue_wait: float = 0.0) -> "Allocation":
        assert self.state == PENDING, self.state
        self.state = QUEUED
        self.submit_t = now
        self.queue_wait = max(float(queue_wait), 0.0)
        return self

    @property
    def grant_t(self) -> float:
        """When the scheduler will hand over the nodes (valid once queued)."""
        assert self.submit_t is not None
        return self.submit_t + self.queue_wait

    @property
    def expiry_t(self) -> float:
        """Hard walltime bound (inf for unbounded live pools)."""
        return self.grant_t + self.walltime_s

    def tick(self, now: float) -> str:
        """Advance time-driven transitions; returns the (new) state.
        Drain and early termination are *decisions* (autoallocator /
        executor), so they have their own methods — tick only handles
        what the native scheduler does on its own: granting nodes and
        enforcing walltime."""
        if self.state == QUEUED and now >= self.grant_t:
            self.state = RUNNING
            self.ready_t = self.grant_t
        if self.state in (RUNNING, DRAINING) and now >= self.expiry_t:
            self.state = EXPIRED
            self.end_t = self.expiry_t
        return self.state

    def drain(self, now: float) -> None:
        """Stop accepting new tasks; running ones finish, then the group
        is terminated early (instead of burning node-seconds to walltime)."""
        if self.state in (QUEUED, RUNNING):
            if self.state == QUEUED:           # never started: cancel
                self.state = EXPIRED
                self.end_t = now
            else:
                self.state = DRAINING

    def terminate(self, now: float) -> None:
        """Release the nodes (drained dry, or executor shutdown)."""
        if self.state != EXPIRED:
            self.state = EXPIRED
            self.end_t = min(now, self.expiry_t) if self.ready_t is not None \
                else now

    # -- views ----------------------------------------------------------
    @property
    def open(self) -> bool:
        """Accepting new tasks (routable)."""
        return self.state in (QUEUED, RUNNING)

    def budget_left(self, now: float) -> Optional[float]:
        """Seconds of walltime remaining; None when unbounded (so
        budget-aware packing degrades to plain LPT, as documented on
        `PackingPolicy`)."""
        if math.isinf(self.walltime_s):
            return None
        if self.state == PENDING:
            return self.walltime_s
        return max(self.expiry_t - now, 0.0)

    def note_busy(self, seconds: float) -> None:
        if self.virtual:
            return
        self.busy_t += max(float(seconds), 0.0)

    def resize(self, n_workers: int, now: float) -> None:
        """Change the group size mid-lifetime (manual `scale_to`, cap
        enforcement), accruing node-seconds at the OLD size up to `now`
        so billing stays time-weighted instead of final-size x lifetime."""
        if self.ready_t is not None:
            mark = self._ws_mark if self._ws_mark is not None \
                else self.ready_t
            upto = min(now, self.expiry_t)
            self._ws_accum += max(upto - mark, 0.0) * self.n_workers
            self._ws_mark = upto
        self.n_workers = max(int(n_workers), 0)

    def node_seconds(self, until: Optional[float] = None) -> float:
        """Node-seconds actually billed (0 until granted / if cancelled;
        always 0 for virtual allocations); `until` bills a still-held
        group provisionally up to the present."""
        if self.virtual:
            return 0.0
        end = self.end_t if self.end_t is not None else until
        if self.ready_t is None or end is None:
            return 0.0
        end = min(end, self.expiry_t)
        mark = self._ws_mark if self._ws_mark is not None else self.ready_t
        return self._ws_accum + self.n_workers * max(end - mark, 0.0)

    def record(self, now: Optional[float] = None) -> AllocationRecord:
        """Snapshot as an `AllocationRecord`.  A group still held has no
        `end_t`; pass `now` to bill it provisionally up to the present
        (so live-executor node-second accounting is non-zero mid-run)."""
        end = self.end_t
        if end is None and self.ready_t is not None and now is not None:
            end = min(now, self.expiry_t)
        return AllocationRecord(
            alloc_id=self.alloc_id, n_workers=self.n_workers,
            submit_t=self.submit_t if self.submit_t is not None else 0.0,
            start_t=self.ready_t if self.ready_t is not None else float("nan"),
            end_t=end if end is not None else float("nan"),
            state=self.state, queue_wait=self.queue_wait,
            busy_t=self.busy_t, node_s=self.node_seconds(until=now))

    def __repr__(self) -> str:
        return (f"Allocation(id={self.alloc_id}, n={self.n_workers}, "
                f"state={self.state}, walltime={self.walltime_s})")
