"""Differential parity harness: one trace, two drivers, one stepper.

The repo's headline numbers are credible only because the simulated
elasticity runs and the live executor exercise the same Broker /
AutoAllocator / LifecycleStepper objects.  This module makes that claim
*testable*: `replay_live` drives the REAL `Executor` machinery — its
broker, allocator, shared `LifecycleStepper`, `_complete`/`_fail`
bookkeeping and allocation records — on a virtual clock with the worker
threads replaced by a deterministic replay loop (the harness plays the
workers: pop, mark running, complete at ``start + init + compute`` in
virtual seconds, using the same `BackendSpec` cost model as the
simulator).  `run_parity` then runs the SAME seeded trace + config
through `simulate_cluster` and `replay_live` and diffs everything the
paper's analysis depends on:

  * per-task terminal status, attempts, and timestamps (including the
    canonical killed-task record shape: ``start_t == end_t``, zero CPU);
  * the allocator decision log (action, allocation id, time, backlog);
  * the stepper's spawn / kill / drain-dry / cancel event sequence;
  * allocation records (group sizes, grant/termination times, billing).

An empty divergence list is the no-forked-logic guarantee on that trace;
`tests/test_parity.py` asserts it across static, elastic, walltime-kill,
drained-dry and surrogate scenarios, and `benchmarks/parity.py --quick`
keeps it honest in CI.

Scope note: the harness replays *lifecycle and scheduling*, not model
execution — completions return placeholder values, so a live run that
conditions a real GP surrogate on completion values has no simulator
counterpart (the sim never produces values).  Parity scenarios involving
offload therefore use deterministic stub engines.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Set

from repro.chaos.inject import ChaosInjector
from repro.cluster.allocation import RUNNING, Allocation
from repro.cluster.autoalloc import AutoAllocConfig, AutoAllocator
from repro.cluster.broker import Broker
from repro.cluster.sim import (ClusterResult, fill_lost, next_event_time,
                               simulate_cluster, trace_requests)
from repro.cluster.traces import TraceTask
from repro.core.backends import BackendSpec
from repro.core.executor import Executor
from repro.core.task import EvalRequest, EvalResult
from repro.sched.policy import WorkerView
from repro.sched.registry import make_predictor


class VirtualClock:
    """Monotonic virtual time: `Executor(clock=...)` reads it, the
    replay loop advances it event by event."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance_to(self, t: float) -> float:
        self.t = max(self.t, float(t))
        return self.t


class _ReplayExecutor(Executor):
    """The real executor, minus thread startup: worker objects exist and
    own their allocations, but the replay loop plays them."""

    _threaded = False


@dataclasses.dataclass
class _Inflight:
    wid: int
    req: EvalRequest
    attempt: int
    mark_t: float        # dispatch decision time (busy-billing base)
    start_t: float       # mark_t + dispatch latency
    end_t: float
    init: float
    compute: float
    wname: str


def replay_live(spec: BackendSpec, trace: List[TraceTask], *,
                policy: Any = "fcfs", predictor: Any = None,
                autoalloc: Any = None, broker: Optional[Broker] = None,
                allocator: Optional[AutoAllocator] = None,
                n_workers: int = 4,
                walltime_s: Optional[float] = None,
                max_workers: Optional[int] = None,
                seed: int = 0, tick_s: float = 5.0,
                max_attempts: int = 3,
                max_t: float = 1e9,
                tracer: Any = None,
                registry: Any = None,
                fault_plan: Any = None,
                retry_policy: Any = None,
                straggler_factor: float = 0.0,
                straggler_min_completed: int = 5) -> ClusterResult:
    """Run one trace through a real `Executor` on a virtual clock.

    Same signature and semantics as `simulate_cluster`; the difference
    is WHICH adapter wraps the shared `LifecycleStepper`: here it is the
    executor's own (`_cluster_step`, thread-table spawn/retire, the
    `_complete`/`_record_expired` result paths), pumped deterministically
    in the simulator's event order — arrivals, completions, lifecycle
    step, dispatch."""
    if broker is None:
        broker = Broker(predictor=make_predictor(predictor), policy=policy)
    if allocator is None and autoalloc is not None:
        if isinstance(autoalloc, AutoAllocator):
            allocator = autoalloc
        else:
            cfg = (autoalloc if isinstance(autoalloc, AutoAllocConfig)
                   else AutoAllocConfig(**autoalloc))
            allocator = AutoAllocator(cfg, spec=spec, seed=seed)

    arrivals, reqs, runtimes = trace_requests(trace, max_attempts,
                                              retry_policy)

    if tracer is not None:
        # the sim emits the identical spec-constants instant (the replay
        # layer reads it back for bit-exact constants); emitting it on
        # both sides keeps the parity span sequences comparable
        tracer.instant("trace.spec", ts=0.0, args={
            "backend": spec.name,
            "dispatch_latency": float(spec.dispatch_latency),
            "server_init": float(spec.server_init),
            "queue_wait_sigma": float(spec.queue_wait_sigma)})

    clock = VirtualClock(0.0)
    factories = {tt.model_name: _never_called for tt in arrivals}
    ex = _ReplayExecutor(
        factories,
        n_workers=(0 if allocator is not None else n_workers),
        max_attempts=max_attempts, max_workers=max_workers,
        allocation_s=walltime_s, cluster=broker, autoalloc=allocator,
        clock=clock, monitor_interval=None,
        straggler_factor=straggler_factor,
        straggler_min_completed=straggler_min_completed,
        tracer=tracer, metrics_registry=registry)
    ex.retry_seed = seed                       # backoff jitter, as the sim
    ex._stepper.retry_seed = seed

    warm: Dict[int, Set[str]] = {}
    inflight: Dict[int, _Inflight] = {}

    # ---- chaos: the injector's handlers mutate the EXECUTOR's tables —
    # the live mirror of the sim's handlers, firing at the same stepper
    # choke point at the same virtual times
    inj: Optional[ChaosInjector] = None
    if fault_plan is not None and len(fault_plan):
        inj = ChaosInjector(fault_plan, tracer=tracer)

        def _crash(ev, t):
            busy = sorted((w for w in ex.workers
                           if w.wid in inflight and w.alloc is not None
                           and not w.alloc.virtual),
                          key=lambda w: (w.alloc.alloc_id, w.wid))
            if not busy:
                return
            w = busy[ev.target % len(busy)]
            e = inflight.pop(w.wid)
            ex._pop_inflight(e.req.task_id, e.attempt)
            w.alloc.note_busy(max(t - e.mark_t, 0.0))
            warm.get(w.wid, set()).clear()     # process restart: cold
            ex._stepper.requeue_or_fail(e.req, e.attempt, e.mark_t, t,
                                        w.alloc, fatal=True)

        def _preempt(ev, t):
            allocs = sorted((a for a in ex.policy.allocations()
                             if not a.virtual and a.state == RUNNING),
                            key=lambda a: a.alloc_id)
            if not allocs:
                return
            victim = allocs[ev.target % len(allocs)]
            deadline = t + ev.duration_s
            if deadline < victim.expiry_t:
                victim.walltime_s = deadline - victim.grant_t
            ex.policy.drain_allocation(victim.alloc_id, t)
            by_wid = {w.wid: w for w in ex.workers}
            for wid in sorted(list(inflight)):
                e = inflight[wid]
                w = by_wid.get(wid)
                if w is None or w.alloc is not victim \
                        or e.end_t <= deadline:
                    continue
                del inflight[wid]
                ex._pop_inflight(e.req.task_id, e.attempt)
                victim.note_busy(max(t - e.mark_t, 0.0))
                ex._stepper.requeue_or_fail(e.req, e.attempt, e.mark_t,
                                            t, victim, migrate=True)

        def _slow(ev, t):
            cand = sorted((w for w in ex.workers
                           if w.alloc is not None and not w.alloc.virtual
                           and w.alloc.state == RUNNING),
                          key=lambda w: (w.alloc.alloc_id, w.wid))
            if cand:
                w = cand[ev.target % len(cand)]
                inj.set_slow(w.wid, ev.factor, t + ev.duration_s)

        def _outage(ev, t):
            sur = getattr(ex.policy, "surrogate", None)
            if sur is not None and hasattr(sur, "set_degraded"):
                sur.set_degraded(t, t + ev.duration_s, "outage")

        inj.on("worker_crash", _crash)
        inj.on("preempt", _preempt)
        inj.on("slow_node", _slow)
        inj.on("surrogate_outage", _outage)
        # journal_torn: the replay has no journal — symmetric no-op
        ex._stepper.chaos = inj

    def _slot_alive(e):
        ent = ex._running.get(e.req.task_id)
        if ent is not None and ent[3] == e.attempt:
            return True
        ent = ex._hedges.get(e.req.task_id)
        return ent is not None and ent[3] == e.attempt

    _TERMINAL = ("ok", "failed", "timeout", "quarantined")

    def n_terminal():
        return sum(1 for r in ex._results.values()
                   if r.status in _TERMINAL)

    arr_i = 0
    now = 0.0
    next_tick = 0.0
    n_final = 0                                # tasks with a terminal result

    max_iters = 10_000 + 1_000 * len(reqs)
    iters = 0
    while n_final < len(reqs):
        iters += 1
        if iters > max_iters:
            raise RuntimeError(
                f"replay_live made no progress after {max_iters} events "
                f"({n_final}/{len(reqs)} tasks done)")
        # ---- next event time (the sim's candidate set, shared code) ---
        extra = ex._stepper.deferred_times()   # backoff release times
        if inj is not None:
            ct = inj.next_time()
            if ct is not None:
                extra.append(ct)
        elastic = allocator is not None or (
            straggler_factor > 0.0 and bool(inflight))
        nxt = next_event_time(arrivals, arr_i,
                              (e.end_t for e in inflight.values()),
                              broker, elastic, next_tick, extra)
        if nxt is None:
            break
        now = max(now, nxt)
        if now > max_t:
            break
        clock.advance_to(now)
        if now >= next_tick:
            next_tick = now + tick_s

        # ---- arrivals --------------------------------------------------
        while arr_i < len(arrivals) and arrivals[arr_i].t <= now:
            ex.submit(reqs[arr_i])             # stamps submit_t = clock()
            arr_i += 1

        # ---- completions (before walltime kills, as in the sim) -------
        done = sorted((e for e in inflight.values() if e.end_t <= now),
                      key=lambda e: (e.end_t, e.wid))
        for e in done:
            if not _slot_alive(e):
                del inflight[e.wid]            # cancelled this batch
                continue
            if inj is not None and not e.req.config.get("_surrogate") \
                    and inj.take_corruption():
                # corrupted result (sim mirror): bill the burned work,
                # route through retry/quarantine as a fatal failure
                ent = ex._pop_inflight(e.req.task_id, e.attempt)
                w = ent[1] if ent is not None else None
                alloc = w.alloc if w is not None else None
                if alloc is not None:
                    alloc.note_busy(max(e.end_t - e.mark_t, 0.0))
                ex._stepper.requeue_or_fail(e.req, e.attempt, e.mark_t,
                                            e.end_t, alloc, fatal=True)
                del inflight[e.wid]
                continue
            ex._complete(e.req, EvalResult(
                task_id=e.req.task_id, value=[[0.0]], status="ok",
                worker=e.wname, attempts=e.attempt,
                submit_t=e.req.submit_t, dispatch_t=e.mark_t,
                start_t=e.start_t, end_t=e.end_t,
                compute_t=e.compute, init_t=e.init))
            del inflight[e.wid]

        # ---- lifecycle: the executor's own stepper adapter ------------
        ex._cluster_step()
        # workers the stepper (or a chaos handler, or a lost hedge race)
        # tore down took their in-flight tasks with them: drop the stale
        # slots; terminal accounting is recomputed below
        for wid in [wid for wid, e in inflight.items()
                    if not _slot_alive(e)]:
            del inflight[wid]

        # ---- speculative re-execution (the executor's own check, the
        # same shared ladder + capacity gate the sim runs) --------------
        if straggler_factor > 0.0:
            ex._straggler_check(now)
        n_final = n_terminal()

        # ---- dispatch (sim order: by allocation, then worker id) ------
        for w in sorted(ex.workers, key=lambda w: (w.alloc.alloc_id,
                                                   w.wid)):
            if w.wid in inflight or w.alloc.state != RUNNING:
                continue
            mine = warm.setdefault(w.wid, set())
            view = WorkerView(wid=w.wid, warm_models=frozenset(mine),
                              budget_left=w.alloc.budget_left(now),
                              alloc_id=w.alloc.alloc_id)
            with ex._cv:
                item = ex.policy.pop(view)
                while item is not None and \
                        ex._already_done(item[0].task_id):
                    item = ex.policy.pop(view)   # as Worker.run drops them
            if item is None:
                continue
            req, attempt = item
            ex._mark_running(req, w, attempt)
            if req.config.get("_surrogate"):
                compute = float(getattr(broker.surrogate, "latency_s",
                                        0.05))
                init = 0.0
                if hasattr(broker.surrogate, "note_served"):
                    broker.surrogate.note_served()
                wname = f"{w.name}-surrogate"
            else:
                compute = runtimes[req.task_id]
                if inj is not None:
                    compute *= inj.slow_factor(w.wid, now)
                init = 0.0 if req.model_name in mine else spec.server_init
                mine.add(req.model_name)
                wname = w.name
            start = now + spec.dispatch_latency
            inflight[w.wid] = _Inflight(
                wid=w.wid, req=req, attempt=attempt, mark_t=now,
                start_t=start, end_t=start + init + compute,
                init=init, compute=compute, wname=wname)

    # ---- wind down (mirrors the sim's) --------------------------------
    end = max((r.end_t for r in ex._results.values()), default=now)
    with ex._cv:
        ex._stepper.release(end)
    records = ex.records()
    fill_lost(records, reqs, end, tracer)
    alloc_records = sorted((a.record() for a in ex._retired_allocs),
                           key=lambda r: r.alloc_id)
    decisions = (list(allocator.decisions) if allocator is not None
                 else [])
    events = list(ex._stepper.events)
    ex.shutdown()
    attribution = None
    if tracer is not None:
        from repro.obs.attribution import attribute_overhead
        attribution = attribute_overhead(tracer.events())
    return ClusterResult(records=records, allocations=alloc_records,
                         decisions=decisions, events=events,
                         overhead_attribution=attribution)


def _never_called():
    raise AssertionError("replay_live plays the workers itself: no model "
                         "server is ever instantiated")


# ---------------------------------------------------------------------------
# the differential check
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ParityReport:
    sim: ClusterResult
    live: ClusterResult
    divergences: List[str]

    @property
    def ok(self) -> bool:
        return not self.divergences


def _close(a: float, b: float, tol: float) -> bool:
    if math.isnan(a) and math.isnan(b):
        return True
    return math.isclose(a, b, rel_tol=tol, abs_tol=tol)


def compare_results(sim: ClusterResult, live: ClusterResult,
                    tol: float = 1e-9) -> List[str]:
    """Diff two `ClusterResult`s on everything that must agree.  Worker
    name strings are the drivers' own (thread names vs sim labels) and
    are deliberately not compared — except for terminal 'failed' records,
    whose canonical shape pins the worker to ``alloc<id>``."""
    out: List[str] = []

    sim_by = {r.task_id: r for r in sim.records}
    live_by = {r.task_id: r for r in live.records}
    if set(sim_by) != set(live_by):
        out.append(f"task sets differ: sim-only="
                   f"{sorted(set(sim_by) - set(live_by))}, live-only="
                   f"{sorted(set(live_by) - set(sim_by))}")
    for tid in sorted(set(sim_by) & set(live_by)):
        s, l = sim_by[tid], live_by[tid]
        if s.status != l.status or s.attempts != l.attempts:
            out.append(f"{tid}: status/attempts sim=({s.status},"
                       f"{s.attempts}) live=({l.status},{l.attempts})")
            continue
        for f in ("submit_t", "start_t", "end_t", "cpu_time", "compute_t"):
            if not _close(getattr(s, f), getattr(l, f), tol):
                out.append(f"{tid}: {f} sim={getattr(s, f)} "
                           f"live={getattr(l, f)}")
        if s.status in ("failed", "quarantined"):
            for r, side in ((s, "sim"), (l, "live")):
                if r.start_t != r.end_t or r.cpu_time != 0.0 \
                        or not r.worker.startswith("alloc"):
                    out.append(f"{tid}: non-canonical killed record "
                               f"({side}): {r}")

    if [e[1:] for e in sim.events] != [e[1:] for e in live.events] or \
            not all(_close(a[0], b[0], tol)
                    for a, b in zip(sim.events, live.events)):
        out.append(f"stepper events differ:\n  sim ={sim.events}\n"
                   f"  live={live.events}")

    if len(sim.decisions) != len(live.decisions):
        out.append(f"decision counts differ: sim={len(sim.decisions)} "
                   f"live={len(live.decisions)}")
    else:
        for i, (ds, dl) in enumerate(zip(sim.decisions, live.decisions)):
            if ds["action"] != dl["action"] \
                    or ds["alloc_id"] != dl["alloc_id"] \
                    or not _close(ds["t"], dl["t"], tol) \
                    or not _close(ds["backlog_per_worker_s"],
                                  dl["backlog_per_worker_s"], tol):
                out.append(f"decision {i} differs: sim={ds} live={dl}")

    sim_allocs = {a.alloc_id: a for a in sim.allocations}
    live_allocs = {a.alloc_id: a for a in live.allocations}
    if set(sim_allocs) != set(live_allocs):
        out.append(f"allocation id sets differ: sim={sorted(sim_allocs)} "
                   f"live={sorted(live_allocs)}")
    for aid in sorted(set(sim_allocs) & set(live_allocs)):
        s, l = sim_allocs[aid], live_allocs[aid]
        if s.n_workers != l.n_workers or s.state != l.state:
            out.append(f"alloc {aid}: shape sim=({s.n_workers},{s.state}) "
                       f"live=({l.n_workers},{l.state})")
        for f in ("submit_t", "start_t", "end_t", "queue_wait", "busy_t"):
            if not _close(getattr(s, f), getattr(l, f), tol):
                out.append(f"alloc {aid}: {f} sim={getattr(s, f)} "
                           f"live={getattr(l, f)}")
        if not _close(s.node_seconds, l.node_seconds, tol):
            out.append(f"alloc {aid}: node_seconds sim={s.node_seconds} "
                       f"live={l.node_seconds}")
    return out


def run_parity(spec: BackendSpec, trace: List[TraceTask], *,
               policy: Any = "fcfs",
               autoalloc: Optional[AutoAllocConfig] = None,
               n_workers: int = 4,
               walltime_s: Optional[float] = None,
               max_workers: Optional[int] = None,
               seed: int = 0, tick_s: float = 5.0,
               max_attempts: int = 3,
               surrogate_factory: Any = None,
               fault_plan: Any = None,
               retry_policy: Any = None,
               straggler_factor: float = 0.0,
               straggler_min_completed: int = 5,
               tol: float = 1e-9,
               tracers: Optional[tuple] = None) -> ParityReport:
    """One differential run: same trace, same config, both drivers.

    Fresh-but-identical Broker/AutoAllocator instances are built per
    side (the objects are stateful, so they cannot literally be shared
    across two runs); in static mode the sim broker is seeded with a
    zero-queue-wait allocation matching the executor's initial group.

    ``tracers=(sim_tracer, live_tracer)`` attaches one `repro.obs.Tracer`
    per driver; both run on the virtual clock, so
    `span_sequence(sim_tracer) == span_sequence(live_tracer)` on a
    parity-clean trace — the observability layer inherits the
    no-forked-logic guarantee.
    """
    def make_broker():
        b = Broker(policy=policy)
        if surrogate_factory is not None:
            b.attach_surrogate(surrogate_factory())
        return b

    def make_allocator():
        if autoalloc is None:
            return None
        return AutoAllocator(autoalloc, spec=spec, seed=seed)

    kw = dict(seed=seed, tick_s=tick_s, max_attempts=max_attempts,
              max_workers=max_workers, walltime_s=walltime_s,
              n_workers=n_workers, fault_plan=fault_plan,
              retry_policy=retry_policy, straggler_factor=straggler_factor,
              straggler_min_completed=straggler_min_completed)
    sim_tracer, live_tracer = tracers if tracers is not None else (None,
                                                                   None)
    sim_broker = make_broker()
    if autoalloc is None:
        # match the live executor's initial group: granted at t=0 with
        # zero queue wait (thread startup, not a SLURM queue)
        init = Allocation(sim_broker.next_alloc_id(), n_workers,
                          walltime_s)
        init.submit(0.0, 0.0)
        sim_broker.add_allocation(init)
    sim = simulate_cluster(spec, trace, broker=sim_broker,
                           allocator=make_allocator(), tracer=sim_tracer,
                           **kw)
    live = replay_live(spec, trace, broker=make_broker(),
                       allocator=make_allocator(), tracer=live_tracer,
                       **kw)
    return ParityReport(sim=sim, live=live,
                        divergences=compare_results(sim, live, tol))
