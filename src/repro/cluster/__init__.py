"""Allocation lifecycle, auto-allocation, and multi-node brokered dispatch.

The elasticity layer the paper's HyperQueue setup relies on: bulk
allocations with a full lifecycle (`Allocation`), an autoallocator that
tracks backlog *cost* in seconds of queued work (`AutoAllocator`), and a
cluster-level broker holding one scheduling policy per allocation
(`Broker`, registered as ``policy="broker"``).  The same objects drive
the deterministic `simulate_cluster` discrete-event mode and the live
`Executor` (``Executor(..., autoalloc=AutoAllocConfig(...))``).
"""
from repro.cluster.allocation import (DRAINING, EXPIRED, PENDING, QUEUED,
                                      RUNNING, Allocation)
from repro.cluster.autoalloc import AutoAllocConfig, AutoAllocator
from repro.cluster.broker import Broker
from repro.cluster.sim import ClusterResult, simulate_cluster
from repro.cluster.traces import (TraceTask, bimodal_trace, bursty_trace,
                                  trace_span)
