"""Allocation lifecycle, auto-allocation, and multi-node brokered dispatch.

The elasticity layer the paper's HyperQueue setup relies on: bulk
allocations with a full lifecycle (`Allocation`), an autoallocator that
tracks backlog *cost* in seconds of queued work (`AutoAllocator`), and a
cluster-level broker holding one scheduling policy per allocation
(`Broker`, registered as ``policy="broker"``).  The same objects drive
the deterministic `simulate_cluster` discrete-event mode and the live
`Executor` (``Executor(..., autoalloc=AutoAllocConfig(...))``) — and the
allocation-lifecycle *rules* (capped grants, walltime kills, drained-dry
termination, autoalloc ordering) live once, in
`repro.cluster.stepper.LifecycleStepper`, with both drivers as thin
adapters.  `repro.cluster.parity` proves it differentially.
"""
from repro.cluster.allocation import (DRAINING, EXPIRED, PENDING, QUEUED,
                                      RUNNING, Allocation)
from repro.cluster.autoalloc import AutoAllocConfig, AutoAllocator
from repro.cluster.broker import Broker
from repro.cluster.sim import ClusterResult, simulate_cluster
from repro.cluster.stepper import LifecycleStepper
from repro.cluster.traces import (TraceTask, bimodal_trace, bursty_trace,
                                  trace_span, with_tenants)

# the parity harness imports repro.core.executor at module level (which
# imports repro.cluster only lazily, inside functions) — re-export it
# lazily so this package's import graph never depends on the executor
# module and the layering cannot go circular
_PARITY_EXPORTS = ("ParityReport", "VirtualClock", "compare_results",
                   "replay_live", "run_parity")


def __getattr__(name):
    if name in _PARITY_EXPORTS:
        from repro.cluster import parity
        return getattr(parity, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
