"""One lifecycle stepper for sim and live: the canonical per-tick rules.

Before this module the allocation-lifecycle *driving rules* — when a
QUEUED allocation's grant spawns workers, how the `max_workers` headroom
cap binds a grant (and cancels one that gets zero headroom), what happens
to tasks still running at walltime expiry, when a DRAINING allocation is
terminated, and when the autoallocator gets to decide — were implemented
twice: once in `simulate_cluster` and once in `Executor._cluster_step`.
They had diverged in at least three observable ways (autoalloc stepped
before vs after transitions, the capacity cap missing from the sim,
terminal kill-record shapes disagreeing).  The whole point of the
simulator is that its elasticity numbers transfer to the live executor,
so the rules now live HERE and nowhere else.

Canonical per-tick phase order (the driver owns phases in [brackets]):

    [arrivals]                 new requests enter the broker
    [completions]              finished tasks leave workers, bill busy_t
    ------------------- LifecycleStepper.step(now) -------------------
    transitions                Allocation.tick: QUEUED->RUNNING grants
                               (headroom-capped spawn, zero-headroom
                               grant cancellation) and walltime expiry
    walltime kill              expired groups: workers torn down, partial
                               busy billed, killed tasks requeued at
                               attempt+1 or terminally failed
    drained dry                DRAINING groups with zero busy workers are
                               terminated (node-seconds stop burning)
    autoalloc                  AutoAllocator.step sees POST-transition
                               capacity (the sim order; the live path
                               used to step it first)
    ------------------------------------------------------------------
    [dispatch]                 idle workers pop from the broker

The stepper is clock-agnostic and mechanism-agnostic: it owns the
*decisions* and their order, while the driver supplies the mechanism
through callbacks — `now` (virtual clock or `time.monotonic`),
`spawn_workers` (dict of sim workers or live threads), `retire_workers`
(tear a group down, returning the in-flight tasks that died with it),
`busy_count`/`worker_count` (occupancy views), and `record_failed` (the
driver's terminal-record sink).  `simulate_cluster` and the live
`Executor` are thin adapters over one instance each, so the two paths
cannot diverge again.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.cluster.allocation import (DRAINING, EXPIRED, QUEUED, RUNNING,
                                      Allocation)
from repro.obs.trace import RingBuffer

# (request, attempt, busy-since): one in-flight task killed with its group
KilledTask = Tuple[Any, int, float]

# (t, kind, alloc_id, n): kind in {"spawn", "kill", "drain-dry", "cancel"};
# n is workers spawned (spawn) or in-flight tasks killed (retirements)
StepperEvent = Tuple[float, str, int, int]


class LifecycleStepper:
    """The single allocation-lifecycle state machine shared by the
    discrete-event simulator and the live executor.

    Parameters
    ----------
    broker:        the `Broker` holding allocations and queues (requeues
                   of killed tasks go back through ``broker.push``).
    allocator:     optional `AutoAllocator`; stepped LAST, after every
                   state transition of the tick.
    now:           clock callback; ``step()`` uses it when no explicit
                   ``now`` is passed (the sim passes its event time).
    spawn_workers: bring up ``alloc.n_workers`` workers for a granted
                   allocation.
    retire_workers: tear down an allocation's workers; returns the killed
                   in-flight tasks as ``(request, attempt, busy_since)``.
                   The stepper bills their partial busy time and decides
                   requeue-vs-fail — the driver must do neither.
    busy_count:    ``{alloc_id: busy workers}`` (zero entries may be
                   omitted; the stepper zero-fills).
    worker_count:  real (non-virtual) workers currently up — the headroom
                   base for the `max_workers` cap.  Defaults to summing
                   ``n_workers`` over RUNNING/DRAINING real allocations.
    record_failed: sink for a terminally-failed killed task
                   ``(request, attempt, alloc, now)``; the canonical
                   record shape is `metrics.killed_task_record`.
    max_workers:   total real-worker ceiling (None = uncapped).  A grant
                   is resized down to the available headroom; a grant
                   with zero headroom is cancelled outright.
    max_attempts:  driver-wide attempt bound, combined with each
                   request's own ``max_attempts`` (None = request-level
                   bound only, the sim default).
    retired:       list retired allocations are appended to (the driver's
                   record store); a fresh list when omitted.
    tracer:        optional `repro.obs.Tracer` — the stepper is the one
                   choke point where allocation transitions, walltime
                   requeues/kills, and autoalloc actions happen, so one
                   set of spans/instants emitted here covers sim and
                   live identically.
    registry:      optional `repro.obs.MetricsRegistry`, sampled once
                   per `step` (queue depth, backlog cost, busy workers,
                   allocation counts, offload rate).
    events_cap:    audit-trail bound — `events` is a ring buffer so a
                   long-lived executor cannot grow it without limit.
    """

    def __init__(self, broker, allocator=None, *,
                 now: Callable[[], float],
                 spawn_workers: Callable[[Allocation], None],
                 retire_workers: Callable[[Allocation], List[KilledTask]],
                 busy_count: Callable[[], Dict[int, int]],
                 record_failed: Callable[[Any, int, Allocation, float], None],
                 worker_count: Optional[Callable[[], int]] = None,
                 max_workers: Optional[int] = None,
                 max_attempts: Optional[int] = None,
                 retired: Optional[List[Allocation]] = None,
                 tracer: Any = None, registry: Any = None,
                 calibration: Any = None,
                 on_tick: Optional[Callable[[float], None]] = None,
                 record_quarantined: Optional[
                     Callable[[Any, int, Allocation, float], None]] = None,
                 retry_seed: int = 0,
                 events_cap: int = 10_000):
        self.broker = broker
        self.allocator = allocator
        self.now = now
        self.spawn_workers = spawn_workers
        self.retire_workers = retire_workers
        self.busy_count = busy_count
        self.record_failed = record_failed
        self.worker_count = worker_count
        self.max_workers = max_workers
        self.max_attempts = max_attempts
        self.retired: List[Allocation] = retired if retired is not None \
            else []
        self.tracer = tracer
        self.registry = registry
        # optional repro.obs.calib.CalibrationMonitor: the grant is the
        # one place (shared by sim and live) where an allocation's drawn
        # queue wait becomes an observed fact, so residuals against the
        # spec's queue-wait model are fed from here
        self.calibration = calibration
        # end-of-tick hook: the one cadence point shared by sim and live
        # (`repro.service` hangs its journal snapshots here, so a
        # virtual-clock test and a wall-clock service checkpoint on the
        # same schedule).  Runs under the driver's dispatch lock.
        self.on_tick = on_tick
        # spawn/retire audit trail, bounded (oldest entries drop first;
        # `events.n_dropped` says how many a long run shed)
        self.events: RingBuffer = RingBuffer(events_cap)
        # -- hardened recovery (repro.chaos) ----------------------------
        # terminal sink for quarantined poison tasks; record_failed is
        # the fallback so legacy drivers need no new callback
        self.record_quarantined = record_quarantined
        # seed for RetryPolicy's deterministic backoff jitter — both
        # parity drivers must carry the same one
        self.retry_seed = int(retry_seed)
        # optional ChaosInjector, fired at the top of every step (set
        # post-hoc by the driver; None = fault-free)
        self.chaos = None
        # requeues released later than the kill (RetryPolicy backoff):
        # (release_t, seq, request, attempt), pushed back to the broker
        # by the first step at/after release_t.  The seq breaks ties in
        # arrival order, deterministically.
        self._deferred: List[Tuple[float, int, Any, int]] = []
        self._defer_seq = 0
        # fatal (worker-killing) failure counts per task, for quarantine
        self._fail_counts: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def step(self, now: Optional[float] = None) -> float:
        """One canonical tick: deferred-requeue release -> chaos faults ->
        transitions (grants + walltime kills) -> drained-dry termination
        -> autoalloc decisions."""
        if now is None:
            now = self.now()
        self._release_deferred(now)
        if self.chaos is not None:
            self.chaos.fire(now)
        self._transitions(now)
        self._drained_dry(now)
        sur = getattr(self.broker, "surrogate", None)
        if sur is not None and hasattr(sur, "tick_degraded"):
            sur.tick_degraded(now)         # outage/drift re-arm point
        if self.allocator is not None:
            actions = self.allocator.step(now, self.broker, self._busy())
            if self.tracer is not None and actions:
                for action, alloc in actions:
                    self.tracer.instant(
                        f"autoalloc.{action}", ts=now,
                        args={"alloc": alloc.alloc_id,
                              "n_workers": alloc.n_workers})
        if self.registry is not None:
            self.registry.sample_cluster(
                now, self.broker, sum(self.busy_count().values()))
        if self.on_tick is not None:
            self.on_tick(now)
        return now

    def release(self, now: float) -> None:
        """Driver wind-down: unregister every allocation still held (a
        still-QUEUED one is cancelled for 0 node-seconds, as scancel
        would) and keep them for the record."""
        for alloc in list(self.broker.allocations()):
            self.broker.remove_allocation(alloc.alloc_id, now)
            self.retired.append(alloc)

    # -- phases ---------------------------------------------------------
    def _transitions(self, now: float) -> None:
        for alloc in list(self.broker.allocations()):
            prev = alloc.state
            state = alloc.tick(now)
            if state != prev:
                # tick mutates allocation state outside the broker's own
                # methods; its cached allocation views must not go stale
                self.broker.invalidate_allocations()
                if self.tracer is not None:
                    self.tracer.alloc_state(alloc, ts=now)
            if prev == QUEUED and state == RUNNING:
                self._grant(alloc, now)
            elif prev in (RUNNING, DRAINING) and state == EXPIRED:
                self._retire(alloc, now, "kill")

    def _grant(self, alloc: Allocation, now: float) -> None:
        """Nodes granted: spawn the group, capped at the `max_workers`
        headroom.  Virtual (surrogate) allocations are not real capacity
        and are exempt.  A grant that gets zero headroom is cancelled —
        the autoallocator's own `worker_cap` normally prevents the
        submit, but a cap can tighten after submission."""
        if not alloc.virtual and self.max_workers is not None:
            headroom = max(self.max_workers - self._real_workers(alloc), 0)
            if headroom < alloc.n_workers:
                alloc.resize(headroom, now)
            if alloc.n_workers == 0:
                self._retire(alloc, now, "cancel")
                return
        if self.calibration is not None and not alloc.virtual:
            self.calibration.observe_queue_wait(alloc, now)
        self._event(now, "spawn", alloc.alloc_id, alloc.n_workers)
        self.spawn_workers(alloc)

    def _drained_dry(self, now: float) -> None:
        busy = self._busy()
        for alloc in list(self.broker.allocations()):
            if alloc.state == DRAINING and busy.get(alloc.alloc_id, 0) == 0:
                alloc.terminate(now)
                self._retire(alloc, now, "drain-dry")

    # -- retirement (the one walltime-kill / teardown rule) -------------
    def _retire(self, alloc: Allocation, now: float, kind: str) -> None:
        killed = self.retire_workers(alloc)
        for _req, _attempt, since in killed:
            alloc.note_busy(max(now - since, 0.0))   # partial work burned
        self._event(now, kind, alloc.alloc_id, len(killed))
        self.broker.remove_allocation(alloc.alloc_id, now)
        if self.tracer is not None:
            self.tracer.alloc_state(alloc, ts=now)   # terminal span
        self.retired.append(alloc)
        for req, attempt, since in killed:
            self.requeue_or_fail(req, attempt, since, now, alloc)

    # -- the one requeue-vs-quarantine-vs-fail rule ---------------------
    def requeue_or_fail(self, req, attempt: int, since: float, now: float,
                        alloc: Allocation, *, fatal: bool = False,
                        migrate: bool = False) -> str:
        """Route one killed in-flight attempt.  The caller has already
        billed the burned ``[since, now]`` interval to the allocation;
        this decides what happens to the TASK — requeue (immediately, or
        deferred by the request's `RetryPolicy` backoff), quarantine
        (``fatal=True`` failures — worker crashes, corrupted results —
        past ``quarantine_after``), or terminal failure when attempts are
        spent.  ``migrate=True`` (preemption-grace drain) requeues at the
        SAME attempt with no backoff: migration is not the task's fault.
        Returns the route taken ("requeued" | "quarantined" | "failed")."""
        retry = getattr(req, "retry", None)
        if fatal and retry is not None \
                and retry.quarantine_after is not None:
            n = self._fail_counts.get(req.task_id, 0) + 1
            self._fail_counts[req.task_id] = n
            if n >= retry.quarantine_after:
                if self.tracer is not None:
                    self.tracer.task_quarantined(req.task_id, attempt,
                                                 now, since)
                sink = self.record_quarantined or self.record_failed
                sink(req, attempt, alloc, now)
                return "quarantined"
        if migrate or attempt < self._attempt_limit(req):
            next_attempt = attempt if migrate else attempt + 1
            release = now
            if retry is not None and not migrate:
                release = now + retry.backoff_s(req.task_id, attempt,
                                                seed=self.retry_seed)
            if self.tracer is not None:
                self.tracer.task_requeue(req.task_id, attempt, now, since,
                                         release=release)
            if release > now:
                self.defer_push(req, next_attempt, release)
            else:
                self.broker.push(req, next_attempt)
            return "requeued"
        if self.tracer is not None:
            self.tracer.task_killed(req.task_id, attempt, now, since)
        self.record_failed(req, attempt, alloc, now)
        return "failed"

    # -- deferred (backed-off) requeues ---------------------------------
    def defer_push(self, req, attempt: int, release: float) -> None:
        self._defer_seq += 1
        self._deferred.append((float(release), self._defer_seq, req,
                               attempt))

    def deferred_times(self) -> List[float]:
        """Pending release times — event-time candidates for the sim's
        next-event search (a release must land ON an event time or the
        requeue timestamp drifts off the parity trace)."""
        return [d[0] for d in self._deferred]

    def _release_deferred(self, now: float) -> None:
        if not self._deferred:
            return
        due = sorted(d for d in self._deferred if d[0] <= now)
        if not due:
            return
        self._deferred = [d for d in self._deferred if d[0] > now]
        for _release, _seq, req, attempt in due:
            self.broker.push(req, attempt)

    def _event(self, now: float, kind: str, alloc_id: int, n: int) -> None:
        self.events.append((now, kind, alloc_id, n))
        if self.tracer is not None:
            self.tracer.instant(f"alloc.{kind}", ts=now, pid=alloc_id + 1,
                                args={"alloc": alloc_id, "n": n})

    # -- views -----------------------------------------------------------
    def _attempt_limit(self, req) -> int:
        if self.max_attempts is None:
            return req.max_attempts
        return min(req.max_attempts, self.max_attempts)

    def _real_workers(self, granting: Allocation) -> int:
        """Headroom base at grant time: the granted group's own workers
        are not up yet, so it never counts against itself."""
        if self.worker_count is not None:
            return self.worker_count()
        return sum(a.n_workers for a in self.broker.allocations()
                   if a is not granting and not a.virtual
                   and a.state in (RUNNING, DRAINING))

    def _busy(self) -> Dict[int, int]:
        busy = {a.alloc_id: 0 for a in self.broker.allocations()}
        busy.update(self.busy_count())
        return busy
