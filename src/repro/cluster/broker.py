"""Multi-node brokered dispatch: one scheduling policy per allocation.

The ROADMAP's multi-node follow-on to `repro.sched`: where
`WorkStealingPolicy` keeps an affinity map from model to *worker*, the
`Broker` generalises it to the cluster level — one `SchedulingPolicy`
instance per allocation (node group), a routing policy between them, and
migration of queued tasks off draining allocations.

The Broker IS a `SchedulingPolicy` (push/pop/pending/len), so it slots
into every dispatch layer unchanged: the live `Executor` uses it as its
queue (workers carry their `alloc_id` in the `WorkerView`), and the
deterministic `simulate_cluster` loop drives the same object on a
virtual clock — in both cases with allocation lifecycle transitions
applied by the shared `repro.cluster.stepper.LifecycleStepper`.
Registered as ``policy="broker"`` for name-based config.

Routing, in order:
  1. model affinity — an open allocation that has run this model before
     holds warm servers for it (the cluster-level warm-start the paper's
     ~1 s per-job server init makes worth chasing);
  2. least-loaded — the open allocation with the fewest queued tasks
     per worker (O(1) by design: routing runs under the dispatch lock);
  3. nowhere — no open allocation: the task parks in an unrouted buffer
     that flushes the moment capacity appears (autoalloc bootstrap).

Pops serve the worker's own allocation queue first; an idle worker then
*steals* from the most backlogged other allocation, moving the model's
affinity with the stolen task (exactly the single-node stealing rule,
lifted one level).
"""
from __future__ import annotations

import itertools
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.cluster.allocation import RUNNING, Allocation
from repro.sched.policy import QueueItem, SchedulingPolicy, WorkerView
from repro.sched.registry import make_policy, register_policy


@register_policy("broker")
class Broker(SchedulingPolicy):
    """Cluster-level queue: allocations, per-allocation policies, routing.

    `policy` names the per-allocation scheduling policy (any registered
    name, or a zero-arg factory returning a fresh instance); every
    sub-policy shares the broker's predictor, so online cost estimates
    sharpen cluster-wide.
    """

    name = "broker"

    def __init__(self, predictor=None, policy: Any = "fcfs",
                 surrogate: Any = None):
        super().__init__(predictor)
        if isinstance(policy, SchedulingPolicy):
            raise TypeError(
                "Broker needs one policy PER allocation: pass a registered "
                "name or a zero-arg factory, not a shared instance")
        if policy == "broker":
            raise TypeError(
                "a Broker's per-allocation policy cannot itself be a "
                "broker — tasks would route into the inner broker's "
                "unrouted buffer and never pop")
        self._sub_spec = policy
        self._allocs: Dict[int, Allocation] = {}
        self._queues: Dict[int, SchedulingPolicy] = {}
        self._affinity: Dict[str, int] = {}        # model -> alloc_id
        self._unrouted: Deque[QueueItem] = deque()
        self._ids = itertools.count()
        # allocations()/_open_ids() run on EVERY routing decision, pop
        # and autoalloc probe; their sorts/filters are cached behind an
        # epoch counter bumped whenever the allocation table or any
        # open-ness-changing state transition goes through the broker
        # (the stepper reports its out-of-band `tick` transitions via
        # `invalidate_allocations`)
        self._alloc_epoch = 0
        self._sorted_cache: List[Allocation] = []
        self._sorted_epoch = -1
        self._open_cache: List[int] = []
        self._open_epoch = -1
        # incremental backlog-cost ledger: every enqueue/dequeue adjusts
        # the running total in O(1); a full rebuild happens only when the
        # predictor's version token changes
        self.default_cost = 1.0
        self._item_costs: Dict[Tuple[str, int], Tuple[float, int]] = {}
        self._cost_total = 0.0
        self._cost_version: object = None
        # surrogate-offload routing (ROADMAP follow-on): the GP surrogate
        # is modelled as a zero-queue-wait VIRTUAL allocation so the
        # drivers (simulate_cluster, live Executor) bring up its server
        # through the ordinary allocation lifecycle
        self.surrogate = None
        self._surrogate_id: Optional[int] = None
        # optional repro.obs.Tracer (set via set_tracer): queue-entry,
        # steal and migration instants + allocation lifecycle spans are
        # emitted HERE, the one code path both drivers share
        self.tracer = None
        if surrogate is not None:
            self.attach_surrogate(surrogate)

    # -- construction helpers -------------------------------------------
    def _make_queue(self) -> SchedulingPolicy:
        if callable(self._sub_spec) and not isinstance(self._sub_spec, str):
            q = self._sub_spec().bind(self.predictor)
        else:
            q = make_policy(self._sub_spec, self.predictor)
        if isinstance(q, Broker):              # factories can sneak one in
            raise TypeError("per-allocation policy cannot be a broker")
        return q

    def bind(self, predictor) -> "Broker":
        super().bind(predictor)
        for q in self._queues.values():
            q.bind(self.predictor)
        return self

    def set_tracer(self, tracer) -> "Broker":
        """Attach a `repro.obs.Tracer`.  Allocations registered BEFORE
        the tracer arrived (the parity harness pre-seeds the sim broker
        with the executor's initial group) retro-emit their lifecycle
        spans from their own timestamp fields, so a late-attached tracer
        produces the same allocation span sequence as an early one."""
        self.tracer = tracer
        if self.surrogate is not None:
            self.surrogate.tracer = tracer
        if tracer is not None:
            for a in self.allocations():
                tracer.alloc_state(a)
        return self

    def attach_surrogate(self, offload) -> Allocation:
        """Register a `repro.sched.offload.SurrogateOffload` as a virtual
        allocation: zero queue wait (submitted at t=0, granted on the
        first tick), unbounded walltime, zero node-second billing.  Tasks
        the engine trusts are routed to its private queue; the owning
        driver spawns its (virtual) workers exactly as for any other
        allocation — no forked lifecycle code."""
        if self.surrogate is not None:
            raise ValueError("a surrogate is already attached")
        self.surrogate = offload
        alloc = Allocation(self.next_alloc_id(),
                           getattr(offload, "n_virtual_workers", 1),
                           None, virtual=True)
        alloc.submit(0.0, 0.0)                 # zero-queue-wait by design
        self._surrogate_id = alloc.alloc_id
        self._allocs[alloc.alloc_id] = alloc
        self._queues[alloc.alloc_id] = make_policy("fcfs", self.predictor)
        self.invalidate_allocations()
        if self.tracer is not None:
            offload.tracer = self.tracer
            self.tracer.alloc_state(alloc)
        return alloc

    def _surrogate_open(self) -> bool:
        sid = self._surrogate_id
        return (self.surrogate is not None and sid in self._allocs
                and self._allocs[sid].open)

    # -- allocation management ------------------------------------------
    def next_alloc_id(self) -> int:
        return next(self._ids)

    def invalidate_allocations(self) -> None:
        """Drop the cached allocation views.  Callers that change an
        allocation's routability OUTSIDE the broker's own methods — the
        stepper's `Allocation.tick` transitions, a manual `drain`/
        `terminate` — must call this; add/drain/remove on the broker bump
        the epoch themselves."""
        self._alloc_epoch += 1

    def allocations(self) -> List[Allocation]:
        """All registered allocations, sorted by id.  Cached between
        allocation-table changes (routing and autoalloc probes ask on
        every decision) — treat the returned list as read-only."""
        if self._sorted_epoch != self._alloc_epoch:
            self._sorted_cache = sorted(self._allocs.values(),
                                        key=lambda a: a.alloc_id)
            self._sorted_epoch = self._alloc_epoch
        return self._sorted_cache

    def allocation(self, alloc_id: int) -> Optional[Allocation]:
        return self._allocs.get(alloc_id)

    def add_allocation(self, alloc: Allocation) -> Allocation:
        self._allocs[alloc.alloc_id] = alloc
        self._queues[alloc.alloc_id] = self._make_queue()
        self.invalidate_allocations()
        if self.tracer is not None:
            self.tracer.alloc_state(alloc)
        self._flush_unrouted()
        return alloc

    def drain_allocation(self, alloc_id: int, now: float) -> None:
        """No new tasks; migrate its queued work to the rest of the
        cluster (running tasks are the owner's problem — the executor /
        simulator terminates the group once they finish)."""
        alloc = self._allocs.get(alloc_id)
        if alloc is None:
            return
        alloc.drain(now)
        self.invalidate_allocations()
        if self.tracer is not None:
            self.tracer.alloc_state(alloc, ts=now)
        self._migrate_off(alloc_id)

    def remove_allocation(self, alloc_id: int, now: float) -> None:
        """Allocation expired or was torn down: migrate queued tasks and
        forget it (warm-server affinities die with the node group)."""
        alloc = self._allocs.get(alloc_id)
        if alloc is None:
            return
        alloc.terminate(now)
        self.invalidate_allocations()          # closed before migration...
        if self.tracer is not None:
            self.tracer.alloc_state(alloc, ts=now)
        self._migrate_off(alloc_id)
        self._queues.pop(alloc_id, None)
        del self._allocs[alloc_id]             # caller keeps it for records
        self.invalidate_allocations()          # ...gone after it

    def _migrate_off(self, alloc_id: int) -> None:
        q = self._queues.get(alloc_id)
        self._affinity = {m: a for m, a in self._affinity.items()
                          if a != alloc_id}
        if q is None:
            return
        items = []
        item = q.pop()
        while item is not None:
            items.append(item)
            item = q.pop()
        for req, attempt in items:
            if self.tracer is not None:
                # a migrated task is the SAME queue entry rerouted — no
                # fresh task.queued instant, its wait keeps accruing
                self.tracer.instant("task.migrate",
                                    args={"task": req.task_id,
                                          "from": alloc_id})
            self._note_dequeue(req, attempt)   # re-enters via _route_push
            self._route_push(req, attempt)

    # -- routing ---------------------------------------------------------
    def _open_ids(self) -> List[int]:
        """Open REAL allocations — the virtual surrogate allocation is
        never a routing / stealing / least-loaded target; tasks reach it
        only through the offload decision.  Cached with `allocations()`
        behind the epoch counter: routing consults this on every push."""
        if self._open_epoch != self._alloc_epoch:
            self._open_cache = [a.alloc_id for a in self.allocations()
                                if a.open and not a.virtual]
            self._open_epoch = self._alloc_epoch
        return self._open_cache

    def _load(self, alloc_id: int) -> float:
        """Queued tasks per worker — O(1), deliberately NOT cost-based:
        routing and stealing run on every push / idle-worker poll under
        the dispatch lock, where an O(pending) predictor sweep would
        stall dispatch (backlog_cost caches for the same reason)."""
        q = self._queues.get(alloc_id)
        if q is None:
            return 0.0
        return len(q) / max(self._allocs[alloc_id].n_workers, 1)

    def _route(self, req) -> Optional[int]:
        open_ids = self._open_ids()
        if not open_ids:
            return None
        aff = self._affinity.get(req.model_name)
        if aff is not None and aff in open_ids:
            return aff
        chosen = min(open_ids, key=lambda i: (self._load(i), i))
        self._affinity.setdefault(req.model_name, chosen)
        return chosen

    def _route_push(self, req, attempt: int) -> None:
        # surrogate offload first: a trusted task never queues for real
        # capacity.  Its (predicted) cost is deliberately kept OUT of the
        # backlog ledger — the autoallocator must not size real node
        # groups for work the surrogate serves in milliseconds.  The cost
        # (possibly a GP inference) is computed ONCE and reused by the
        # ledger: push runs under the dispatch lock.
        cost = self.cost(req)
        if self._surrogate_open() and self.surrogate.decide(req, cost=cost):
            self._queues[self._surrogate_id].push(req, attempt)
            return
        self._note_enqueue(req, attempt, cost=cost)
        target = self._route(req)
        if target is None:
            self._unrouted.append((req, attempt))
        else:
            self._queues[target].push(req, attempt)

    def _flush_unrouted(self) -> None:
        if not self._unrouted or not self._open_ids():
            return
        items, self._unrouted = list(self._unrouted), deque()
        for req, attempt in items:
            self._note_dequeue(req, attempt)   # re-enters via _route_push
            self._route_push(req, attempt)

    # -- SchedulingPolicy protocol ---------------------------------------
    def push(self, req, attempt: int) -> None:
        if self.tracer is not None:
            self.tracer.task_queued(req.task_id, attempt, req=req)
        self._route_push(req, attempt)

    def pop(self, worker: Optional[WorkerView] = None
            ) -> Optional[QueueItem]:
        item = self._pop_inner(worker)
        if item is not None:
            self._note_dequeue(item[0], item[1])
        return item

    def _pop_inner(self, worker: Optional[WorkerView]
                   ) -> Optional[QueueItem]:
        self._flush_unrouted()
        if worker is None or worker.alloc_id is None:
            # anonymous consumer (snapshot draining, legacy pools): any
            # task — surrogate queue first, it is milliseconds of work
            if self._surrogate_id is not None and \
                    self._surrogate_id in self._queues:
                item = self._queues[self._surrogate_id].pop()
                if item is not None:
                    return item
            for i in self._open_ids():
                item = self._queues[i].pop()
                if item is not None:
                    return item
            return self._unrouted.popleft() if self._unrouted else None
        alloc = self._allocs.get(worker.alloc_id)
        if alloc is None or alloc.state != RUNNING:
            return None                        # draining/expired: no new work
        item = self._queues[worker.alloc_id].pop(worker)
        if item is not None:
            return item
        return self._steal(worker)

    def _steal(self, worker: WorkerView) -> Optional[QueueItem]:
        thief = self._allocs.get(worker.alloc_id)
        if thief is not None and thief.virtual:
            return None                        # surrogate serves only its own
        victims = [i for i in self._open_ids() if i != worker.alloc_id
                   and len(self._queues[i])]
        if not victims:
            return None
        victim = max(victims, key=lambda i: (self._load(i), -i))
        item = self._queues[victim].pop()
        if item is None:
            return None
        req, attempt = item
        self._affinity[req.model_name] = worker.alloc_id
        if self.tracer is not None:
            self.tracer.instant("task.steal",
                                args={"task": req.task_id,
                                      "from": victim,
                                      "to": worker.alloc_id})
        return req, attempt

    def pending(self) -> List[QueueItem]:
        out: List[QueueItem] = list(self._unrouted)
        for i in sorted(self._queues):
            out.extend(self._queues[i].pending())
        return out

    def __len__(self) -> int:
        return len(self._unrouted) + sum(len(q)
                                         for q in self._queues.values())

    def remove_worker(self, wid: int) -> None:
        for q in self._queues.values():
            q.remove_worker(wid)

    # -- autoalloc instrumentation ---------------------------------------
    def queued_on(self, alloc_id: int) -> int:
        q = self._queues.get(alloc_id)
        return len(q) if q is not None else 0

    def backlog_count(self) -> int:
        """Queued tasks waiting for REAL capacity (the surrogate's
        private queue is excluded, exactly as `backlog_cost` excludes its
        costs) — the count the legacy count-based autoscale trigger
        should scale on."""
        n = len(self)
        if self._surrogate_id is not None:
            n -= self.queued_on(self._surrogate_id)
        return n

    def tenant_backlogs(self) -> Dict[str, int]:
        """Queued tasks per tenant, summed across every real
        per-allocation queue plus the unrouted buffer.  Empty when no
        per-allocation policy is tenant-aware (i.e. anything but
        "fairshare") — per-tenant gauges then simply don't exist, so the
        single-tenant observability surface is unchanged."""
        out: Dict[str, int] = {}
        aware = False
        for i in sorted(self._queues):
            if i == self._surrogate_id:
                continue
            fn = getattr(self._queues[i], "tenant_pending_all", None)
            if callable(fn):
                aware = True
                for tenant, n in fn().items():
                    out[tenant] = out.get(tenant, 0) + n
        if aware:
            for req, _ in self._unrouted:
                tenant = getattr(req, "tenant", "") or "default"
                out[tenant] = out.get(tenant, 0) + 1
        return out

    def backlog_cost(self, default: float = 1.0) -> float:
        """Total queued seconds of work cluster-wide (predictor estimate,
        else time_request hint, else `default` per task) — the signal the
        `AutoAllocator` scales on.

        Maintained incrementally (the executor's monitor asks every 50 ms
        under the dispatch lock, where an O(queue) sweep of GP predictions
        would stall dispatch); the only O(queue) rebuild is when the
        predictor version token changes — the GP bumps it on posterior
        installs, not on every observation."""
        self.default_cost = default
        v = self._predictor_version()
        if v != self._cost_version:
            self._cost_version = v
            self._item_costs = {}
            self._cost_total = 0.0
            # rebuild over REAL queues only: surrogate-routed work is
            # never in the ledger (see _route_push)
            items: List[QueueItem] = list(self._unrouted)
            for i in sorted(self._queues):
                if i != self._surrogate_id:
                    items.extend(self._queues[i].pending())
            for req, attempt in items:
                self._note_enqueue(req, attempt)
        return max(self._cost_total, 0.0)

    def _note_enqueue(self, req, attempt: int,
                      cost: Optional[float] = None) -> None:
        key = (req.task_id, attempt)
        entry = self._item_costs.get(key)
        if entry is not None:                  # duplicate copy: reuse cost
            c, n = entry
            self._item_costs[key] = (c, n + 1)
        else:
            c = (cost if cost is not None else self.cost(req)) \
                or self.default_cost
            self._item_costs[key] = (c, 1)
        self._cost_total += c

    def _note_dequeue(self, req, attempt: int) -> None:
        entry = self._item_costs.get((req.task_id, attempt))
        if entry is None:
            return
        c, n = entry
        self._cost_total -= c
        if n <= 1:
            del self._item_costs[(req.task_id, attempt)]
        else:
            self._item_costs[(req.task_id, attempt)] = (c, n - 1)
