"""Seeded arrival traces for elasticity experiments.

The paper's benchmarks submit everything up front; elasticity only
matters when demand *varies*, so `simulate_cluster` is exercised against
arrival traces instead: tasks arrive over virtual time, and the
autoallocator must track the load without burning node-seconds through
the quiet stretches.  Everything is seeded — same seed, same trace.

  * `bursty_trace`   — bursts of near-simultaneous arrivals separated by
                       long idle gaps (campaign-style UQ usage: a user
                       fires a sweep, studies the results, fires again).
  * `bimodal_trace`  — a Poisson-ish arrival stream whose runtimes mix a
                       cheap majority with an expensive minority (the
                       GS2 "minutes to hours" spread collapsed to two
                       modes, as in `benchmarks/policy_comparison.py`).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceTask:
    """One arrival: when it lands, what it costs, what model serves it."""
    t: float                         # arrival time (virtual seconds)
    runtime: float                   # true compute seconds
    model_name: str = "model"
    time_request: Optional[float] = None   # HQ-style hint (None = unknown)
    n_cpus: int = 1
    # the task's physics input theta (UM-Bridge [[...]] shape); None keeps
    # the synthetic per-index payload `simulate_cluster` generates.  Real
    # parameters are what runtime predictors and the surrogate-offload
    # trust gate discriminate on.
    parameters: Optional[List[List[float]]] = None
    # owning tenant; "default" keeps single-tenant traces unchanged
    tenant: str = "default"


def with_tenants(trace: List[TraceTask],
                 weights: "dict[str, float]") -> List[TraceTask]:
    """Assign tenants to a trace so each tenant's task *count* is
    proportional to its weight (D'Hondt divisor rounding, interleaved).

    Under exact weighted fair sharing of equal-cost tasks, tenants loaded
    proportionally to their weights all drain together — the saturating
    shape the fairness benchmarks measure shares on.  Deterministic: same
    trace + same weights -> same assignment (ties break on tenant name).
    """
    if not weights:
        return list(trace)
    names = sorted(weights)
    for t in names:
        if weights[t] <= 0:
            raise ValueError(f"tenant weight must be > 0: {t}={weights[t]}")
    counts = {t: 0 for t in names}
    out: List[TraceTask] = []
    for tt in trace:
        t = max(names, key=lambda n: (weights[n] / (counts[n] + 1), n))
        counts[t] += 1
        out.append(dataclasses.replace(tt, tenant=t))
    return out


def bursty_trace(n_bursts: int = 4, burst_size: int = 24,
                 gap_s: float = 600.0, burst_span_s: float = 10.0,
                 runtime_s: float = 20.0, jitter: float = 0.1,
                 hints: bool = True, seed: int = 0) -> List[TraceTask]:
    """`n_bursts` bursts of `burst_size` tasks each; within a burst,
    arrivals spread uniformly over `burst_span_s`; bursts start `gap_s`
    apart.  Runtimes are `runtime_s` with lognormal hardware jitter."""
    rng = np.random.default_rng(seed)
    out: List[TraceTask] = []
    for b in range(n_bursts):
        t0 = b * gap_s
        offsets = np.sort(rng.uniform(0.0, burst_span_s, size=burst_size))
        rts = runtime_s * np.exp(jitter * rng.standard_normal(burst_size))
        for off, rt in zip(offsets, rts):
            out.append(TraceTask(
                t=float(t0 + off), runtime=float(rt),
                model_name="burst-model",
                time_request=runtime_s if hints else None))
    return out


def bimodal_trace(n: int = 80, rate_per_s: float = 0.2,
                  short_s: float = 4.0, long_s: float = 60.0,
                  frac_long: float = 0.2, jitter: float = 0.05,
                  hints: bool = True, seed: int = 0) -> List[TraceTask]:
    """Exponential inter-arrivals at `rate_per_s`; a `frac_long` minority
    runs `long_s`, the rest `short_s` — two model names so per-model
    predictors and affinity routing have something to discriminate on."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_per_s, size=n))
    is_long = rng.uniform(size=n) < frac_long
    out: List[TraceTask] = []
    for t, lng in zip(arrivals, is_long):
        base = long_s if lng else short_s
        rt = base * float(np.exp(jitter * rng.standard_normal()))
        out.append(TraceTask(
            t=float(t), runtime=rt,
            model_name="long-model" if lng else "short-model",
            time_request=base if hints else None))
    return out


def trace_span(trace: List[TraceTask]) -> Tuple[float, float]:
    """(first arrival, last arrival) of a trace."""
    if not trace:
        return 0.0, 0.0
    return trace[0].t, max(task.t for task in trace)
