"""HQ-style auto-allocation driven by backlog *cost*, not task counts.

HyperQueue's autoalloc watches its task queue and submits/renews bulk
SLURM allocations so capacity tracks demand; the count-based grow-only
loop this replaces could neither shrink nor tell ten 1-second tasks from
ten 10-hour ones.  The `AutoAllocator` here measures backlog in *seconds
of queued work per worker* — predictor-estimated where a runtime
predictor is bound, falling back to each request's `time_request` hint,
falling back to `default_task_cost` — and applies three guards so the
allocation churn itself stays cheap:

  * hysteresis: high/low watermarks plus a minimum interval between
    scale decisions (no flapping on oscillating backlog);
  * a max-pending cap: never more than `max_pending` allocations waiting
    in the native scheduler's queue at once (HQ's backlog guard);
  * idle draining: an allocation whose workers have all been idle for
    `idle_drain_s` is drained — running tasks finish, queued work is
    migrated by the broker, and the node-seconds stop burning.

The allocator is pure decision logic over (now, broker state, busy map):
the SAME instance drives the deterministic `simulate_cluster` loop and
the live `Executor` monitor thread — no forked decision code.  Both
drivers invoke `step` through `repro.cluster.stepper.LifecycleStepper`,
which fixes its place in the tick: AFTER allocation state transitions,
so scaling decisions always see post-grant capacity (the live path once
stepped it first and sized against stale capacity).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.cluster.allocation import Allocation
from repro.cluster.broker import Broker


@dataclasses.dataclass
class AutoAllocConfig:
    """Knobs for the allocation policy (all times in seconds)."""
    workers_per_alloc: int = 1       # worker group size per allocation
    walltime_s: float = 600.0        # requested walltime per allocation
    n_cpus: int = 1                  # per-worker cores (queue-wait model)
    backlog_high_s: float = 30.0     # submit above this backlog/worker
    backlog_low_s: float = 5.0       # drain only below this backlog/worker
    max_pending: int = 2             # allocations queued in SLURM at once
    max_allocations: int = 8         # open (queued+running) cap
    min_allocations: int = 0         # never drain below this many
    idle_drain_s: float = 10.0       # full-idle time before draining
    hysteresis_s: float = 5.0        # min gap between scale decisions
    default_task_cost: float = 1.0   # backlog cost of a hint-less task
    # watermark semantics: True compares backlog seconds PER OPEN WORKER
    # (capacity-aware, the HQ-style default); False compares the total
    # queued seconds regardless of capacity — what the executor's legacy
    # count-based `autoscale_backlog` trigger did, kept for the alias
    per_worker: bool = True
    # True makes the watermark metric the queued-task COUNT, ignoring
    # cost estimates and hints entirely — the exact legacy trigger
    # (watermarks are then in tasks, not seconds)
    count_tasks: bool = False


class AutoAllocator:
    """Submits and drains allocations on a broker from backlog cost.

    `spec` (a `BackendSpec`) supplies the queue-wait overhead model for
    submitted allocations; None means grants are immediate — the right
    default for live thread pools, where "allocation" is worker-group
    startup.  All randomness comes from the seeded generator, so a given
    (seed, event sequence) always produces the same decisions.
    """

    def __init__(self, config: Optional[AutoAllocConfig] = None, *,
                 spec=None, seed: int = 0):
        self.config = config or AutoAllocConfig()
        self.spec = spec
        self.rng = np.random.default_rng(seed)
        self.decisions: List[Dict[str, Any]] = []   # audit trail (tests/bench)
        # total-worker ceiling across open allocations; the live executor
        # sets it to its max_workers so the grow branch stops firing at
        # the cap instead of churning submit-then-cancelled grants
        self.worker_cap: Optional[int] = None
        self._last_decision_t = -math.inf
        self._idle_since: Dict[int, float] = {}     # alloc_id -> idle start

    # ------------------------------------------------------------------
    def backlog_per_worker(self, broker: Broker) -> float:
        """The watermark metric: seconds of queued work per open-worker
        (the whole backlog if no capacity is open — that is what triggers
        bootstrap); raw totals under ``per_worker=False``; queued-task
        count (hints ignored) under ``count_tasks=True``."""
        cost = (float(broker.backlog_count()) if self.config.count_tasks
                else broker.backlog_cost(default=self.config.
                                         default_task_cost))
        if not self.config.per_worker:
            return cost
        # virtual (surrogate) allocations are not real capacity: scaling
        # decisions are about node groups that cost node-seconds
        capacity = sum(a.n_workers for a in broker.allocations()
                       if a.open and not a.virtual)
        return cost / max(capacity, 1)

    def _grow_headroom(self, broker: Broker) -> int:
        """Workers a new allocation may bring up (inf-ish without a cap)."""
        if self.worker_cap is None:
            return self.config.workers_per_alloc
        planned = sum(a.n_workers for a in broker.allocations()
                      if a.open and not a.virtual)
        return min(self.config.workers_per_alloc,
                   max(self.worker_cap - planned, 0))

    def submit(self, now: float, broker: Broker,
               walltime_s: Optional[float] = None,
               n_workers: Optional[int] = None) -> Allocation:
        """Create, queue-wait-price, and register one allocation."""
        cfg = self.config
        alloc = Allocation(broker.next_alloc_id(),
                           n_workers if n_workers is not None
                           else cfg.workers_per_alloc,
                           walltime_s if walltime_s is not None
                           else cfg.walltime_s)
        wait = (self.spec.draw_queue_wait(self.rng, alloc.walltime_s,
                                          cfg.n_cpus)
                if self.spec is not None else 0.0)
        alloc.submit(now, wait)
        broker.add_allocation(alloc)
        return alloc

    # ------------------------------------------------------------------
    def step(self, now: float, broker: Broker,
             busy_workers: Optional[Dict[int, int]] = None
             ) -> List[Tuple[str, Allocation]]:
        """One decision pass; returns the actions taken as
        ``[("submit", alloc), ("drain", alloc), ...]`` (usually 0 or 1).

        `busy_workers` maps alloc_id -> number of workers currently
        running a task (used for idle-drain detection); omitted means
        "assume busy" so nothing is drained blind.
        """
        cfg = self.config
        busy = busy_workers or {}
        actions: List[Tuple[str, Allocation]] = []
        # the virtual surrogate allocation is invisible to elasticity: it
        # must neither count against max_allocations nor be idle-drained
        allocs = [a for a in broker.allocations() if not a.virtual]
        open_allocs = [a for a in allocs if a.open]
        pending = [a for a in allocs if a.state == "queued"]
        backlog_s = self.backlog_per_worker(broker)

        # -- idle bookkeeping (runs every step, decisions or not) -------
        for a in open_allocs:
            if a.state == "running" and busy.get(a.alloc_id, None) == 0 \
                    and broker.queued_on(a.alloc_id) == 0:
                self._idle_since.setdefault(a.alloc_id, now)
            else:
                self._idle_since.pop(a.alloc_id, None)

        # -- bootstrap: any work, zero capacity -> submit regardless of
        # watermark (a cold cluster must not idle a backlog forever)
        if not open_allocs and broker.backlog_cost(
                default=cfg.default_task_cost) > 0 \
                and cfg.max_allocations > 0 \
                and self._grow_headroom(broker) > 0:
            alloc = self.submit(now, broker,
                                n_workers=self._grow_headroom(broker))
            self._note(now, "submit", alloc, backlog_s)
            actions.append(("submit", alloc))
            return actions

        if now - self._last_decision_t < cfg.hysteresis_s:
            return actions

        # -- grow: backlog over the high watermark ----------------------
        if backlog_s > cfg.backlog_high_s \
                and len(pending) < cfg.max_pending \
                and len(open_allocs) < cfg.max_allocations \
                and self._grow_headroom(broker) > 0:
            alloc = self.submit(now, broker,
                                n_workers=self._grow_headroom(broker))
            self._note(now, "submit", alloc, backlog_s)
            actions.append(("submit", alloc))
            return actions

        # -- shrink: drain one fully idle allocation --------------------
        if backlog_s < cfg.backlog_low_s \
                and len(open_allocs) > cfg.min_allocations:
            for a in sorted(open_allocs, key=lambda a: a.alloc_id,
                            reverse=True):    # newest first: LIFO shrink
                idle_t = self._idle_since.get(a.alloc_id)
                if idle_t is not None and now - idle_t >= cfg.idle_drain_s:
                    broker.drain_allocation(a.alloc_id, now)
                    self._idle_since.pop(a.alloc_id, None)
                    self._note(now, "drain", a, backlog_s)
                    actions.append(("drain", a))
                    break
        return actions

    def _note(self, now: float, action: str, alloc: Allocation,
              backlog_s: float) -> None:
        self._last_decision_t = now
        self.decisions.append({"t": now, "action": action,
                               "alloc_id": alloc.alloc_id,
                               "backlog_per_worker_s": backlog_s})
