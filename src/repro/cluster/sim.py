"""`simulate_cluster`: deterministic discrete-event elasticity runs.

Where `simulate` reproduces the paper's queue-depth submission model and
`simulate_policy` models this repo's executor on a fixed worker pool,
`simulate_cluster` models the full allocation lifecycle: tasks ARRIVE
over virtual time (seeded traces from `repro.cluster.traces`), a
`Broker` routes them between allocations, and an optional
`AutoAllocator` submits/drains bulk allocations as backlog cost moves —
the same Broker/AutoAllocator objects that drive the live `Executor`,
stepped on a virtual clock instead of `time.monotonic()`.

Semantics per allocation follow the HQ backend spec: one queue wait per
allocation (drawn from the `BackendSpec` overhead model), persistent
workers with warm model servers inside it, per-task `server_init` paid
once per (worker, model), ms-level dispatch.  Warm servers die with
their allocation; a task still running at walltime expiry is killed and
requeued (up to `max_attempts`), exactly the failure mode budget-aware
packing policies exist to avoid.

Everything is seeded end-to-end: same (trace, seed, config) -> identical
task records, allocation records, and autoalloc decisions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import numpy as np

from repro.chaos.inject import ChaosInjector
from repro.chaos.speculate import find_stragglers
from repro.cluster.allocation import DRAINING, QUEUED, RUNNING, Allocation
from repro.cluster.autoalloc import AutoAllocConfig, AutoAllocator
from repro.cluster.broker import Broker
from repro.cluster.stepper import LifecycleStepper, StepperEvent
from repro.cluster.traces import TraceTask
from repro.core import metrics as _metrics
from repro.core.backends import BackendSpec
from repro.core.metrics import (AllocationRecord, TaskRecord,
                                killed_task_record,
                                quarantined_task_record)
from repro.core.task import EvalRequest, RetryPolicy
from repro.obs.attribution import attribute_overhead
from repro.sched.policy import WorkerView
from repro.sched.registry import make_predictor


@dataclasses.dataclass
class ClusterResult:
    """Everything a seeded run produced (all deterministically ordered).

    `events` is the stepper's spawn/retire audit trail
    (``(t, kind, alloc_id, n)``) — what the differential parity suite
    compares between the sim and live paths."""
    records: List[TaskRecord]
    allocations: List[AllocationRecord]
    decisions: List[Dict[str, Any]]
    events: List[StepperEvent] = dataclasses.field(default_factory=list)
    # per-task overhead decomposition (repro.obs.attribute_overhead
    # output); populated only when the run was traced (``tracer=``)
    overhead_attribution: Optional[Dict[str, Any]] = None

    def summary(self) -> Dict[str, float]:
        done = [r for r in self.records if r.status == "ok"]
        return {
            "n_tasks": float(len(self.records)),
            "n_ok": float(len(done)),
            "makespan": _metrics.makespan(self.records),
            "node_seconds": _metrics.node_seconds(self.allocations),
            "utilization": _metrics.allocation_utilization(self.allocations),
            "n_allocations": float(len(self.allocations)),
        }


def trace_requests(trace: List[TraceTask], max_attempts: int,
                   retry: Any = None):
    """The one trace-to-request mapping both differential drivers use
    (`simulate_cluster` and `parity.replay_live`): time-sorted arrivals,
    task ids ``trace-<i>``, synthetic per-index payloads where the trace
    carries none, and ``submit_t`` pinned to the arrival time.  An
    optional `RetryPolicy` (or its dict form) is stamped on every
    request.  Returns ``(arrivals, requests, runtimes)``."""
    if isinstance(retry, dict):
        retry = RetryPolicy(**retry)
    arrivals = sorted(trace, key=lambda tt: (tt.t,))
    runtimes: Dict[str, float] = {}
    reqs: List[EvalRequest] = []
    for i, tt in enumerate(arrivals):
        req = EvalRequest(model_name=tt.model_name,
                          parameters=(tt.parameters
                                      if tt.parameters is not None
                                      else [[float(i)]]),
                          time_request=tt.time_request,
                          n_cpus=tt.n_cpus,
                          task_id=f"trace-{i}",
                          max_attempts=max_attempts,
                          tenant=getattr(tt, "tenant", "default"),
                          retry=retry)
        req.submit_t = tt.t        # after init: 0.0 must survive as-is
        runtimes[req.task_id] = tt.runtime
        reqs.append(req)
    return arrivals, reqs, runtimes


def next_event_time(arrivals, arr_i: int, busy_ends, broker,
                    elastic: bool, next_tick: float,
                    extra=()) -> Optional[float]:
    """The canonical next-event candidate set shared by both drivers:
    the next arrival, every in-flight completion, allocation grant and
    walltime-expiry times, and — while an allocator has anything left to
    react to — the autoalloc tick.  ``extra`` appends driver-supplied
    candidates (chaos fault fire times, deferred backoff releases): they
    must be event times or those instants drift off the parity trace.
    None means nothing can ever happen (the caller stops and surfaces
    unserved work as 'lost')."""
    candidates: List[float] = list(busy_ends)
    candidates.extend(extra)
    if arr_i < len(arrivals):
        candidates.append(arrivals[arr_i].t)
    for a in broker.allocations():
        if a.state == QUEUED:
            candidates.append(a.grant_t)
        elif a.state in (RUNNING, DRAINING) and math.isfinite(a.expiry_t):
            candidates.append(a.expiry_t)
    if elastic and (len(broker) or broker.allocations()
                    or arr_i < len(arrivals)):
        candidates.append(next_tick)
    return min(candidates) if candidates else None


def fill_lost(records: List[TaskRecord], reqs: List[EvalRequest],
              end: float, tracer: Any = None) -> None:
    """Tasks a run could never finish (e.g. a static pool whose only
    allocation expired with work still queued) MUST leave a record —
    silent loss would read as a smaller, fully-served workload."""
    finalized = {r.task_id for r in records}
    for req in reqs:
        if req.task_id not in finalized:
            records.append(TaskRecord(
                task_id=req.task_id, submit_t=req.submit_t,
                start_t=end, end_t=end, cpu_time=0.0, compute_t=0.0,
                worker="", attempts=0, status="lost"))
            if tracer is not None:
                tracer.task_lost(req.task_id, end)


class _SimWorker:
    __slots__ = ("wid", "alloc", "warm", "busy", "req", "attempt",
                 "mark_t", "start_t", "end_t", "compute", "init")

    def __init__(self, wid: int, alloc: Allocation):
        self.wid = wid
        self.alloc = alloc
        self.warm: set = set()
        self.busy = False
        self.req: Optional[EvalRequest] = None
        self.attempt = 1
        self.mark_t = 0.0    # dispatch decision time (busy-billing base)
        self.start_t = 0.0   # mark_t + dispatch latency
        self.end_t = 0.0
        self.compute = 0.0
        self.init = 0.0


def simulate_cluster(spec: BackendSpec, trace: List[TraceTask], *,
                     policy: Any = "fcfs", predictor: Any = None,
                     autoalloc: Any = None, broker: Optional[Broker] = None,
                     allocator: Optional[AutoAllocator] = None,
                     n_workers: int = 4,
                     walltime_s: Optional[float] = None,
                     max_workers: Optional[int] = None,
                     seed: int = 0, tick_s: float = 5.0,
                     max_attempts: int = 3,
                     max_t: float = 1e9,
                     tracer: Any = None,
                     registry: Any = None,
                     calibration: Any = None,
                     fault_plan: Any = None,
                     retry_policy: Any = None,
                     straggler_factor: float = 0.0,
                     straggler_min_completed: int = 5) -> ClusterResult:
    """Run one trace through brokered, allocation-backed dispatch.

    Two modes:
      * static (``autoalloc=None``): one allocation of `n_workers` for
        `walltime_s` (None = held until the run ends) — the fixed-pool
        baseline every elasticity comparison needs.  A broker that
        already carries a real allocation keeps it (the parity harness
        injects one matching the live executor's initial group);
      * elastic (``autoalloc=AutoAllocConfig(...)`` or an
        `AutoAllocator`): allocations are submitted and drained by the
        allocator; the run starts with zero capacity and bootstraps off
        the unrouted backlog.

    `max_workers` is the live executor's pool cap, enforced by the shared
    `LifecycleStepper` (grants resized to headroom, zero-headroom grants
    cancelled) and advertised to the allocator as its `worker_cap`; None
    (the default) leaves the sim uncapped and any caller-set `worker_cap`
    untouched.

    Pass `broker`/`allocator` instances to drive *the same objects* you
    later hand to a live `Executor` (the no-forked-logic guarantee).

    Trace replay: pass a `repro.obs.replay.ReplayBackendSpec` (built
    from a recorded trace) as ``spec`` and the replay's reconstructed
    trace as ``trace`` — queue waits pop from the recorded FIFO through
    `draw_queue_wait` and per-model cold-init costs come from
    ``spec.server_init_for`` (consulted here when the spec provides it),
    so a sim-recorded trace reproduces its original records exactly.
    ``calibration=`` accepts a `repro.obs.calib.CalibrationMonitor`:
    observed per-attempt overheads and granted queue waits are streamed
    into it for online drift detection, exactly as the live `Executor`
    does.

    Chaos & recovery (all seeded, all mirrored by `parity.replay_live`):
    ``fault_plan=`` takes a `repro.chaos.FaultPlan` whose events fire at
    the stepper choke point — worker crashes, allocation preemption with
    a grace-period drain (in-flight work migrates), slow-node compute
    degradation, result corruption, surrogate outages.  ``retry_policy=``
    stamps a `RetryPolicy` on every request: failed attempts requeue
    after deterministic exponential backoff (+ seeded jitter) and
    worker-killing failures quarantine the task after
    ``quarantine_after`` strikes.  ``straggler_factor>0`` arms
    speculative re-execution: when the queue is drained and idle
    capacity exists, tasks running past their model's p95 cutoff
    (`repro.chaos.find_stragglers`) are hedged on a spare worker —
    first completion wins, the loser is cancelled and its partial work
    billed to the allocation.
    """
    rng = np.random.default_rng(seed)
    if broker is None:
        broker = Broker(predictor=make_predictor(predictor), policy=policy)
    if allocator is None and autoalloc is not None:
        if isinstance(autoalloc, AutoAllocator):
            allocator = autoalloc              # same-objects contract
        else:
            if isinstance(autoalloc, AutoAllocConfig):
                cfg = autoalloc
            elif isinstance(autoalloc, dict):
                cfg = AutoAllocConfig(**autoalloc)
            else:
                raise TypeError(f"autoalloc= expects an AutoAllocConfig, "
                                f"dict, or AutoAllocator; got {autoalloc!r}")
            allocator = AutoAllocator(cfg, spec=spec, seed=seed)

    arrivals, reqs, runtimes = trace_requests(trace, max_attempts,
                                              retry_policy)

    now = 0.0
    if tracer is not None:
        # the tracer stamps with the virtual event time — the live
        # executor binds its own injected clock, so parity replays of
        # the same trace produce identical span timestamps
        tracer.bind_clock(lambda: now)
        # the spec's exact overhead constants, recorded so a replay of
        # this trace uses the same floats (span durs are endpoint
        # differences and lose the last ulp); parity.replay_live emits
        # the identical instant, keeping span sequences comparable
        tracer.instant("trace.spec", ts=0.0, args={
            "backend": spec.name,
            "dispatch_latency": float(spec.dispatch_latency),
            "server_init": float(spec.server_init),
            "queue_wait_sigma": float(spec.queue_wait_sigma)})
        broker.set_tracer(tracer)

    if allocator is None and not any(not a.virtual
                                     for a in broker.allocations()):
        static = Allocation(broker.next_alloc_id(), n_workers, walltime_s)
        request_s = static.walltime_s
        static.submit(0.0, spec.draw_queue_wait(rng, request_s))
        broker.add_allocation(static)
    if allocator is not None and max_workers is not None:
        allocator.worker_cap = max_workers     # live-executor semantics

    workers: Dict[int, _SimWorker] = {}
    wid_counter = 0
    records: List[TaskRecord] = []
    n_final = 0                                # tasks with a final record
    done_ids: set = set()                      # tasks with a terminal record
    real_done: List[tuple] = []                # (model, compute) of real oks
    arr_i = 0
    next_tick = 0.0
    retired: List[Allocation] = []             # keep records of removed allocs

    # dispatch scans workers in (alloc_id, wid) order on EVERY event;
    # the order only changes when a group spawns or retires, so the
    # sorted list is cached and rebuilt on membership changes instead of
    # re-sorted per event (O(W log W) off the inner loop)
    order_cache: List[_SimWorker] = []
    order_dirty = [True]

    def dispatch_order() -> List[_SimWorker]:
        if order_dirty[0]:
            order_cache[:] = sorted(workers.values(),
                                    key=lambda w: (w.alloc.alloc_id, w.wid))
            order_dirty[0] = False
        return order_cache

    # ---- stepper adapter: mechanism callbacks over the sim worker table
    def spawn_workers(alloc: Allocation):
        nonlocal wid_counter
        for _ in range(alloc.n_workers):
            workers[wid_counter] = _SimWorker(wid_counter, alloc)
            wid_counter += 1
        order_dirty[0] = True

    def retire_workers(alloc: Allocation):
        killed = []
        for w in sorted(list(workers.values()), key=lambda w: w.wid):
            if w.alloc is not alloc:
                continue
            if w.busy:
                killed.append((w.req, w.attempt, w.mark_t))
            broker.remove_worker(w.wid)
            del workers[w.wid]
        order_dirty[0] = True
        return killed

    def busy_count():
        busy: Dict[int, int] = {}
        for w in workers.values():
            if w.busy:
                busy[w.alloc.alloc_id] = busy.get(w.alloc.alloc_id, 0) + 1
        return busy

    def cancel_copies(task_id, t):
        # a task just reached a terminal state: any OTHER in-flight copy
        # (a speculative hedge, or the original of a hedge that lost) is
        # cancelled — its partial work bills to its allocation and the
        # hedge_cancel instant feeds conservation accounting
        for w in sorted((w for w in workers.values()
                         if w.busy and w.req.task_id == task_id),
                        key=lambda w: w.wid):
            w.alloc.note_busy(max(t - w.mark_t, 0.0))
            if tracer is not None:
                tracer.task_hedge_cancel(task_id, w.attempt, t, w.mark_t)
            w.busy, w.req = False, None

    def record_failed(req, attempt, alloc, t):
        nonlocal n_final
        records.append(killed_task_record(req.task_id, req.submit_t, t,
                                          alloc.alloc_id, attempt))
        n_final += 1
        done_ids.add(req.task_id)
        cancel_copies(req.task_id, t)

    def record_quarantined(req, attempt, alloc, t):
        nonlocal n_final
        records.append(quarantined_task_record(req.task_id, req.submit_t, t,
                                               alloc.alloc_id, attempt))
        n_final += 1
        done_ids.add(req.task_id)
        cancel_copies(req.task_id, t)

    stepper = LifecycleStepper(
        broker, allocator, now=lambda: now,
        spawn_workers=spawn_workers, retire_workers=retire_workers,
        busy_count=busy_count,
        worker_count=lambda: len([w for w in workers.values()
                                  if not w.alloc.virtual]),
        record_failed=record_failed, record_quarantined=record_quarantined,
        max_workers=max_workers, max_attempts=None, retired=retired,
        tracer=tracer, registry=registry, calibration=calibration,
        retry_seed=seed)

    # ---- chaos: handlers mutate the sim worker/allocation tables at the
    # stepper choke point, so a parity replay (whose handlers mutate the
    # live executor's tables) observes the identical fault sequence
    chaos: Optional[ChaosInjector] = None
    if fault_plan is not None and len(fault_plan):
        chaos = ChaosInjector(fault_plan, tracer=tracer)

        def _crash(ev, t):
            busy = sorted((w for w in workers.values()
                           if w.busy and not w.alloc.virtual),
                          key=lambda w: (w.alloc.alloc_id, w.wid))
            if not busy:
                return
            w = busy[ev.target % len(busy)]
            req, attempt, mark = w.req, w.attempt, w.mark_t
            w.alloc.note_busy(max(t - mark, 0.0))
            w.warm.clear()           # worker process restart: servers cold
            w.busy, w.req = False, None
            stepper.requeue_or_fail(req, attempt, mark, t, w.alloc,
                                    fatal=True)

        def _preempt(ev, t):
            allocs = sorted((a for a in broker.allocations()
                             if not a.virtual and a.state == RUNNING),
                            key=lambda a: a.alloc_id)
            if not allocs:
                return
            victim = allocs[ev.target % len(allocs)]
            deadline = t + ev.duration_s
            if deadline < victim.expiry_t:
                victim.walltime_s = deadline - victim.grant_t
            broker.drain_allocation(victim.alloc_id, t)
            # in-flight work that cannot finish inside the grace window
            # migrates NOW (same attempt — migration is not a failure)
            for w in sorted((w for w in workers.values()
                             if w.busy and w.alloc is victim
                             and w.end_t > deadline),
                            key=lambda w: w.wid):
                req, attempt, mark = w.req, w.attempt, w.mark_t
                w.alloc.note_busy(max(t - mark, 0.0))
                w.busy, w.req = False, None
                stepper.requeue_or_fail(req, attempt, mark, t, victim,
                                        migrate=True)

        def _slow(ev, t):
            cand = sorted((w for w in workers.values()
                           if not w.alloc.virtual
                           and w.alloc.state == RUNNING),
                          key=lambda w: (w.alloc.alloc_id, w.wid))
            if cand:
                w = cand[ev.target % len(cand)]
                chaos.set_slow(w.wid, ev.factor, t + ev.duration_s)

        def _outage(ev, t):
            sur = getattr(broker, "surrogate", None)
            if sur is not None and hasattr(sur, "set_degraded"):
                sur.set_degraded(t, t + ev.duration_s, "outage")

        chaos.on("worker_crash", _crash)
        chaos.on("preempt", _preempt)
        chaos.on("slow_node", _slow)
        chaos.on("surrogate_outage", _outage)
        # journal_torn: the sim has no journal — a symmetric no-op (the
        # chaos.fire instant still lands on the trace for parity)
        stepper.chaos = chaos

    # ---- speculative re-execution: when the queue is drained and idle
    # real capacity exists, hedge tasks running past their model's p95
    def hedge_check(t):
        if straggler_factor <= 0.0 or len(broker) != 0:
            return
        idle = [w for w in workers.values()
                if not w.busy and not w.alloc.virtual
                and w.alloc.state == RUNNING]
        if not idle:
            return
        cands = sorted((w for w in workers.values()
                        if w.busy and not w.req.config.get("_surrogate")
                        and not w.req.config.get("_speculated")),
                       key=lambda w: (w.mark_t, w.req.task_id))
        ids = find_stragglers(
            t, [(w.req.task_id, w.req.model_name, w.mark_t)
                for w in cands],
            real_done, predictor=broker.predictor,
            factor=straggler_factor, min_n=straggler_min_completed)
        by_id = {w.req.task_id: w for w in cands}
        for tid in ids[:len(idle)]:
            w = by_id[tid]
            w.req.config["_speculated"] = True
            w.req.config["_no_surrogate"] = True
            if tracer is not None:
                tracer.task_speculate(tid, w.attempt + 1, t, w.mark_t)
            broker.push(w.req, w.attempt + 1)

    # per-model cold-init costs: a calibrated/replay spec refines the
    # scalar `server_init` per model; a plain BackendSpec has no hook
    init_for = getattr(spec, "server_init_for", None)

    max_iters = 10_000 + 1_000 * len(reqs)     # runaway-config backstop
    iters = 0
    while n_final < len(reqs):
        iters += 1
        if iters > max_iters:
            raise RuntimeError(
                f"simulate_cluster made no progress after {max_iters} "
                f"events ({n_final}/{len(reqs)} tasks done) — check the "
                f"autoalloc config can actually serve the trace")
        # ---- next event time ------------------------------------------
        extra = stepper.deferred_times()       # backoff release times
        if chaos is not None:
            ct = chaos.next_time()
            if ct is not None:
                extra.append(ct)
        # hedging needs periodic ticks while work is in flight even on a
        # static pool (the straggler check is clock-, not event-, driven)
        elastic = allocator is not None or (
            straggler_factor > 0.0
            and any(w.busy for w in workers.values()))
        nxt = next_event_time(
            arrivals, arr_i,
            (w.end_t for w in workers.values() if w.busy),
            broker, elastic, next_tick, extra)
        if nxt is None:
            break                              # nothing can ever happen
        now = max(now, nxt)
        if now > max_t:
            break
        if now >= next_tick:
            next_tick = now + tick_s

        # ---- arrivals --------------------------------------------------
        while arr_i < len(arrivals) and arrivals[arr_i].t <= now:
            broker.push(reqs[arr_i], 1)
            arr_i += 1

        # ---- completions (before walltime kills: a task ending exactly
        # at expiry did finish) -----------------------------------------
        done = sorted((w for w in workers.values()
                       if w.busy and w.end_t <= now),
                      key=lambda w: (w.end_t, w.wid))
        for w in done:
            if not w.busy:
                continue                       # cancelled earlier this batch
            req = w.req
            if chaos is not None and not req.config.get("_surrogate") \
                    and chaos.take_corruption():
                # corrupted result: the attempt ran to completion but its
                # output is garbage — bill the burned node-seconds and
                # route through retry/quarantine as a fatal failure
                w.alloc.note_busy(max(w.end_t - w.mark_t, 0.0))
                alloc, attempt, mark = w.alloc, w.attempt, w.mark_t
                w.busy, w.req = False, None
                stepper.requeue_or_fail(req, attempt, mark, w.end_t,
                                        alloc, fatal=True)
                continue
            records.append(TaskRecord(
                task_id=req.task_id, submit_t=req.submit_t,
                start_t=w.start_t, end_t=w.end_t,
                cpu_time=w.init + w.compute, compute_t=w.compute,
                worker=f"alloc{w.alloc.alloc_id}-w{w.wid}",
                attempts=w.attempt, status="ok"))
            n_final += 1
            w.alloc.note_busy(w.init + w.compute)
            if tracer is not None:
                tracer.task_attempt(req.task_id, w.alloc.alloc_id, w.wid,
                                    w.mark_t, w.start_t, w.init, w.end_t,
                                    w.attempt, "ok",
                                    model=req.model_name,
                                    compute=w.compute)
            if calibration is not None and \
                    not req.config.get("_surrogate"):
                calibration.observe_attempt(
                    req.model_name, dispatch_s=w.start_t - w.mark_t,
                    init_s=w.init, compute_s=w.compute, now=w.end_t)
            # surrogate completions are milliseconds of GP predict: they
            # must not teach the runtime predictor what the REAL model
            # costs at this theta
            if broker.predictor is not None and \
                    not req.config.get("_surrogate"):
                if registry is not None:
                    # pre-observe residual: |predicted - actual| before
                    # this completion sharpens the predictor
                    pred = broker.predictor.predict(req)
                    if pred is not None:
                        registry.observe("predictor_abs_residual",
                                         abs(pred - w.compute))
                broker.predictor.observe(req, w.compute)
            if not req.config.get("_surrogate"):
                real_done.append((req.model_name, w.compute))
            w.busy, w.req = False, None
            done_ids.add(req.task_id)
            cancel_copies(req.task_id, now)    # hedge losers, if any

        # ---- lifecycle: the shared stepper owns transitions (capped
        # grants), walltime kills, drained-dry, and autoalloc — in the
        # ONE canonical order the live executor also runs ---------------
        stepper.step(now)
        hedge_check(now)

        # ---- dispatch --------------------------------------------------
        for w in dispatch_order():
            if w.busy or w.alloc.state != RUNNING:
                continue
            view = WorkerView(wid=w.wid, warm_models=frozenset(w.warm),
                              budget_left=w.alloc.budget_left(now),
                              alloc_id=w.alloc.alloc_id)
            item = broker.pop(view)
            # a queued copy of a task that already reached a terminal
            # state (quarantined while its hedge ran, etc.) is stale —
            # drop it at pop, exactly as the live executor does
            while item is not None and item[0].task_id in done_ids:
                item = broker.pop(view)
            if item is None:
                continue
            req, attempt = item
            w.req, w.attempt, w.busy = req, attempt, True
            if req.config.get("_surrogate"):
                # offloaded: one GP predict instead of the forward model —
                # no model server, no warm-start bookkeeping.  Count the
                # served evaluation where the live path counts inside
                # evaluate() — same-object stats parity.
                w.compute = getattr(broker.surrogate, "latency_s", 0.05)
                w.init = 0.0
                if hasattr(broker.surrogate, "note_served"):
                    broker.surrogate.note_served()
            else:
                w.compute = runtimes[req.task_id]
                if chaos is not None:
                    w.compute *= chaos.slow_factor(w.wid, now)
                w.init = (0.0 if req.model_name in w.warm
                          else (init_for(req.model_name)
                                if init_for is not None
                                else spec.server_init))
                w.warm.add(req.model_name)
            w.mark_t = now
            w.start_t = now + spec.dispatch_latency
            w.end_t = w.start_t + w.init + w.compute

    # ---- wind down: release held groups; still-queued ones are
    # cancelled (0 node-seconds, as scancel would) -----------------------
    end = max((r.end_t for r in records), default=now)
    stepper.release(end)
    fill_lost(records, reqs, end, tracer)
    alloc_records = sorted((a.record() for a in retired),
                           key=lambda r: r.alloc_id)
    return ClusterResult(
        records=records,
        allocations=alloc_records,
        decisions=list(allocator.decisions) if allocator is not None else [],
        events=list(stepper.events),
        overhead_attribution=(attribute_overhead(tracer.events())
                              if tracer is not None else None))
