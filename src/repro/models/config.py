"""Model / workload configuration dataclasses.

A single ``ModelConfig`` describes every architecture family in the assigned
pool (dense GQA, MLA, MoE, SSM, RWKV, hybrid, audio/vlm-backbone).  Family
specific fields are simply unused by the other families.  ``ShapeConfig``
describes one (seq_len, global_batch, mode) workload cell.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str                    # "train" | "prefill" | "decode"


# The four LM shapes assigned to every architecture in the pool.
LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # --- block layout -------------------------------------------------
    # Per-layer block kind.  "attn+mlp" is a standard transformer layer;
    # "mamba2" an SSM block; "rwkv6" an RWKV time/channel-mix pair.
    block_kind: str = "attn+mlp"
    attn_kind: str = "gqa"            # gqa | mla | none
    mlp_kind: str = "swiglu"          # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # hybrid (zamba2): a weight-shared attention block applied every
    # `shared_attn_every` SSM layers.
    shared_attn_every: int = 0

    # --- MLA ------------------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden size
    first_k_dense: int = 0            # leading dense layers (deepseek-v3: 3)
    dense_d_ff: int = 0               # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_kind: str = "softmax"      # softmax | sigmoid (deepseek-v3)

    # --- SSM (mamba2) -----------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- RWKV6 ------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # --- MTP (deepseek-v3) -------------------------------------------------
    mtp_depth: int = 0

    # --- IO ------------------------------------------------------------
    input_mode: str = "tokens"        # tokens | embeddings (audio/vlm stubs)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # --- numerics / distribution knobs ----------------------------------
    dtype: str = "bfloat16"
    accum_steps: int = 1              # gradient-accumulation microbatches
    moments_dtype: str = "float32"    # adam moment dtype (bf16 for huge models)
    fsdp_pod: bool = False            # shard params over pod axis too (ZeRO over DCN)
    remat: bool = True
    remat_policy: str = "full"        # full | dots (save matmul outputs:
                                      # backward skips recompute AND its
                                      # FSDP weight re-gathers)
    scan_layers: bool = True
    # beyond-paper perf knobs (§Perf hillclimb; False = paper-faithful
    # baseline distribution):
    seq_shard: bool = False           # Megatron-style sequence parallelism:
                                      # shard activation S over `model`
    ep_over_data: bool = False        # EP over data x model (1 expert/chip;
                                      # token all-gather instead of per-step
                                      # FSDP weight gathers — decode/serving)
    subquadratic: bool = False        # True -> long_500k cell is runnable
    vocab_pad_multiple: int = 128

    # ---------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def shapes(self) -> Tuple[ShapeConfig, ...]:
        return LM_SHAPES

    def runnable(self, shape: ShapeConfig) -> bool:
        """long_500k requires sub-quadratic attention (SSM/hybrid/linear)."""
        if shape.name == "long_500k" and not self.subquadratic:
            return False
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
