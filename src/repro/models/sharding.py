"""Logical-axis sharding rules (t5x-style) with divisibility fallback.

Every parameter leaf is declared with a tuple of *logical* axis names; the
rules below map logical axes onto mesh axes.  A mapping is dropped (axis left
unsharded) whenever the dimension size is not divisible by the mesh-axis
size — this keeps one rule table valid across all ten architectures (e.g.
yi-34b's 56 heads do not divide a 16-way model axis; its head axis falls back
to replicated + padded activations, which the roofline table then reports
honestly).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> candidate mesh axes, in priority order. The first candidate
# whose total size divides the dimension wins.
#
# "fsdp" is a placeholder resolved to ("data",) or ("pod", "data") per-config.
PARAM_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "vocab": (("model",),),
    # input-embedding table: vocab UNsharded, d_model TP-sharded.  A gather
    # along a sharded vocab axis forces SPMD to replicate the whole table
    # (involuntary full remat); sharding d_model instead costs one small
    # activation all-gather and keeps storage at table/16 per device.
    "in_vocab": ((),),
    "embed": (("fsdp",),),            # d_model rows of weight matrices
    "mlp": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": ((),),
    "expert": (("model",),),
    "expert_mlp": (("fsdp",),),
    "q_lora": ((),),
    "kv_lora": ((),),
    "inner": (("model",),),           # ssm/rwkv fused inner dim
    "state": ((),),
    "conv": ((),),
    "lora": ((),),
    "layers": ((),),                  # scan-stacked layer dim: never sharded
    None: ((),),
}

ACT_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "act_batch": (("pod", "data"),),
    "act_seq": ((),),
    "act_seq_attn": ((),),             # q/k/v seq dim: NEVER seq-sharded
                                       # (attention is the TP-heads region
                                       # even under sequence parallelism)
    "act_seq_sharded": (("model",),),  # kv-cache sequence dim (flash-decoding)
    "act_vocab": (("model",),),
    "act_heads": (("model",),),
    "act_kv_heads": (("model",),),
    "act_embed": ((),),
    "act_mlp": (("model",),),
    "act_expert": (("model",),),
    None: ((),),
}


def _resolve(candidates, fsdp_axes: Tuple[str, ...]):
    out = []
    for cand in candidates:
        axes: Tuple[str, ...] = ()
        for a in cand:
            axes += fsdp_axes if a == "fsdp" else (a,)
        out.append(axes)
    return out


def spec_for(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    *,
    fsdp_axes: Tuple[str, ...] = ("data",),
    rules: Optional[Dict] = None,
    strict_divisible: bool = True,
) -> P:
    """Map logical axes of one array onto a PartitionSpec for `mesh`."""
    rules = rules or PARAM_RULES
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical_axes):
        table = rules.get(name, ((),))
        chosen: Tuple[str, ...] = ()
        for axes in _resolve(table, fsdp_axes):
            # drop axes absent from this mesh (e.g. "pod" on the single-pod
            # mesh) rather than rejecting the whole candidate
            axes = tuple(a for a in axes if a in mesh_sizes)
            if not axes or any(a in used for a in axes):
                continue
            total = math.prod(mesh_sizes[a] for a in axes)
            if strict_divisible and dim % total != 0:
                continue
            chosen = axes
            break
        for a in chosen:
            used.add(a)
        parts.append(chosen if len(chosen) > 1 else (chosen[0] if chosen else None))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_pspecs(axes_tree, shape_tree, mesh: Mesh, *, fsdp_axes=("data",), rules=None):
    """Build a pytree of PartitionSpec matching `shape_tree`/`axes_tree`."""
    def f(axes, shp):
        shape = shp.shape if hasattr(shp, "shape") else shp
        return spec_for(shape, axes, mesh, fsdp_axes=fsdp_axes, rules=rules)
    return jax.tree.map(f, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, **kw):
    specs = tree_pspecs(axes_tree, shape_tree, mesh, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, *logical_axes, mesh: Optional[Mesh] = None):
    """with_sharding_constraint by activation logical axes (no-op off-mesh)."""
    mesh = mesh or _current_mesh()
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        return x
    spec = spec_for(x.shape, logical_axes, mesh, rules=_effective_act_rules())
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --- activation-rule overrides (perf knobs, e.g. sequence parallelism) ---
_ACT_OVERRIDES: list = []


class act_overrides:
    """Context manager overriding ACT_RULES entries during tracing, e.g.
    `with act_overrides(act_seq=(("model",),)):` turns on Megatron-style
    sequence parallelism for every `constrain` under it."""

    def __init__(self, **over):
        self.over = {k: v for k, v in over.items()}

    def __enter__(self):
        _ACT_OVERRIDES.append(self.over)
        return self

    def __exit__(self, *exc):
        _ACT_OVERRIDES.pop()


def _effective_act_rules() -> Dict:
    if not _ACT_OVERRIDES:
        return ACT_RULES
    rules = dict(ACT_RULES)
    for o in _ACT_OVERRIDES:
        rules.update(o)
    return rules


# --- lightweight mesh context -------------------------------------------
_MESH_STACK = []


class use_mesh:
    """Context manager marking the mesh used by `constrain` (and `with mesh:`)."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    def __enter__(self):
        _MESH_STACK.append(self.mesh)
        self._ctx = self.mesh.__enter__()
        return self.mesh

    def __exit__(self, *exc):
        _MESH_STACK.pop()
        return self.mesh.__exit__(*exc)


def _current_mesh() -> Optional[Mesh]:
    return _MESH_STACK[-1] if _MESH_STACK else None


def current_mesh_axis_size(axis: str) -> int:
    m = _current_mesh()
    if m is None or axis not in m.axis_names:
        return 1
    return dict(zip(m.axis_names, m.devices.shape))[axis]


def batch_axes(mesh: Optional[Mesh] = None) -> Tuple[str, ...]:
    """The mesh axes that shard the batch dimension (pod and/or data)."""
    mesh = mesh or _current_mesh()
    names = mesh.axis_names if mesh is not None else ()
    return tuple(a for a in ("pod", "data") if a in names)
