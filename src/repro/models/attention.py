"""Attention blocks: GQA (llama-style) and MLA (deepseek/minicpm-style).

Three execution paths per block:
  * train / prefill: full-sequence causal attention (Pallas flash kernel on
    TPU, chunked-jnp fallback elsewhere) — prefill additionally returns the
    KV cache.
  * decode: one new token against a pre-filled cache.  When a mesh is active
    the cache's sequence dimension is sharded over the `model` axis and the
    attention is computed flash-decoding style inside `shard_map` (partial
    max/sum per shard + logsumexp merge via psum) — the TPU-native analogue
    of splitting one long context over many workers.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops
from repro.models import sharding
from repro.models.layers import ParamDef, apply_rope, dense, rms_norm


# ==========================================================================
# GQA
# ==========================================================================
def gqa_defs(cfg) -> Dict[str, ParamDef]:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    defs = {
        "w_q": ParamDef((d, h, dh), ("embed", "heads", "head_dim")),
        "w_k": ParamDef((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "w_v": ParamDef((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "w_o": ParamDef((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((dh,), ("head_dim",), "ones")
        defs["k_norm"] = ParamDef((dh,), ("head_dim",), "ones")
    return defs


def _project_qkv(p, x, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_apply(p, x, cfg, *, positions, cache=None, decode_pos=None):
    """x: [B,S,D].  Returns (out, new_cache_or_None)."""
    if cache is not None and decode_pos is not None:          # decode
        return _gqa_decode(p, x, cfg, cache, decode_pos)
    q, k, v = _project_qkv(p, x, cfg, positions)
    if cfg.seq_shard and cache is None:
        # context-parallel attention (train path): Q rows seq-sharded over
        # `model`, K/V replicated; the dense form lets XLA SPMD shard the
        # score/context matmuls by Q rows — the chunked-scan form would
        # serialise a scan over a sharded dim.  Traffic and FLOPs per
        # device drop ~TP-fold vs the replicated fallback.
        from repro.kernels import ref as kref
        q = sharding.constrain(q, "act_batch", "act_seq", "act_heads", None)
        k = sharding.constrain(k, "act_batch", "act_seq_attn",
                               "act_kv_heads", None)
        v = sharding.constrain(v, "act_batch", "act_seq_attn",
                               "act_kv_heads", None)
        out = kref.attention(q, k, v, causal=True)
    elif _use_cp_prefill(cfg, cache, x.shape[1]):
        # context-parallel prefill (forward-only, memory-bounded): chunked
        # attention per rank over its Q-row shard via shard_map
        out = _cp_prefill_attention(q, k, v, cfg, sharding._current_mesh())
    else:
        q = sharding.constrain(q, "act_batch", "act_seq_attn", "act_heads",
                               None)
        k = sharding.constrain(k, "act_batch", "act_seq_attn",
                               "act_kv_heads", None)
        v = sharding.constrain(v, "act_batch", "act_seq_attn",
                               "act_kv_heads", None)
        out = kops.flash_attention(q, k, v, causal=True)
    out = jnp.einsum("bshk,hkd->bsd", out, p["w_o"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = None
    if cache is not None:                                     # prefill into cache
        s = x.shape[1]
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
        } if s != cache["k"].shape[1] else {"k": k, "v": v}
        new_cache = {n: sharding.constrain(
            c, "act_batch", "act_seq_sharded", "act_kv_heads", None)
            for n, c in new_cache.items()}
    return out, new_cache


def gqa_cache_defs(cfg, batch: int, max_len: int) -> Dict[str, ParamDef]:
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    ax = ("act_batch", "act_seq_sharded", "act_kv_heads", None)
    return {"k": ParamDef((batch, max_len, hkv, dh), ax, "zeros"),
            "v": ParamDef((batch, max_len, hkv, dh), ax, "zeros")}


def _cp_prefill_attention(q, k, v, cfg, mesh):
    """Context-parallel prefill: each `model`-rank computes its S/tp Q rows
    against the full K/V (gathered once) with the chunked forward —
    inside shard_map, so the chunk scan stays per-device (SPMD would
    serialise a scan over a sharded dim)."""
    from repro.kernels import ref as kref
    b, s = q.shape[:2]
    tp = sharding.current_mesh_axis_size("model")
    bspec = _batch_spec(mesh, b)
    s_local = s // tp

    def body(q_l, k_f, v_f):
        rank = jax.lax.axis_index("model")
        return kref.attention_chunked_fwd(q_l, k_f, v_f, causal=True,
                                          q_offset=rank * s_local)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, "model", None, None),
                  P(bspec, None, None, None), P(bspec, None, None, None)),
        out_specs=P(bspec, "model", None, None),
        check_vma=False,
    )(q, k, v)


def _use_cp_prefill(cfg, cache, s: int) -> bool:
    mesh = sharding._current_mesh()
    tp = sharding.current_mesh_axis_size("model")
    return (cfg.seq_shard and cache is not None and mesh is not None
            and tp > 1 and s % tp == 0)


def _merge_partial(o, m, l, axis_name):
    """Merge flash-decoding partials across `axis_name`: [B,H,Dh],[B,H],[B,H]."""
    m_g = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axis_name)
    o_g = jax.lax.psum(o * corr[..., None], axis_name)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]


def _local_masked_attend(q, k, v, valid):
    """q:[B,H,Dh] k/v:[B,S,H,Dh] valid:[B,S] -> partial (o, m, l) in f32."""
    s = jnp.einsum("bhk,bshk->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    s = jnp.where(valid[:, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)                                   # [B,H]
    e = jnp.exp(s - m[..., None]) * valid[:, None, :]
    l = jnp.sum(e, axis=-1)
    o = jnp.einsum("bhs,bshk->bhk", e, v.astype(jnp.float32))
    return o, m, l


def _gqa_decode_body(q, k_new, v_new, ck, cv, pos, *, axis_name, shards):
    """Per-shard body. ck/cv: [B, S_local, Hkv, Dh]; q: [B, H, Dh]."""
    b, s_local, hkv, dh = ck.shape
    h = q.shape[1]
    rank = jax.lax.axis_index(axis_name) if axis_name else 0
    local_pos = pos - rank * s_local
    iota = jnp.arange(s_local)
    hit = (iota == local_pos)[None, :, None, None]            # [1,S_l,1,1]
    ck = jnp.where(hit, k_new[:, None], ck)
    cv = jnp.where(hit, v_new[:, None], cv)
    # expand kv heads -> q heads
    rep = h // hkv
    ke = jnp.repeat(ck, rep, axis=2)
    ve = jnp.repeat(cv, rep, axis=2)
    global_iota = iota + rank * s_local
    valid = jnp.broadcast_to((global_iota <= pos)[None, :], (b, s_local))
    o, m, l = _local_masked_attend(q, ke, ve, valid)
    if axis_name:
        out = _merge_partial(o, m, l, axis_name)
    else:
        out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype), ck, cv


def _batch_spec(mesh, batch_size: int):
    """Mesh axes for the batch dim of a shard_map decode body; falls back
    to replicated when the batch does not divide (e.g. long_500k B=1)."""
    ba = sharding.batch_axes(mesh)
    total = 1
    for a in ba:
        total *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    if not ba or batch_size % total != 0:
        return None
    return ba[0] if len(ba) == 1 else ba


def _gqa_decode(p, x, cfg, cache, pos):
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)              # [B,1,H,Dh]
    q, k_new, v_new = q[:, 0], k[:, 0], v[:, 0]
    mesh = sharding._current_mesh()
    shards = sharding.current_mesh_axis_size("model")
    if mesh is not None and shards > 1 and cache["k"].shape[1] % shards == 0:
        bspec = _batch_spec(mesh, b)
        body = functools.partial(_gqa_decode_body, axis_name="model",
                                 shards=shards)
        out, ck, cv = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, None, None), P(bspec, None, None),
                      P(bspec, None, None),
                      P(bspec, "model", None, None), P(bspec, "model", None, None),
                      P()),
            out_specs=(P(bspec, None, None),
                       P(bspec, "model", None, None), P(bspec, "model", None, None)),
            check_vma=False,
        )(q, k_new, v_new, cache["k"], cache["v"], pos)
    else:
        out, ck, cv = _gqa_decode_body(q, k_new, v_new, cache["k"], cache["v"],
                                       pos, axis_name=None, shards=1)
    out = jnp.einsum("bhk,hkd->bd", out, p["w_o"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out[:, None], {"k": ck, "v": cv}


# ==========================================================================
# MLA (multi-head latent attention)
# ==========================================================================
def mla_defs(cfg) -> Dict[str, ParamDef]:
    d, h = cfg.d_model, cfg.n_heads
    nope, rope_d, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qd = nope + rope_d
    defs: Dict[str, ParamDef] = {}
    if cfg.q_lora_rank:
        defs["w_q_a"] = ParamDef((d, cfg.q_lora_rank), ("embed", "q_lora"))
        defs["q_a_norm"] = ParamDef((cfg.q_lora_rank,), ("q_lora",), "ones")
        defs["w_q_b"] = ParamDef((cfg.q_lora_rank, h, qd),
                                 ("q_lora", "heads", "head_dim"))
    else:
        defs["w_q"] = ParamDef((d, h, qd), ("embed", "heads", "head_dim"))
    defs["w_kv_a"] = ParamDef((d, cfg.kv_lora_rank + rope_d), ("embed", "kv_lora"))
    defs["kv_a_norm"] = ParamDef((cfg.kv_lora_rank,), ("kv_lora",), "ones")
    defs["w_kv_b"] = ParamDef((cfg.kv_lora_rank, h, nope + vdim),
                              ("kv_lora", "heads", "head_dim"))
    defs["w_o"] = ParamDef((h, vdim, d), ("heads", "head_dim", "embed"))
    return defs


def mla_cache_defs(cfg, batch: int, max_len: int) -> Dict[str, ParamDef]:
    return {
        "c_kv": ParamDef((batch, max_len, cfg.kv_lora_rank),
                         ("act_batch", "act_seq_sharded", None), "zeros"),
        "k_rope": ParamDef((batch, max_len, cfg.qk_rope_head_dim),
                           ("act_batch", "act_seq_sharded", None), "zeros"),
    }


def _mla_q(p, x, cfg, positions):
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        qa = rms_norm(dense(x, p["w_q_a"]), p["q_a_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", qa, p["w_q_b"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(p, x, cfg, positions):
    rope_d = cfg.qk_rope_head_dim
    kv_a = dense(x, p["w_kv_a"])                              # [B,S,r+rope]
    c_kv = rms_norm(kv_a[..., :cfg.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv_a[..., cfg.kv_lora_rank:]
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_apply(p, x, cfg, *, positions, cache=None, decode_pos=None):
    nope, vdim = cfg.qk_nope_head_dim, cfg.v_head_dim
    if cache is not None and decode_pos is not None:
        return _mla_decode(p, x, cfg, cache, decode_pos)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latents(p, x, cfg, positions)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_kv_b"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    h = k_nope.shape[2]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_rope.shape[:2] + (h, k_rope.shape[-1]))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    if cfg.seq_shard and cache is None:
        # context-parallel train path (see gqa_apply)
        from repro.kernels import ref as kref
        q = sharding.constrain(q, "act_batch", "act_seq", "act_heads", None)
        k = sharding.constrain(k, "act_batch", "act_seq_attn", "act_heads",
                               None)
        v = sharding.constrain(v, "act_batch", "act_seq_attn", "act_heads",
                               None)
        out = kref.attention(q, k, v, causal=True)
    elif _use_cp_prefill(cfg, cache, x.shape[1]):
        out = _cp_prefill_attention(q, k, v, cfg, sharding._current_mesh())
    else:
        q = sharding.constrain(q, "act_batch", "act_seq_attn", "act_heads",
                               None)
        k = sharding.constrain(k, "act_batch", "act_seq_attn", "act_heads",
                               None)
        v = sharding.constrain(v, "act_batch", "act_seq_attn", "act_heads",
                               None)
        out = kops.flash_attention(q, k, v, causal=True)
    out = jnp.einsum("bshk,hkd->bsd", out, p["w_o"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        if c_kv.shape[1] != cache["c_kv"].shape[1]:
            new_cache = {
                "c_kv": jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, 0, 0)),
                "k_rope": jax.lax.dynamic_update_slice(cache["k_rope"], k_rope,
                                                       (0, 0, 0)),
            }
        new_cache = {n: sharding.constrain(c, "act_batch", "act_seq_sharded", None)
                     for n, c in new_cache.items()}
    return out, new_cache


def _mla_decode_body(qc, q_rope, c_new, kr_new, c_kv, k_rope, w_uv, pos,
                     *, axis_name):
    """Absorbed MLA decode. qc: [B,H,r] (q_nope @ W_uk); q_rope: [B,H,rope];
    c_kv: [B,S_l,r]; k_rope: [B,S_l,rope]; w_uv: [r,H,v]."""
    b, s_local, r = c_kv.shape
    rank = jax.lax.axis_index(axis_name) if axis_name else 0
    local_pos = pos - rank * s_local
    iota = jnp.arange(s_local)
    hit = (iota == local_pos)[None, :, None]
    c_kv = jnp.where(hit, c_new[:, None], c_kv)
    k_rope = jnp.where(hit, kr_new[:, None], k_rope)
    # qc and q_rope arrive pre-scaled by 1/sqrt(nope + rope); the latent dot
    # qc . c_kv reproduces q_nope . k_nope exactly (absorption identity).
    s = (jnp.einsum("bhr,bsr->bhs", qc.astype(jnp.float32),
                    c_kv.astype(jnp.float32))
         + jnp.einsum("bhk,bsk->bhs", q_rope.astype(jnp.float32),
                      k_rope.astype(jnp.float32)))
    global_iota = iota + rank * s_local
    valid = jnp.broadcast_to((global_iota <= pos)[None, :], (b, s_local))
    s = jnp.where(valid[:, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)
    e = jnp.exp(s - m[..., None]) * valid[:, None, :]
    l = jnp.sum(e, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", e, c_kv.astype(jnp.float32))
    if axis_name:
        m_g = jax.lax.pmax(m, axis_name)
        corr = jnp.exp(m - m_g)
        l = jax.lax.psum(l * corr, axis_name)
        ctx = jax.lax.psum(ctx * corr[..., None], axis_name)
    ctx = ctx / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32))
    return out, c_kv, k_rope


def _mla_decode(p, x, cfg, cache, pos):
    nope = cfg.qk_nope_head_dim
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)             # [B,1,H,*]
    c_kv_new, k_rope_new = _mla_latents(p, x, cfg, positions)
    w_uk = p["w_kv_b"][..., :nope]                            # [r,H,nope]
    w_uv = p["w_kv_b"][..., nope:]                            # [r,H,v]
    scale = 1.0 / math.sqrt(nope + cfg.qk_rope_head_dim)
    qc = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0].astype(jnp.float32),
                    w_uk.astype(jnp.float32)) * scale
    q_rope_s = q_rope[:, 0].astype(jnp.float32) * scale
    mesh = sharding._current_mesh()
    shards = sharding.current_mesh_axis_size("model")
    args = (qc, q_rope_s, c_kv_new[:, 0], k_rope_new[:, 0],
            cache["c_kv"], cache["k_rope"], w_uv, pos)
    if mesh is not None and shards > 1 and cache["c_kv"].shape[1] % shards == 0:
        bspec = _batch_spec(mesh, b)
        body = functools.partial(_mla_decode_body, axis_name="model")
        out, c_kv, k_rope = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, None, None), P(bspec, None, None),
                      P(bspec, None), P(bspec, None),
                      P(bspec, "model", None), P(bspec, "model", None),
                      P(None, None, None), P()),
            out_specs=(P(bspec, None, None),
                       P(bspec, "model", None), P(bspec, "model", None)),
            check_vma=False,
        )(*args)
    else:
        out, c_kv, k_rope = _mla_decode_body(*args, axis_name=None)
    out = jnp.einsum("bhv,hvd->bd", out, p["w_o"].astype(jnp.float32))
    return out.astype(x.dtype)[:, None], {"c_kv": c_kv, "k_rope": k_rope}


def attention_defs(cfg):
    return mla_defs(cfg) if cfg.attn_kind == "mla" else gqa_defs(cfg)


def attention_apply(p, x, cfg, **kw):
    if cfg.attn_kind == "mla":
        return mla_apply(p, x, cfg, **kw)
    return gqa_apply(p, x, cfg, **kw)


def attention_cache_defs(cfg, batch: int, max_len: int):
    if cfg.attn_kind == "mla":
        return mla_cache_defs(cfg, batch, max_len)
    return gqa_cache_defs(cfg, batch, max_len)
