"""Mamba2 (state-space dual) block — used by zamba2.

Layout follows the reference Mamba2: fused in-projection producing
(z, x, B, C, dt), causal depthwise conv over (x, B, C), per-head scalar
decay SSD recurrence, gated RMSNorm, out-projection.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import sharding
from repro.models.layers import ParamDef, dense, rms_norm


def _dims(cfg):
    d_inner = cfg.ssm_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = d_inner + 2 * n
    return d_inner, n, h, conv_dim


def mamba2_defs(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    d_inner, n, h, conv_dim = _dims(cfg)
    d_proj = 2 * d_inner + 2 * n + h
    return {
        "w_in": ParamDef((d, d_proj), ("embed", "inner")),
        "conv_w": ParamDef((cfg.ssm_conv, conv_dim), ("conv", "inner"), "normal"),
        "conv_b": ParamDef((conv_dim,), ("inner",), "zeros"),
        "a_log": ParamDef((h,), ("inner",), "zeros"),
        "d_skip": ParamDef((h,), ("inner",), "ones"),
        "dt_bias": ParamDef((h,), ("inner",), "zeros"),
        "norm": ParamDef((d_inner,), ("inner",), "ones"),
        "w_out": ParamDef((d_inner, d), ("inner", "embed")),
    }


def mamba2_cache_defs(cfg, batch: int) -> Dict[str, ParamDef]:
    d_inner, n, h, conv_dim = _dims(cfg)
    return {
        "conv": ParamDef((batch, cfg.ssm_conv - 1, conv_dim),
                         ("act_batch", None, None), "zeros"),
        "ssd": ParamDef((batch, h, cfg.ssm_head_dim, n),
                        ("act_batch", None, None, None), "zeros"),
    }


def _split_proj(proj, cfg):
    d_inner, n, h, _ = _dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * n]
    dt = proj[..., -h:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, prev: Optional[jax.Array] = None):
    """xbc: [B,S,C]; conv_w: [K,C] depthwise. prev: [B,K-1,C] state."""
    k = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    xp = jnp.concatenate([prev.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):  # k is tiny (4): unrolled taps beat a conv primitive here
        out = out + (xp[:, i:i + xbc.shape[1]].astype(jnp.float32)
                     * conv_w[i].astype(jnp.float32))
    out = out + conv_b.astype(jnp.float32)
    new_state = xp[:, -(k - 1):] if k > 1 else prev
    return jax.nn.silu(out).astype(xbc.dtype), new_state


def mamba2_apply(p, x: jax.Array, cfg, *, cache=None, decode: bool = False
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: [B,S,D] -> (out, new_cache)."""
    b, s, d = x.shape
    d_inner, n, h, conv_dim = _dims(cfg)
    proj = dense(x, p["w_in"])
    z, xbc, dt = _split_proj(proj, cfg)
    prev_conv = cache["conv"] if cache is not None else None
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], prev_conv)
    xs = xbc[..., :d_inner].reshape(b, s, h, cfg.ssm_head_dim)
    b_in = xbc[..., d_inner:d_inner + n]
    c_in = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    state0 = cache["ssd"] if cache is not None else None
    if decode:
        # single-step recurrence (s == 1)
        dtt = dt[:, 0]                                          # [B,H]
        dec = jnp.exp(dtt * a[None])
        dbx = jnp.einsum("bh,bhp,bn->bhpn", dtt, xs[:, 0].astype(jnp.float32),
                         b_in[:, 0].astype(jnp.float32))
        st = dec[..., None, None] * state0.astype(jnp.float32) + dbx
        y = (jnp.einsum("bhpn,bn->bhp", st, c_in[:, 0].astype(jnp.float32))
             + p["d_skip"].astype(jnp.float32)[None, :, None]
             * xs[:, 0].astype(jnp.float32))
        y = y[:, None].astype(x.dtype)
        ssd_state = st
    else:
        y, ssd_state = kops.mamba2_ssd(xs, dt, a, b_in, c_in, p["d_skip"],
                                       state0, chunk=cfg.ssm_chunk)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], cfg.norm_eps)
    out = dense(y, p["w_out"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                     "ssd": ssd_state.astype(cache["ssd"].dtype)}
    return out, new_cache
