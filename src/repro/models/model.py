"""Model composition: segments of scanned homogeneous layers.

Every architecture in the pool is expressed as a list of `Segment`s, each a
stack of identical layers run under `jax.lax.scan` (keeping HLO size and
compile time bounded at 512 devices) with optional per-layer remat.  The
zamba2 hybrid is a scan over *groups* (N mamba layers + one weight-shared
attention block passed by closure, so the sharing is structural).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.attention import (attention_apply, attention_cache_defs,
                                    attention_defs)
from repro.models.config import ModelConfig
from repro.models.layers import (ParamDef, axes_tree, embed_defs, embed_tokens,
                                 init_tree, logits_from_hidden, mlp_apply,
                                 mlp_defs, rms_norm, shape_tree,
                                 softmax_cross_entropy, stack_defs)
from repro.models.moe import moe_apply, moe_defs
from repro.models.rwkv import rwkv6_apply, rwkv6_cache_defs, rwkv6_defs
from repro.models.ssm import mamba2_apply, mamba2_cache_defs, mamba2_defs

MOE_AUX_COEF = 0.01
MTP_LOSS_COEF = 0.3


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    n_layers: int
    kind: str                 # attn_mlp | attn_moe | mamba2 | rwkv6 | zamba_group
    cfg: ModelConfig          # possibly a modified copy (e.g. dense d_ff)


def model_segments(cfg: ModelConfig) -> List[Segment]:
    if cfg.block_kind == "rwkv6":
        return [Segment("layers", cfg.n_layers, "rwkv6", cfg)]
    if cfg.block_kind == "mamba2":
        if cfg.shared_attn_every:
            assert cfg.n_layers % cfg.shared_attn_every == 0
            return [Segment("groups", cfg.n_layers // cfg.shared_attn_every,
                            "zamba_group", cfg)]
        return [Segment("layers", cfg.n_layers, "mamba2", cfg)]
    if cfg.n_experts:
        segs = []
        if cfg.first_k_dense:
            dense_cfg = cfg.replace(n_experts=0, d_ff=cfg.dense_d_ff or cfg.d_ff)
            segs.append(Segment("dense", cfg.first_k_dense, "attn_mlp", dense_cfg))
        segs.append(Segment("moe", cfg.n_layers - cfg.first_k_dense,
                            "attn_moe", cfg))
        return segs
    return [Segment("layers", cfg.n_layers, "attn_mlp", cfg)]


# --------------------------------------------------------------------------
# Per-layer defs / apply
# --------------------------------------------------------------------------
def _layer_defs(kind: str, cfg: ModelConfig):
    d = cfg.d_model
    if kind == "attn_mlp":
        return {"norm1": ParamDef((d,), ("embed",), "ones"),
                "attn": attention_defs(cfg),
                "norm2": ParamDef((d,), ("embed",), "ones"),
                "mlp": mlp_defs(cfg)}
    if kind == "attn_moe":
        return {"norm1": ParamDef((d,), ("embed",), "ones"),
                "attn": attention_defs(cfg),
                "norm2": ParamDef((d,), ("embed",), "ones"),
                "moe": moe_defs(cfg)}
    if kind == "mamba2":
        return {"norm": ParamDef((d,), ("embed",), "ones"),
                "mamba": mamba2_defs(cfg)}
    if kind == "rwkv6":
        return rwkv6_defs(cfg)
    if kind == "zamba_group":
        return {"mamba": stack_defs(_layer_defs("mamba2", cfg),
                                    cfg.shared_attn_every)}
    raise ValueError(kind)


def _layer_cache_defs(kind: str, cfg: ModelConfig, batch: int, max_len: int):
    if kind in ("attn_mlp", "attn_moe"):
        return attention_cache_defs(cfg, batch, max_len)
    if kind == "mamba2":
        return mamba2_cache_defs(cfg, batch)
    if kind == "rwkv6":
        return rwkv6_cache_defs(cfg, batch)
    if kind == "zamba_group":
        return {"mamba": stack_defs(mamba2_cache_defs(cfg, batch),
                                    cfg.shared_attn_every),
                "shared_attn": attention_cache_defs(cfg, batch, max_len)}
    raise ValueError(kind)


def _layer_apply(kind: str, lp, x, cfg, *, positions, cache, decode_pos,
                 shared=None):
    """-> (x, new_cache, aux_loss)."""
    if kind in ("attn_mlp", "attn_moe"):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        attn_out, new_c = attention_apply(lp["attn"], h, cfg,
                                          positions=positions, cache=cache,
                                          decode_pos=decode_pos)
        x = x + attn_out
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if kind == "attn_moe":
            mo, aux = moe_apply(lp["moe"], h, cfg)
            return x + mo, new_c, aux
        return x + mlp_apply(lp["mlp"], h, cfg), new_c, jnp.float32(0)
    if kind == "mamba2":
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        out, new_c = mamba2_apply(lp["mamba"], h, cfg, cache=cache,
                                  decode=decode_pos is not None)
        return x + out, new_c, jnp.float32(0)
    if kind == "rwkv6":
        x, new_c = rwkv6_apply(lp, x, cfg, cache=cache,
                               decode=decode_pos is not None)
        return x, new_c, jnp.float32(0)
    if kind == "zamba_group":
        x, mcache, aux = _run_stack("mamba2", lp["mamba"], x, cfg,
                                    positions=positions,
                                    caches=None if cache is None
                                    else cache["mamba"],
                                    decode_pos=decode_pos)
        x2, acache, aux2 = _layer_apply(
            "attn_mlp", shared, x, cfg, positions=positions,
            cache=None if cache is None else cache["shared_attn"],
            decode_pos=decode_pos)
        new_c = None
        if cache is not None:
            new_c = {"mamba": mcache, "shared_attn": acache}
        return x2, new_c, aux + aux2
    raise ValueError(kind)


def _run_stack(kind: str, stacked_params, x, cfg, *, positions, caches,
               decode_pos, shared=None):
    """Scan over a stack of identical layers. caches: stacked or None."""
    train_mode = caches is None and decode_pos is None

    def body(carry, xs):
        h, aux = carry
        lp, cache_in = xs
        h, new_cache, a = _layer_apply(kind, lp, h, cfg, positions=positions,
                                       cache=cache_in, decode_pos=decode_pos,
                                       shared=shared)
        return (h, aux + a), new_cache

    if cfg.remat and train_mode:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)

    if not cfg.scan_layers:
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        aux = jnp.float32(0)
        new_caches = []
        for i in range(n):
            lp = jax.tree.map(lambda t: t[i], stacked_params)
            ci = None if caches is None else jax.tree.map(lambda t: t[i], caches)
            (x, aux), nc = body((x, aux), (lp, ci))
            new_caches.append(nc)
        out_caches = None
        if caches is not None:
            out_caches = jax.tree.map(lambda *ts: jnp.stack(ts), *new_caches)
        return x, out_caches, aux

    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0)),
                                        (stacked_params, caches))
    return x, new_caches, aux


# --------------------------------------------------------------------------
# Whole-model param / cache trees
# --------------------------------------------------------------------------
def param_defs(cfg: ModelConfig):
    defs: Dict[str, Any] = dict(embed_defs(cfg))
    for seg in model_segments(cfg):
        defs[seg.name] = stack_defs(_layer_defs(seg.kind, seg.cfg), seg.n_layers)
    if cfg.shared_attn_every:
        defs["shared_attn"] = _layer_defs("attn_mlp", cfg)
    if cfg.mtp_depth:
        defs["mtp"] = {"proj": ParamDef((2 * cfg.d_model, cfg.d_model),
                                        ("embed", "embed")),
                       "norm": ParamDef((cfg.d_model,), ("embed",), "ones"),
                       "layer": _layer_defs(
                           "attn_mlp",
                           cfg.replace(n_experts=0,
                                       d_ff=cfg.dense_d_ff or cfg.d_ff))}
    return defs


def param_axes(cfg: ModelConfig):
    return axes_tree(param_defs(cfg))


def abstract_params(cfg: ModelConfig):
    return shape_tree(param_defs(cfg), cfg.activation_dtype)


def init_params(cfg: ModelConfig, key: jax.Array):
    return init_tree(param_defs(cfg), key, cfg.activation_dtype)


def count_params(cfg: ModelConfig) -> int:
    total = 0
    for leaf in jax.tree.leaves(param_defs(cfg),
                                is_leaf=lambda x: isinstance(x, ParamDef)):
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
    return total


def count_active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token: routed experts scaled by top_k/E,
    input embedding excluded (a lookup, not a matmul)."""
    defs = param_defs(cfg)
    flat = jax.tree.flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))[0]
    total = 0
    for path, leaf in flat:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        n = 1
        for s in leaf.shape:
            n *= s
        if "embedding" in keys and not cfg.tie_embeddings:
            continue
        if "moe" in keys and "shared" not in keys and "router" not in keys:
            n = n * cfg.moe_top_k // max(cfg.n_experts, 1)
        total += n
    return total


def cache_defs(cfg: ModelConfig, batch: int, max_len: int):
    defs = {}
    for seg in model_segments(cfg):
        defs[seg.name] = stack_defs(
            _layer_cache_defs(seg.kind, seg.cfg, batch, max_len), seg.n_layers)
    return defs


def cache_axes(cfg: ModelConfig, batch: int, max_len: int):
    return axes_tree(cache_defs(cfg, batch, max_len))


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return init_tree(cache_defs(cfg, batch, max_len), jax.random.PRNGKey(0),
                     cfg.activation_dtype)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return shape_tree(cache_defs(cfg, batch, max_len), cfg.activation_dtype)


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------
def _inputs_to_hidden(params, batch: Dict[str, jax.Array], cfg) -> jax.Array:
    if cfg.input_mode == "embeddings":
        x = batch["embeddings"].astype(cfg.activation_dtype)
    else:
        x = embed_tokens(params, batch["tokens"], cfg)
    return sharding.constrain(x, "act_batch", "act_seq", None)


def forward(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            *, cache=None, decode_pos=None, last_only: bool = False,
            last_index: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Any, jax.Array]:
    """-> (logits [B,S,Vpad] f32, new_cache, aux_loss).
    last_only=True computes the LM head on the final position only (prefill
    never needs the other 32k-1 rows of a 150k-wide head); last_index [B]
    selects a per-row position instead (bucketed-prefill serving)."""
    import contextlib
    sp = (sharding.act_overrides(act_seq=(("model",),))
          if (cfg.seq_shard and decode_pos is None)
          else contextlib.nullcontext())
    with sp:
        x = _inputs_to_hidden(params, batch, cfg)
        b, s = x.shape[:2]
        if decode_pos is not None:
            positions = jnp.full((b, s), decode_pos, jnp.int32)
        else:
            positions = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        shared = params.get("shared_attn")
        aux = jnp.float32(0)
        new_cache = {} if cache is not None else None
        for seg in model_segments(cfg):
            seg_cache = None if cache is None else cache[seg.name]
            x, nc, a = _run_stack(seg.kind, params[seg.name], x, seg.cfg,
                                  positions=positions, caches=seg_cache,
                                  decode_pos=decode_pos, shared=shared)
            aux = aux + a
            if cache is not None:
                new_cache[seg.name] = nc
    if last_index is not None:
        x = jnp.take_along_axis(
            x, last_index.astype(jnp.int32)[:, None, None], axis=1)
    elif last_only:
        x = x[:, -1:]
    logits = logits_from_hidden(params, x, cfg)
    logits = sharding.constrain(logits, "act_batch", "act_seq", "act_vocab")
    return logits, new_cache, aux


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _, aux = forward(params, batch, cfg)
    labels = batch.get("labels", batch.get("tokens"))
    ce = softmax_cross_entropy(logits[:, :-1], labels[:, 1:], cfg.vocab_size)
    loss = ce + MOE_AUX_COEF * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth:
        mtp_ce = _mtp_loss(params, batch, cfg)
        loss = loss + MTP_LOSS_COEF * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(params, batch, cfg) -> jax.Array:
    """DeepSeek-V3 multi-token prediction: one extra depth (predict t+2)."""
    mtp = params["mtp"]
    x = _inputs_to_hidden(params, batch, cfg)
    b, s = x.shape[:2]
    labels = batch.get("labels", batch.get("tokens"))
    # h'_t = proj([norm(h_t); emb(token_{t+1})]) for t < S-1
    h = rms_norm(x, mtp["norm"], cfg.norm_eps)
    nxt = embed_tokens(params, labels, cfg)
    hcat = jnp.concatenate([h[:, :-1], nxt[:, 1:]], axis=-1)
    hp = jnp.einsum("bsd,df->bsf", hcat, mtp["proj"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s - 1, dtype=jnp.int32)[None],
                                 (b, s - 1))
    dense_cfg = cfg.replace(n_experts=0, d_ff=cfg.dense_d_ff or cfg.d_ff)
    hp, _, _ = _layer_apply("attn_mlp", mtp["layer"], hp, dense_cfg,
                            positions=positions, cache=None, decode_pos=None)
    logits = logits_from_hidden(params, hp, cfg)
    return softmax_cross_entropy(logits[:, :-1], labels[:, 2:], cfg.vocab_size)


def prefill(params, batch, cfg, cache, *, last_only: bool = False):
    """Full-sequence forward that also fills the cache."""
    logits, new_cache, aux = forward(params, batch, cfg, cache=cache,
                                     last_only=last_only)
    return logits, new_cache, aux


def decode_step(params, token_batch, cfg, cache, pos):
    """token_batch: {'tokens': [B,1]} (or embeddings [B,1,D]); pos: scalar."""
    logits, new_cache, _ = forward(params, token_batch, cfg, cache=cache,
                                   decode_pos=pos)
    return logits[:, -1], new_cache
