"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Faithful to arXiv:2404.05892: token-shift ddlerp with a shared low-rank
projection for the five mix targets (w,k,v,r,g), low-rank data-dependent
decay w_t, bonus u, per-head group norm, squared-relu channel mix.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.layers import ParamDef, dense

_N_MIX = 5  # w, k, v, r, g


def layer_norm(x, w, b, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def rwkv6_defs(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    h = cfg.rwkv_heads
    kd = cfg.rwkv_head_dim
    lw = cfg.rwkv_decay_lora
    lm = cfg.rwkv_mix_lora
    f = cfg.d_ff
    return {
        "ln1_w": ParamDef((d,), ("embed",), "ones"),
        "ln1_b": ParamDef((d,), ("embed",), "zeros"),
        "ln2_w": ParamDef((d,), ("embed",), "ones"),
        "ln2_b": ParamDef((d,), ("embed",), "zeros"),
        # --- time mix ---
        "mix_x": ParamDef((d,), ("embed",), "zeros"),
        "mix_base": ParamDef((_N_MIX, d), (None, "embed"), "zeros"),
        "mix_w1": ParamDef((d, _N_MIX * lm), ("embed", "lora")),
        "mix_w2": ParamDef((_N_MIX, lm, d), (None, "lora", "embed")),
        "decay_base": ParamDef((d,), ("embed",), "zeros"),
        "decay_w1": ParamDef((d, lw), ("embed", "lora")),
        "decay_w2": ParamDef((lw, d), ("lora", "embed")),
        "bonus_u": ParamDef((h, kd), ("heads", "head_dim"), "normal"),
        "w_r": ParamDef((d, d), ("embed", "inner")),
        "w_k": ParamDef((d, d), ("embed", "inner")),
        "w_v": ParamDef((d, d), ("embed", "inner")),
        "w_g": ParamDef((d, d), ("embed", "inner")),
        "gn_w": ParamDef((d,), ("inner",), "ones"),
        "gn_b": ParamDef((d,), ("inner",), "zeros"),
        "w_o": ParamDef((d, d), ("inner", "embed")),
        # --- channel mix ---
        "cmix_k": ParamDef((d,), ("embed",), "zeros"),
        "cmix_r": ParamDef((d,), ("embed",), "zeros"),
        "cw_k": ParamDef((d, f), ("embed", "mlp")),
        "cw_r": ParamDef((d, d), ("embed", "embed2")),
        "cw_v": ParamDef((f, d), ("mlp", "embed")),
    }


def rwkv6_cache_defs(cfg, batch: int) -> Dict[str, ParamDef]:
    d, h, kd = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "shift_t": ParamDef((batch, 1, d), ("act_batch", None, None), "zeros"),
        "shift_c": ParamDef((batch, 1, d), ("act_batch", None, None), "zeros"),
        "wkv": ParamDef((batch, h, kd, kd), ("act_batch", None, None, None),
                        "zeros"),
    }


def _token_shift(x, prev: Optional[jax.Array]):
    """Return x_{t-1} stream: [B,S,D]."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


def _group_norm(x, w, b, n_heads, eps=1e-5):
    """Per-head layer norm over head_dim. x: [B,S,D]."""
    bsz, s, d = x.shape
    xh = x.reshape(bsz, s, n_heads, d // n_heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = ((xh - mu) * jax.lax.rsqrt(var + eps)).reshape(bsz, s, d)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def _time_mix(p, x, cfg, prev_shift, wkv_state, decode):
    b, s, d = x.shape
    h, kd = cfg.rwkv_heads, cfg.rwkv_head_dim
    xprev = _token_shift(x, prev_shift)
    dx = xprev - x
    # shared ddlerp: five data-dependent mixing coefficients
    xx = x + dx * p["mix_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("bsd,dl->bsl", xx, p["mix_w1"],
                               preferred_element_type=jnp.float32))
    lora = lora.reshape(b, s, _N_MIX, -1)
    mix = (p["mix_base"].astype(jnp.float32)[None, None]
           + jnp.einsum("bsml,mld->bsmd", lora,
                        p["mix_w2"].astype(jnp.float32)))
    xm = x[:, :, None] + dx[:, :, None] * mix.astype(x.dtype)  # [B,S,5,D]
    x_w, x_k, x_v, x_r, x_g = (xm[:, :, i] for i in range(_N_MIX))
    # data-dependent decay in (0, 1)
    dec = jnp.tanh(jnp.einsum("bsd,dl->bsl", x_w, p["decay_w1"],
                              preferred_element_type=jnp.float32))
    dec = (p["decay_base"].astype(jnp.float32)[None, None]
           + jnp.einsum("bsl,ld->bsd", dec, p["decay_w2"].astype(jnp.float32)))
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32) - 2.0))        # init near ~0.87
    r = dense(x_r, p["w_r"]).reshape(b, s, h, kd)
    k = dense(x_k, p["w_k"]).reshape(b, s, h, kd)
    v = dense(x_v, p["w_v"]).reshape(b, s, h, kd)
    g = jax.nn.silu(dense(x_g, p["w_g"]).astype(jnp.float32)).astype(x.dtype)
    wh = w.reshape(b, s, h, kd).astype(jnp.float32)
    if decode:
        # one-step recurrence
        st = wkv_state.astype(jnp.float32)
        rt, kt, vt = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv",
                         rt, st + p["bonus_u"].astype(jnp.float32)[None, :, :, None] * kv)
        new_state = wh[:, 0][..., None] * st + kv
        out = out[:, None].reshape(b, 1, d).astype(x.dtype)
    else:
        out, new_state = kops.rwkv6_wkv(r, k, v, wh, p["bonus_u"], wkv_state)
        out = out.reshape(b, s, d)
    out = _group_norm(out, p["gn_w"], p["gn_b"], h) * g
    return dense(out, p["w_o"]), x[:, -1:], new_state


def _channel_mix(p, x, prev_shift):
    xprev = _token_shift(x, prev_shift)
    dx = xprev - x
    x_k = x + dx * p["cmix_k"].astype(x.dtype)
    x_r = x + dx * p["cmix_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(dense(x_k, p["cw_k"]).astype(jnp.float32)))
    r = jax.nn.sigmoid(dense(x_r, p["cw_r"]).astype(jnp.float32))
    out = r * jnp.einsum("bsf,fd->bsd", k.astype(x.dtype), p["cw_v"],
                         preferred_element_type=jnp.float32)
    return out.astype(x.dtype), x[:, -1:]


def rwkv6_apply(p, x: jax.Array, cfg, *, cache=None, decode: bool = False
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """One RWKV6 layer (time-mix + channel-mix, pre-LN residual)."""
    st = cache["shift_t"] if cache is not None else None
    sc = cache["shift_c"] if cache is not None else None
    wkv = cache["wkv"] if cache is not None else None
    h1 = layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
    tm, new_st, new_wkv = _time_mix(p, h1, cfg, st, wkv, decode)
    x = x + tm
    h2 = layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
    cm, new_sc = _channel_mix(p, h2, sc)
    x = x + cm
    new_cache = None
    if cache is not None:
        new_cache = {"shift_t": new_st.astype(cache["shift_t"].dtype),
                     "shift_c": new_sc.astype(cache["shift_c"].dtype),
                     "wkv": new_wkv.astype(cache["wkv"].dtype)}
    return x, new_cache
