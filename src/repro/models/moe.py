"""Mixture-of-Experts FFN with explicit expert-parallel dispatch.

Design (TPU-native adaptation of EP):
  * tokens are sharded over (pod, data) and *replicated* over the `model`
    axis (standard TP activation layout at the FFN boundary);
  * experts are sharded over `model` (E/tp experts per rank) with their
    weights additionally FSDP-sharded over the fsdp axes and all-gathered
    at use (ZeRO-3);
  * each model-rank routes every local token, keeps only the assignments
    that land on its own experts, packs them into static [E_local, C, D]
    capacity buffers with a cumsum position index (dropping on overflow),
    runs the expert FFN as one grouped einsum, scatter-adds the weighted
    results, and a single psum over `model` combines routed partials with
    the hidden-sharded shared-expert partials — the same all-reduce a dense
    TP FFN would need, so EP adds *no* extra collective on the hot path.

The body is mesh-free when called without an axis name, which is the path
unit tests and single-device smoke configs take.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import sharding
from repro.models.layers import ParamDef


def moe_defs(cfg) -> Dict[str, ParamDef]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    defs = {
        "router": ParamDef((d, e), ("embed", None), "normal"),
        "w_gate": ParamDef((e, d, f), ("expert", None, "expert_mlp")),
        "w_up": ParamDef((e, d, f), ("expert", None, "expert_mlp")),
        "w_down": ParamDef((e, f, d), ("expert", "expert_mlp", None)),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        defs["shared"] = {
            "w_gate": ParamDef((d, fs), ("embed", "mlp")),
            "w_up": ParamDef((d, fs), ("embed", "mlp")),
            "w_down": ParamDef((fs, d), ("mlp", "embed")),
        }
    return defs


def _route(logits: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits: [T, E] (f32) -> (weights [T,k], idx [T,k], aux_loss scalar)."""
    t, e = logits.shape
    k = cfg.moe_top_k
    if cfg.router_kind == "sigmoid":                    # deepseek-v3 style
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(jnp.sum(scores, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_i f_i * P_i
    dispatch = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    f_i = jnp.mean(dispatch, axis=0)
    p_i = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_i * p_i)
    return w, idx, aux


def _swiglu_grouped(xg, wg, wu, wd):
    """xg: [E,C,D]; wg/wu: [E,D,F]; wd: [E,F,D]."""
    g = jnp.einsum("ecd,edf->ecf", xg, wg, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xg, wu, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(xg.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wd,
                      preferred_element_type=jnp.float32).astype(xg.dtype)


def _moe_body(x, router_w, w_gate, w_up, w_down, shared, cfg, *,
              axis_name: Optional[str], fsdp_axes: Tuple[str, ...],
              batch_axes: Tuple[str, ...] = ()):
    """x: [T, D] local tokens; expert weights are this rank's slice
    [E_l, D, F_l] (F additionally FSDP-sharded -> all-gathered here)."""
    t, d = x.shape
    e = cfg.n_experts
    k = cfg.moe_top_k
    e_l = w_gate.shape[0]
    rank = jax.lax.axis_index(axis_name) if axis_name else 0
    if fsdp_axes:
        w_gate = jax.lax.all_gather(w_gate, fsdp_axes, axis=2, tiled=True)
        w_up = jax.lax.all_gather(w_up, fsdp_axes, axis=2, tiled=True)
        w_down = jax.lax.all_gather(w_down, fsdp_axes, axis=1, tiled=True)

    logits = jnp.einsum("td,de->te", x, router_w,
                        preferred_element_type=jnp.float32)
    weights, idx, aux = _route(logits, cfg)

    cap = max(1, int(math.ceil(cfg.capacity_factor * t * k / e)))
    token_id = jnp.repeat(jnp.arange(t), k)                      # [T*k]
    expert_id = idx.reshape(-1)
    w_flat = weights.reshape(-1).astype(jnp.float32)
    local_e = expert_id - rank * e_l
    in_local = (local_e >= 0) & (local_e < e_l)
    onehot = (jnp.where(in_local, local_e, e_l)[:, None]
              == jnp.arange(e_l)[None, :]).astype(jnp.int32)     # [T*k, E_l]
    pos = jnp.cumsum(onehot, axis=0) * onehot                    # 1-based
    pos_e = jnp.sum(pos, axis=-1) - 1                            # [-1 if foreign]
    keep = in_local & (pos_e >= 0) & (pos_e < cap)
    slot = jnp.where(keep, jnp.where(in_local, local_e, 0) * cap + pos_e,
                     e_l * cap)                                  # sentinel slot
    buf_tok = jnp.full((e_l * cap + 1,), t, jnp.int32).at[slot].set(token_id)
    buf_w = jnp.zeros((e_l * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, w_flat, 0.0))
    buf_tok, buf_w = buf_tok[:-1], buf_w[:-1]

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xg = x_pad[buf_tok].reshape(e_l, cap, d)
    y = _swiglu_grouped(xg, w_gate, w_up, w_down).reshape(e_l * cap, d)
    y = y * buf_w[:, None].astype(y.dtype)
    out = jnp.zeros((t + 1, d), jnp.float32).at[buf_tok].add(
        y.astype(jnp.float32))[:t]

    if shared is not None:                                        # hidden-sharded
        g = jnp.einsum("td,df->tf", x, shared["w_gate"],
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("td,df->tf", x, shared["w_up"],
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)
        out = out + jnp.einsum("tf,fd->td", h, shared["w_down"],
                               preferred_element_type=jnp.float32)
    if axis_name:
        out = jax.lax.psum(out, axis_name)
        axes = ((axis_name,) if isinstance(axis_name, str)
                else tuple(axis_name))
        aux = jax.lax.pmean(aux, tuple(dict.fromkeys(batch_axes + axes)))
    return out.astype(x.dtype), aux


def _moe_body_ep_all(x_local, router_w, w_gate, w_up, w_down, shared, cfg, *,
                     ep_axes: Tuple[str, ...],
                     gather_axes: Tuple[str, ...]):
    """EP over (data x model) — 1..few experts per chip, weights fully
    resident (the DeepSeek-V3 serving layout).  Tokens are all-gathered
    over the batch axes (cheap when tokens << weights, i.e. decode),
    every rank runs its local experts over the full token set, one psum
    over the EP axes combines; each rank keeps its own batch rows.
    Replaces the per-step FSDP weight gathers whose traffic dominates
    decode."""
    t_local, d = x_local.shape
    x = x_local
    if gather_axes:
        x = jax.lax.all_gather(x, gather_axes, axis=0, tiled=True)
    out, aux = _moe_body(x, router_w, w_gate, w_up, w_down, shared, cfg,
                         axis_name=ep_axes, fsdp_axes=(),
                         batch_axes=gather_axes)
    if gather_axes:
        my_row = jax.lax.axis_index(gather_axes) * t_local
        out = jax.lax.dynamic_slice(out, (my_row, 0), (t_local, d))
    return out, aux


def moe_apply(p, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,D] -> (out [B,S,D], aux loss scalar)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    mesh = sharding._current_mesh()
    tp = sharding.current_mesh_axis_size("model")
    shared = p.get("shared")
    if mesh is None or tp == 1 or cfg.n_experts % tp != 0:
        out, aux = _moe_body(xt, p["router"], p["w_gate"], p["w_up"],
                             p["w_down"], shared, cfg, axis_name=None,
                             fsdp_axes=())
        return out.reshape(b, s, d), aux

    batch = sharding.batch_axes(mesh)               # (pod?, data)
    n_batch = 1
    for a in batch:
        n_batch *= sharding.current_mesh_axis_size(a)
    ep_axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    n_ep = 1
    for a in ep_axes:
        n_ep *= sharding.current_mesh_axis_size(a)
    if (cfg.ep_over_data and len(ep_axes) == 2
            and cfg.n_experts % n_ep == 0 and (b * s) % n_batch == 0):
        def _m(axes):
            if not axes:
                return None
            return axes[0] if len(axes) == 1 else tuple(axes)

        bspec = _m(batch)
        ew = P(_m(ep_axes), None, None)
        shared_specs = None
        if shared is not None:
            shared_specs = {"w_gate": P(None, "model"),
                            "w_up": P(None, "model"),
                            "w_down": P("model", None)}
        body = functools.partial(_moe_body_ep_all, cfg=cfg,
                                 ep_axes=ep_axes, gather_axes=batch)
        out, aux = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, None), P(None, None), ew, ew, ew,
                      shared_specs),
            out_specs=(P(bspec, None), P()),
            check_vma=False,
        )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)
        return out.reshape(b, s, d), aux

    fsdp = ("pod", "data") if cfg.fsdp_pod else ("data",)
    total = 1
    resolved = []
    for a in fsdp:
        if a in mesh.axis_names:
            resolved.append(a)
            total *= sharding.current_mesh_axis_size(a)
    fsdp = tuple(resolved) if (resolved and cfg.moe_d_ff % total == 0) else ()

    def _m(axes):
        if not axes:
            return None
        return axes[0] if len(axes) == 1 else tuple(axes)

    batch = sharding.batch_axes(mesh)
    bspec = _m(batch)
    ew = P("model", None, _m(fsdp))
    ewd = P("model", _m(fsdp), None)
    shared_specs = None
    if shared is not None:
        shared_specs = {"w_gate": P(None, "model"), "w_up": P(None, "model"),
                        "w_down": P("model", None)}
    body = functools.partial(_moe_body, cfg=cfg, axis_name="model",
                             fsdp_axes=fsdp, batch_axes=batch)
    out, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None), P(None, None), ew, ew, ewd, shared_specs),
        out_specs=(P(bspec, None), P()),
        check_vma=False,
    )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)
    return out.reshape(b, s, d), aux
