"""Parameter-definition machinery and elementary layers (pure JAX)."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Parameter definitions.  Modules describe their parameters declaratively so
# that (a) init, (b) logical-axis pspecs and (c) abstract eval_shape trees all
# come from one source of truth.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"         # fan_in | normal | zeros | ones
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


DefTree = Union[ParamDef, Dict[str, "DefTree"]]


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def map_defs(fn, tree: DefTree):
    return jax.tree.map(fn, tree, is_leaf=_is_def)


def stack_defs(tree: DefTree, n: int) -> DefTree:
    """Prepend a scan-stacked layer dimension to every leaf."""
    return map_defs(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale),
        tree)


def axes_tree(tree: DefTree):
    return map_defs(lambda d: d.axes, tree)


def shape_tree(tree: DefTree, dtype) -> DefTree:
    return map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), tree)


def init_tree(tree: DefTree, key: jax.Array, dtype) -> DefTree:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            v = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            v = jnp.ones(d.shape, dtype)
        else:
            if d.init == "fan_in":
                fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
                std = d.scale / math.sqrt(max(fan_in, 1))
            else:
                std = d.scale * 0.02
            v = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


# --------------------------------------------------------------------------
# Elementary ops.  Norms run in f32; matmuls accumulate in f32.
# --------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dt)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def mlp_defs(cfg, d_model: Optional[int] = None, d_ff: Optional[int] = None) -> DefTree:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        return {
            "w_gate": ParamDef((d, f), ("embed", "mlp")),
            "w_up": ParamDef((d, f), ("embed", "mlp")),
            "w_down": ParamDef((f, d), ("mlp", "embed")),
        }
    return {  # gelu two-matrix MLP (musicgen / starcoder2 style)
        "w_up": ParamDef((d, f), ("embed", "mlp")),
        "b_up": ParamDef((f,), ("mlp",), "zeros"),
        "w_down": ParamDef((f, d), ("mlp", "embed")),
        "b_down": ParamDef((d,), ("embed",), "zeros"),
    }


def mlp_apply(p, x: jax.Array, cfg) -> jax.Array:
    if cfg.mlp_kind == "swiglu":
        g = dense(x, p["w_gate"])
        u = dense(x, p["w_up"])
        return dense(jax.nn.silu(g) * u, p["w_down"])
    h = jax.nn.gelu(dense(x, p["w_up"], p["b_up"]))
    return dense(h, p["w_down"], p["b_down"])


# --------------------------------------------------------------------------
# Rotary position embeddings (llama split-half convention).
# --------------------------------------------------------------------------
def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]                        # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding / logits.
# --------------------------------------------------------------------------
def embed_defs(cfg) -> DefTree:
    defs: Dict[str, DefTree] = {
        "embedding": ParamDef((cfg.padded_vocab, cfg.d_model),
                              ("in_vocab", "mlp"), "normal"),
        "final_norm": ParamDef((cfg.d_model,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.padded_vocab),
                                   ("embed", "vocab"))
    return defs


def embed_tokens(p, tokens: jax.Array, cfg) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def logits_from_hidden(p, x: jax.Array, cfg) -> jax.Array:
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    w = p["embedding"].T if cfg.tie_embeddings else p["lm_head"]
    return jnp.einsum("...d,dv->...v", x, w, preferred_element_type=jnp.float32)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          vocab_size: int) -> jax.Array:
    """Mean CE over tokens; padded vocab columns are masked out of the lse."""
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab_size:
        pad = logits.shape[-1] - vocab_size
        mask = jnp.concatenate([jnp.zeros((vocab_size,), jnp.float32),
                                jnp.full((pad,), -1e30, jnp.float32)])
        logits = logits + mask
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
