"""Quasilinear quantity-of-interest integral (paper eq. (5)).

    Q_ql = Q0 * Lambda^(a-1) * (1/(rho* c_s)) *
           Int dk_y (1/theta0_max) Int_0^theta0_max dtheta0
              [ Q_l(k_y, theta0) / Q_l(k_y, theta0) ]_s * Lambda_hat(k_y, theta0)

The integrand needs the linear growth rate / mode frequency at every
quadrature node (k_y, theta0) — each node is one forward-model evaluation
(GS2 proxy or GP surrogate), which is exactly the mixed-cost workload the
paper schedules.  Two estimators:

  * `quadrature`: tensor-product trapezoid over a (k_y, theta0) grid; the
    node evaluations are returned as a request list so the load balancer
    can distribute them (the paper's end-goal workload).
  * `bayesian_quadrature`: a GP over the integrand with max-variance
    acquisition — adaptive node placement, integral mean +/- uncertainty
    (the paper's 'future exploration' adaptive setting, §VI).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.uq import gp as gp_lib

Q0 = 1.0
ALPHA = 1.5
RHO_STAR_CS = 1.0
THETA0_MAX = np.pi / 2


def saturation_weight(ky: np.ndarray, theta0: np.ndarray) -> np.ndarray:
    """Lambda_hat(k_y, theta0): the saturation-rule spectral weight.

    Standard quasilinear shape: peaked at intermediate k_y, decaying with
    ballooning angle (cf. eq. (3.6) of Giacomin et al. 2024)."""
    return (ky ** 2 / (1.0 + ky ** 4)) * np.exp(-0.5 * (theta0 / 0.7) ** 2)


def quasilinear_integrand(growth: np.ndarray, freq: np.ndarray,
                          ky: np.ndarray, theta0: np.ndarray) -> np.ndarray:
    """Flux-ratio integrand from linear-mode outputs: unstable modes
    (growth > 0) contribute gamma/k_y^2-weighted flux."""
    gamma_eff = np.maximum(growth, 0.0)
    flux_ratio = gamma_eff / (1.0 + 0.2 * np.abs(freq))
    return flux_ratio * saturation_weight(ky, theta0)


@dataclasses.dataclass
class QoIResult:
    value: float
    n_evals: int
    uncertainty: float = 0.0


def quadrature_nodes(base_params: np.ndarray, n_ky: int = 8,
                     n_theta0: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Return ([n_ky*n_theta0, 7] model inputs, [n,2] (ky,theta0) nodes).

    base_params fixes the 5 thermodynamic inputs; the integration runs
    over (binormal wavelength k_y, ballooning angle theta0 ~ folded into
    magnetic shear offset) per the quasilinear rule."""
    kys = np.linspace(0.1, 1.0, n_ky)
    th0s = np.linspace(0.0, THETA0_MAX, n_theta0)
    grid = np.stack(np.meshgrid(kys, th0s, indexing="ij"), -1).reshape(-1, 2)
    inputs = np.tile(np.asarray(base_params, float), (len(grid), 1))
    inputs[:, 6] = grid[:, 0]                        # k_y
    inputs[:, 1] = inputs[:, 1] + 0.3 * grid[:, 1]   # theta0 -> shear offset
    return inputs, grid


def integrate_from_evals(outputs: Sequence[Sequence[float]],
                         nodes: np.ndarray, n_ky: int,
                         n_theta0: int) -> QoIResult:
    """Trapezoid the integrand given model outputs at the grid nodes."""
    out = np.asarray(outputs, float)
    growth, freq = out[:, 0], out[:, 1]
    f = quasilinear_integrand(growth, freq, nodes[:, 0], nodes[:, 1])
    f = f.reshape(n_ky, n_theta0)
    kys = np.linspace(0.1, 1.0, n_ky)
    th0s = np.linspace(0.0, THETA0_MAX, n_theta0)
    inner = np.trapezoid(f, th0s, axis=1) / THETA0_MAX
    outer = np.trapezoid(inner, kys)
    value = Q0 * (1.0 ** (ALPHA - 1)) / RHO_STAR_CS * outer
    return QoIResult(value=float(value), n_evals=len(out))


def quadrature(model_fn: Callable[[np.ndarray], Tuple[float, float]],
               base_params: np.ndarray, n_ky: int = 8, n_theta0: int = 8
               ) -> QoIResult:
    """Direct tensor-quadrature estimator (embarrassingly parallel nodes)."""
    inputs, nodes = quadrature_nodes(base_params, n_ky, n_theta0)
    outputs = [model_fn(x) for x in inputs]
    return integrate_from_evals(outputs, nodes, n_ky, n_theta0)


def bayesian_quadrature(model_fn: Callable[[np.ndarray], Tuple[float, float]],
                        base_params: np.ndarray, n_init: int = 6,
                        n_adaptive: int = 10, seed: int = 0,
                        candidate_grid: int = 16,
                        backend: str = "exact") -> QoIResult:
    """Adaptive GP quadrature: start from a small LHS design over
    (k_y, theta0), repeatedly evaluate the max-posterior-variance node,
    estimate the integral from the GP mean on a dense grid.  The
    dependency chain (each new node depends on the GP conditioned on all
    previous) is the paper's 'loosely dependent tasks' future workload.

    `backend` selects the conditioning engine (`repro.uq.engine`): the
    acquisition loop conditions once per node, so "incremental" turns
    its cumulative cost from O(Σn³) to O(Σn²); "exact" (default) is the
    reference refit path."""
    from repro.uq import engine as engine_lib
    rng = np.random.default_rng(seed)
    lo = np.array([0.1, 0.0])
    hi = np.array([1.0, THETA0_MAX])

    def eval_node(node: np.ndarray) -> float:
        x = np.asarray(base_params, float).copy()
        x[6] = node[0]
        x[1] = x[1] + 0.3 * node[1]
        g, fq = model_fn(x)
        return float(quasilinear_integrand(np.array(g), np.array(fq),
                                           node[0], node[1]))

    nodes = lo + rng.random((n_init, 2)) * (hi - lo)
    vals = np.array([eval_node(nd) for nd in nodes])
    engine = engine_lib.fit_engine(nodes, vals, backend, steps=100)

    cand = np.stack(np.meshgrid(np.linspace(0.1, 1.0, candidate_grid),
                                np.linspace(0.0, THETA0_MAX, candidate_grid),
                                indexing="ij"), -1).reshape(-1, 2)
    for _ in range(n_adaptive):
        _, var = engine.predict(cand)
        nxt = cand[int(np.argmax(np.asarray(var)[:, 0]))]   # var is [S, M=1]
        engine = engine.condition(nxt[None], np.array([eval_node(nxt)]))

    mean, var = engine.predict(cand)
    f = np.asarray(mean)[:, 0].reshape(candidate_grid, candidate_grid)
    kys = np.linspace(0.1, 1.0, candidate_grid)
    th0s = np.linspace(0.0, THETA0_MAX, candidate_grid)
    inner = np.trapezoid(f, th0s, axis=1) / THETA0_MAX
    value = Q0 / RHO_STAR_CS * np.trapezoid(inner, kys)
    # integral-uncertainty proxy: mean posterior sd over the grid x volume
    vol = (hi[0] - lo[0])
    unc = float(np.mean(np.sqrt(np.asarray(var))) * vol / THETA0_MAX)
    return QoIResult(value=float(value), n_evals=n_init + n_adaptive,
                     uncertainty=unc)
