"""eigen-100 / eigen-5000 benchmark tasks (paper §IV-B).

Dense non-symmetric eigenproblems solved with numpy.linalg.eig (LAPACK
_geev), memory-bound, deterministic per seed: 'matrices in the eigen-100
benchmark are the same for all 100 evaluations'.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.task import Model


def make_matrix(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) / np.sqrt(n)


def solve_eigen(a: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    return np.linalg.eig(a)


class EigenModel(Model):
    """UM-Bridge model wrapping the eigenproblem.  Input: a seed scalar;
    output: the spectral abscissa + spectral radius (2 scalars)."""

    def __init__(self, n: int, fixed_seed: Optional[int] = 0):
        super().__init__(f"eigen-{n}")
        self.n = n
        self.fixed_seed = fixed_seed
        self._cache: Dict[int, np.ndarray] = {}

    def get_input_sizes(self, config=None) -> List[int]:
        return [1]

    def get_output_sizes(self, config=None) -> List[int]:
        return [2]

    def _matrix(self, seed: int) -> np.ndarray:
        if seed not in self._cache:
            self._cache[seed] = make_matrix(self.n, seed)
        return self._cache[seed]

    def __call__(self, parameters, config=None):
        seed = (self.fixed_seed if self.fixed_seed is not None
                else int(parameters[0][0]))
        vals, _ = solve_eigen(self._matrix(seed))
        return [[float(np.max(vals.real)), float(np.max(np.abs(vals)))]]

    def cost_hint(self, parameters, config=None) -> float:
        # O(n^3) with LAPACK geev constants measured on the testbed
        return 2.5e-10 * self.n ** 3

    def warmup(self):
        self._matrix(self.fixed_seed if self.fixed_seed is not None else 0)
