"""Metropolis-Hastings over the forward model, scheduled as a DEPENDENT
task chain (paper §II-C: "MCMC methods require a well-defined dependency
structure ... each step depends on the results of the previous").

Each proposal evaluation is an `EvalRequest` whose `depends_on` points at
the previous accepted state's evaluation — the executor releases it only
when its predecessor completes, so the chain structure lives in the
scheduler, not in client-side blocking.  Multiple independent chains
interleave freely across the worker pool (the standard multi-chain UQ
pattern the HQ backend is built for).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executor import Executor
from repro.core.task import EvalRequest


@dataclasses.dataclass
class MCMCResult:
    samples: np.ndarray              # [n_kept, d]
    log_likelihoods: np.ndarray      # [n_kept]
    accept_rate: float
    n_evals: int


def gaussian_loglike(output: Sequence[float], observed: Sequence[float],
                     sigma: float = 0.1) -> float:
    out = np.asarray(output, float)
    obs = np.asarray(observed, float)
    return float(-0.5 * np.sum((out - obs) ** 2) / sigma ** 2)


def run_chain(executor: Executor, model_name: str, *,
              x0: np.ndarray, bounds: Sequence[Tuple[float, float]],
              observed: Sequence[float], n_steps: int = 50,
              step_scale: float = 0.1, sigma: float = 0.1,
              seed: int = 0, timeout: float = 600.0) -> MCMCResult:
    """One MH chain; evaluations flow through the scheduler with explicit
    dependency edges."""
    rng = np.random.default_rng(seed)
    lo = np.array([b[0] for b in bounds])
    hi = np.array([b[1] for b in bounds])
    scale = step_scale * (hi - lo)

    def propose(x):
        return np.clip(x + rng.normal(size=x.shape) * scale, lo, hi)

    # initial evaluation
    req = EvalRequest(model_name, [list(map(float, x0))])
    executor.submit(req)
    res = executor.result(req.task_id, timeout)
    if res.status != "ok":
        raise RuntimeError(f"initial evaluation failed: {res.error}")
    x, ll = np.asarray(x0, float), gaussian_loglike(res.value[0], observed,
                                                    sigma)
    prev_task = req.task_id

    samples, lls = [x.copy()], [ll]
    accepts, n_evals = 0, 1
    for _ in range(n_steps):
        xp = propose(x)
        req = EvalRequest(model_name, [xp.tolist()],
                          depends_on=(prev_task,))
        executor.submit(req)
        res = executor.result(req.task_id, timeout)
        n_evals += 1
        if res.status == "ok":
            llp = gaussian_loglike(res.value[0], observed, sigma)
            if math.log(max(rng.random(), 1e-300)) < llp - ll:
                x, ll = xp, llp
                accepts += 1
                prev_task = req.task_id
        samples.append(x.copy())
        lls.append(ll)
    return MCMCResult(samples=np.asarray(samples),
                      log_likelihoods=np.asarray(lls),
                      accept_rate=accepts / max(n_steps, 1),
                      n_evals=n_evals)


def run_chains(executor: Executor, model_name: str, *,
               x0s: Sequence[np.ndarray], **kw) -> List[MCMCResult]:
    """Multiple chains; their dependent requests interleave across the
    pool (chains are independent; steps within a chain are ordered)."""
    import threading
    out: List[Optional[MCMCResult]] = [None] * len(x0s)

    def _one(i):
        out[i] = run_chain(executor, model_name, x0=x0s[i],
                           seed=kw.pop("seed", 0) + i if "seed" in kw
                           else i, **{k: v for k, v in kw.items()
                                      if k != "seed"})

    threads = [threading.Thread(target=_one, args=(i,))
               for i in range(len(x0s))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return list(out)  # type: ignore[return-value]
