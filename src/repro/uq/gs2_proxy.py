"""GS2 proxy: a JAX linear gyrokinetic-stability forward model.

GS2 itself is a Fortran code solving the 5-D Vlasov-Maxwell system; what
matters to *this* paper is its scheduling profile: an initial-value solver
whose runtime varies unpredictably (minutes to hours) with seven physics
inputs because it iterates until an unstable mode converges.

The proxy keeps exactly that profile.  It discretises a 1-D
ballooning-space mode equation along the field line into an m x m operator
A(theta) built from the paper's Table II inputs (safety factor, shear,
density/temperature gradients, beta, collisionality, binormal wavelength)
and runs an initial-value power iteration under `lax.while_loop` until the
dominant-mode growth rate converges.  The spectral gap of A — and hence
the iteration count, and hence the runtime — depends strongly and
non-obviously on the inputs: the milliseconds->seconds spread on CPU has
the same ~100-1000x dynamic range as GS2's minutes->hours.

Outputs mirror the GP surrogate's: (growth rate, mode frequency).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_RESOLUTION = 96
MAX_ITERS = 20_000
TOL = 1e-9

# GS2-equivalent calibration: wall-clock grows superlinearly in proxy
# iterations (GS2 must resolve marginal modes on finer time grids), scaled
# so the induced runtime distribution spans the paper's [1, 180] minute
# band with a long right tail.
GS2_RUNTIME_SCALE = 0.0143
GS2_RUNTIME_POWER = 2.0


def build_operator(theta: jax.Array, m: int = DEFAULT_RESOLUTION) -> jax.Array:
    """Assemble the m x m ballooning-mode operator from the 7 inputs."""
    q, shear, dens_grad, temp_grad, beta, nu, ky = (theta[i] for i in range(7))
    ky = 0.05 + ky                                 # avoid the ky=0 degeneracy
    grid = jnp.linspace(-jnp.pi, jnp.pi, m)
    # field-line bending: -(d^2/dtheta^2) with shear-dependent metric
    h = grid[1] - grid[0]
    bend = (1.0 + (shear * grid - beta * q * jnp.sin(grid)) ** 2) / (q * q)
    lap = (jnp.diag(jnp.full(m - 1, 1.0), 1) + jnp.diag(jnp.full(m - 1, 1.0), -1)
           - 2.0 * jnp.eye(m)) / (h * h)
    # instability drive: curvature * pressure gradients, localised at the
    # outboard midplane; damping: collisions + FLR
    drive = (ky * (temp_grad + 0.4 * dens_grad)
             * (jnp.cos(grid) + (shear * grid - beta * q * jnp.sin(grid))
                * jnp.sin(grid)))
    damp = nu * 12.0 + 0.15 * ky * ky
    # bending is stabilising: +bend * lap (lap is negative-definite)
    a = (jnp.diag(bend) @ lap * 0.05
         + jnp.diag(drive) * 0.5
         - damp * jnp.eye(m))
    # mode coupling (off-diagonal, shear-driven) makes the spectrum -- and
    # the power-iteration convergence rate -- a non-obvious function of
    # the inputs
    couple = 0.08 * shear * (jnp.diag(jnp.cos(grid[:-1]), 1)
                             - jnp.diag(jnp.cos(grid[:-1]), -1))
    return a + couple


@functools.partial(jax.jit, static_argnames=("m",))
def solve(theta: jax.Array, m: int = DEFAULT_RESOLUTION
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Initial-value iteration -> (growth_rate, frequency, n_iters)."""
    a = build_operator(theta, m)
    # shifted power iteration on exp(A dt) ~ (I + dt A): the dominant
    # eigenvalue's real part is the growth rate.  dt respects the explicit
    # stability bound (Gershgorin radius) so stiff, strongly-sheared cases
    # stay stable at any resolution — they just take more iterations,
    # which is exactly GS2's runtime profile.
    gersh = jnp.max(jnp.sum(jnp.abs(a), axis=1))
    dt = jnp.minimum(0.02, 0.5 / jnp.maximum(gersh, 1e-6))
    prop = jnp.eye(m) + dt * a + 0.5 * dt * dt * (a @ a)
    v0 = jnp.ones((m,)) / jnp.sqrt(m)

    def cond(state):
        _, lam, lam_prev, it = state
        return (jnp.abs(lam - lam_prev) > TOL) & (it < MAX_ITERS)

    def body(state):
        v, lam, _, it = state
        w = prop @ v
        nrm = jnp.linalg.norm(w)
        v_new = w / jnp.maximum(nrm, 1e-30)
        lam_new = jnp.log(jnp.maximum(nrm, 1e-30)) / dt
        return v_new, lam_new, lam, it + 1

    v, lam, _, iters = jax.lax.while_loop(
        cond, body, (v0, jnp.float32(0.0), jnp.float32(jnp.inf), 0))
    growth = lam
    # mode frequency: Rayleigh-quotient imaginary proxy via the
    # antisymmetric part of A
    asym = 0.5 * (a - a.T)
    freq = v @ (asym @ v)
    return growth, freq, iters


def evaluate(theta, m: int = DEFAULT_RESOLUTION) -> Tuple[float, float]:
    g, f, _ = solve(jnp.asarray(theta, jnp.float32), m)
    return float(g), float(f)


_solver_salt = [0]


def make_solver(m: int = DEFAULT_RESOLUTION):
    """A FRESH jitted solver (private executable cache).  Model servers
    use this so that 'fresh server per task' really pays the compile —
    the module-level `solve` shares its cache across instances, and jax
    also memoises compilations by HLO hash, so a unique compile-time salt
    is folded in (emulating the cold process a fresh SLURM job gets)."""
    _solver_salt[0] += 1
    salt = float(_solver_salt[0])

    def _solve_salted(theta, m):
        # +salt −salt: numerically a no-op that XLA folds away, but it
        # lands in the unoptimised HLO, so the compile cache misses
        return solve.__wrapped__((theta + salt) - salt, m)

    fresh = jax.jit(_solve_salted, static_argnames=("m",))

    def _eval(theta) -> Tuple[float, float]:
        g, f, _ = fresh(jnp.asarray(theta, jnp.float32), m)
        return float(g), float(f)

    return _eval


def iteration_count(theta, m: int = DEFAULT_RESOLUTION) -> int:
    _, _, it = solve(jnp.asarray(theta, jnp.float32), m)
    return int(it)


def gs2_equivalent_runtime(theta, m: int = DEFAULT_RESOLUTION,
                           floor_s: float = 60.0,
                           cap_s: float = 10_800.0) -> float:
    """Map the proxy's iteration count onto GS2's wall-clock band
    ([1, 180] minutes on 8 cores, Table III) for the scheduling simulator."""
    it = iteration_count(theta, m)
    return float(np.clip(GS2_RUNTIME_SCALE * it ** GS2_RUNTIME_POWER,
                         floor_s, cap_s))


def runtime_table(thetas: np.ndarray, m: int = DEFAULT_RESOLUTION
                  ) -> np.ndarray:
    return np.array([gs2_equivalent_runtime(t, m) for t in thetas])
