"""Pluggable surrogate engines: exact, incremental, and partitioned GPs.

`repro.uq.gp` is the scheduler's brain — runtime prediction
(`sched.predictor`), offload trust gates (`sched.offload`), adaptive
delegation and Bayesian quadrature (`uq.adaptive` / `uq.qoi`) all
condition one posterior online.  Every one of those consumers used to
pay an exact Cholesky refit — O(n³) per update — so at the 10⁵–10⁶
completions the paper's UQ workloads produce, the surrogate becomes the
bottleneck PR 5 removed from the queues.  This module makes the
conditioning path pluggable behind one `SurrogateEngine` interface:

  * ``exact`` — the reference: every `condition` is a full
    re-factorisation (`gp.recondition`).  O(n³) per update, bitwise the
    pre-refactor behaviour; the default everywhere.
  * ``incremental`` — rank-k block Cholesky *updates*: conditioning on
    a batch of k new points extends the existing factor L (and its
    cached inverse, so `predict_batch` never re-inverts) in O(n²k)
    instead of refactoring in O(n³).  Periodic full re-factorisation
    (``refactor_every``) plus a finite-ness check keep f32 drift and
    near-singular blocks from accumulating — the same hygiene HPC
    always-on services apply to refit-from-scratch state (Balsam,
    PAPERS.md).
  * ``partitioned`` — a local-GP ensemble routed by input region:
    recursive median splits bound every expert at ``expert_cap``
    points, so conditioning is O(cap³) *per affected expert* no matter
    how large the training set grows, and predict fans out through ONE
    fused multi-expert launch (`kops.gp_predict_experts`, Pallas on
    TPU) with optional multi-device sharding over the expert axis.
    Predictions are approximate (each query answered by its region's
    expert); the differential suite bounds the error.

Engines are *persistent* (functional): `condition` / `recondition`
return a NEW engine sharing hyperparameters, so the thread-safety
patterns the consumers already use (install-if-not-raced under a lock,
expensive math outside it) carry over unchanged.  Every engine keeps
the `gp.predict_batch` bucket discipline — scoring any queue costs a
bounded set of compile shapes.

Backend choice in one line: ``exact`` until conditioning shows up in a
profile; ``incremental`` when one posterior must absorb an unbounded
completion stream; ``partitioned`` when the training set itself must
scale past what one Cholesky can hold.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, List, Optional, Protocol, Sequence, Tuple, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.uq import gp as gp_lib

BACKENDS = ("exact", "incremental", "partitioned")


@runtime_checkable
class SurrogateEngine(Protocol):
    """What every consumer of the posterior needs from a backend."""

    backend: str

    def n_train(self) -> int: ...
    def dim(self) -> int: ...
    def n_outputs(self) -> int: ...
    def condition(self, x_new, y_new) -> "SurrogateEngine": ...
    def recondition(self, x, y) -> "SurrogateEngine": ...
    def predict(self, x_star) -> Tuple[jax.Array, jax.Array]: ...
    def predict_batch(self, x_star) -> Tuple[jax.Array, jax.Array]: ...
    def latent_sd(self, thetas) -> np.ndarray: ...


class _EngineBase:
    """Shared surface: data views and the latent-sd trust metric."""

    backend = "base"

    # subclasses define .x / .y / .y_std / .kind / .params
    def n_train(self) -> int:
        return int(self.x.shape[0])

    def dim(self) -> int:
        return int(self.x.shape[1])

    def n_outputs(self) -> int:
        return int(self.y.shape[1])

    def latent_sd(self, thetas) -> np.ndarray:
        """Standardised (latent) posterior sd at each theta: the
        dimensionless trust metric the offload gate thresholds — one
        bucket-padded `predict_batch` pass for the whole batch."""
        _, var = self.predict_batch(np.asarray(thetas, np.float32))
        return (np.sqrt(np.asarray(var)[:, 0])
                / max(float(self.y_std[0]), 1e-12))

    def warm(self) -> None:
        """Pre-compile the single-row predict bucket (push-time trust
        checks run under dispatch locks — never stall them on XLA)."""
        try:
            self.predict_batch(np.asarray(self.x[:1], np.float32))
        except Exception:  # noqa: BLE001 — warmup is best-effort
            pass


# ===========================================================================
# exact — the O(n³) reference path
# ===========================================================================
class ExactEngine(_EngineBase):
    """The pre-refactor behaviour behind the engine interface: every
    `condition` re-factorises from scratch (`gp.recondition`, one fresh
    O(n³) Cholesky), with the same most-recent-``max_points`` window the
    consumers applied by hand.  Kept as the differential reference the
    other backends are pinned against."""

    backend = "exact"

    def __init__(self, post: gp_lib.GPPosterior, *,
                 max_points: Optional[int] = None):
        self.post = post
        self.max_points = max_points

    # -- views -----------------------------------------------------------
    @property
    def x(self):
        return self.post.x

    @property
    def y(self):
        return self.post.y

    @property
    def y_std(self):
        return self.post.y_std

    @property
    def params(self):
        return self.post.params

    @property
    def kind(self):
        return self.post.kind

    # -- predict ---------------------------------------------------------
    def predict(self, x_star):
        return gp_lib.predict(self.post, x_star)

    def predict_batch(self, x_star):
        return gp_lib.predict_batch(self.post, x_star)

    # -- conditioning ----------------------------------------------------
    def _merged(self, x_new, y_new):
        x_new, y_new2 = gp_lib.coerce_new_data(x_new, y_new)
        x_all = jnp.concatenate([self.post.x, x_new])
        y_all = jnp.concatenate([self.post.y, y_new2])
        if self.max_points and x_all.shape[0] > self.max_points:
            x_all = x_all[-self.max_points:]   # keep the most recent
            y_all = y_all[-self.max_points:]
        return x_all, y_all

    def condition(self, x_new, y_new) -> "ExactEngine":
        x_all, y_all = self._merged(x_new, y_new)
        return type(self)(gp_lib.recondition(self.post, x_all, y_all),
                          max_points=self.max_points)

    def recondition(self, x, y) -> "ExactEngine":
        return type(self)(gp_lib.recondition(self.post, x, y),
                          max_points=self.max_points)


# ===========================================================================
# incremental — rank-k block Cholesky updates
# ===========================================================================
def _np_params(params: gp_lib.GPParams) -> Tuple[np.ndarray, float, float]:
    """(lengthscale, variance, jitter) with the SAME clips and diagonal
    load as `gp._chol_factor` — the block update must extend the factor
    the exact path would have built."""
    ls = np.exp(np.clip(np.asarray(params.log_lengthscale, np.float32),
                        -5.0, 5.0))
    var = float(np.exp(np.clip(float(params.log_variance), -8.0, 8.0)))
    s2 = float(np.exp(2.0 * np.clip(float(params.log_noise), -5.0, 5.0)))
    return ls, var, s2 + 1e-5 * (var + 1.0)


def _np_kernel(params: gp_lib.GPParams, x1: np.ndarray, x2: np.ndarray,
               kind: str) -> np.ndarray:
    """`kernels.ref.gp_kernel_matrix` in numpy (f32, same formulas) —
    the update path stays off the XLA eager dispatcher entirely."""
    import math
    ls, var, _ = _np_params(params)
    x1s = (x1 / ls).astype(np.float32)
    x2s = (x2 / ls).astype(np.float32)
    d2 = ((x1s ** 2).sum(-1)[:, None] + (x2s ** 2).sum(-1)[None, :]
          - 2.0 * x1s @ x2s.T)
    d2 = np.maximum(d2, 0.0)
    if kind == "rbf":
        k = np.exp(-0.5 * d2)
    elif kind == "matern52":
        r = np.sqrt(d2 + 1e-12)
        k = (1.0 + math.sqrt(5.0) * r + 5.0 / 3.0 * d2) \
            * np.exp(-math.sqrt(5.0) * r)
    else:
        raise ValueError(kind)
    return (var * k).astype(np.float32)


def _np_solve_tri(a: np.ndarray, b: np.ndarray,
                  trans: str = "N") -> np.ndarray:
    import scipy.linalg
    return scipy.linalg.solve_triangular(a, b, lower=True, trans=trans,
                                         check_finite=False)


def _np_alpha(chol: np.ndarray, yn: np.ndarray) -> np.ndarray:
    """K⁻¹yn by two backward-stable triangular solves (LAPACK) — the
    explicit-inverse product (linvᵀ(linv·yn)) loses ~cond(K)·eps of
    accuracy, which is exactly the drift the differential suite pins."""
    return _np_solve_tri(chol, _np_solve_tri(chol, yn), trans="T")


class _IncrementalState:
    """Growable append-only numpy storage for one factor lineage.

    The Cholesky factor, its inverse, and the training window live in
    capacity-padded buffers; each engine generation pins its own fill
    level `n` and reads the [:n] views, which are frozen the moment they
    are written — appending rows [n, n+k) never touches them, so every
    generation's view stays valid forever (persistence without copying
    O(n²) state per update).  Appends go through `append` under the
    lock: only the lineage tip may extend in place; a raced or forked
    append — or one past capacity — copies the prefix into fresh
    buffers (amortised by 1.25x capacity slack) and extends there."""

    def __init__(self, n: int, cap: int, d: int, m: int):
        self.lock = threading.Lock()
        self.n = n
        self.chol = np.zeros((cap, cap), np.float32)
        self.linv = np.zeros((cap, cap), np.float32)
        self.x = np.zeros((cap, d), np.float32)
        self.y = np.zeros((cap, m), np.float32)

    @classmethod
    def from_arrays(cls, chol, linv, x, y) -> "_IncrementalState":
        n = chol.shape[0]
        st = cls(n, n, x.shape[1], y.shape[1])
        st.chol[:n, :n] = chol
        st.linv[:n, :n] = linv
        st.x[:n] = x
        st.y[:n] = y
        return st

    def _fork(self, n: int, need: int) -> "_IncrementalState":
        cap = max(need, (need * 5) // 4 + 16)
        st = _IncrementalState(n, cap, self.x.shape[1], self.y.shape[1])
        st.chol[:n, :n] = self.chol[:n, :n]
        st.linv[:n, :n] = self.linv[:n, :n]
        st.x[:n] = self.x[:n]
        st.y[:n] = self.y[:n]
        return st

    def append(self, n: int, x_new, y_new, s12, s22, li21, li22
               ) -> Tuple["_IncrementalState", bool]:
        """Write the new factor block after row n; returns the state
        holding the result and whether a fork (copy) was needed."""
        k = x_new.shape[0]
        with self.lock:
            forked = self.n != n or self.chol.shape[0] < n + k
            st = self._fork(n, n + k) if forked else self
            st.chol[n:n + k, :n] = s12.T
            st.chol[n:n + k, n:n + k] = s22
            st.linv[n:n + k, :n] = li21
            st.linv[n:n + k, n:n + k] = li22
            st.x[n:n + k] = x_new
            st.y[n:n + k] = y_new
            st.n = n + k
        return st, forked


class IncrementalEngine(_EngineBase):
    """O(n²k) conditioning by extending the Cholesky factor in place of
    rebuilding it.

    For new points X_k against the factored K_n = L Lᵀ:

        L' = [[L,    0  ],          S21 = (L⁻¹ K(X_n, X_k))ᵀ
              [S21,  S22]],         S22 S22ᵀ = K_kk − S21 S21ᵀ

    and the cached inverse factor extends the same way
    (L'⁻¹ = [[L⁻¹, 0], [−S22⁻¹ S21 L⁻¹, S22⁻¹]]), so the fused
    `predict_batch` path never pays the O(n³) triangular inversion the
    exact engine re-runs after every update.  The observation
    standardisation and alpha are recomputed over the full window —
    two O(n²m) BLAS products against the maintained inverse, not a
    refactor.

    The factor lineage lives in `_IncrementalState`'s growable numpy
    buffers: an update computes three O(n²k) BLAS products and WRITES
    only the O(nk) new block (old generations keep reading their frozen
    prefix views), so per-batch cost is two orders of magnitude under
    an O(n³) refactorisation — and entirely off the XLA eager
    dispatcher, whose CPU triangular solves and whole-matrix rebuilds
    were costing nearly as much as the refactor they replaced.  The
    predict paths still run through `gp.predict_batch` (bucketed fused
    launches) against a per-generation lazily materialised
    `GPPosterior`.

    Numerical hygiene: every ``refactor_every`` updates — and whenever
    the update block comes out non-positive-definite (near-singular
    S22) or the recency window slides (`max_points`) — the engine falls
    back to one exact re-factorisation, bounding f32 drift.
    """

    backend = "incremental"

    def __init__(self, post: Optional[gp_lib.GPPosterior] = None, *,
                 max_points: Optional[int] = None,
                 refactor_every: int = 64,
                 _internal: Optional[tuple] = None):
        self.max_points = max_points
        self.refactor_every = refactor_every
        self._post_cache: Optional[gp_lib.GPPosterior] = None
        if _internal is not None:
            (self.params, self.kind, self.y_mean, self.y_std,
             self._state, self._n, self._alpha, self._updates,
             self.stats) = _internal
            return
        self.params = post.params
        self.kind = post.kind
        self.y_mean = np.asarray(post.y_mean, np.float32)
        self.y_std = np.asarray(post.y_std, np.float32)
        chol = np.asarray(post.chol, np.float32)
        linv = post.linv
        linv = np.asarray(linv, np.float32) if linv is not None else \
            _np_solve_tri(chol, np.eye(chol.shape[0], dtype=np.float32))
        self._state = _IncrementalState.from_arrays(
            chol, linv, np.asarray(post.x, np.float32),
            np.asarray(post.y, np.float32))
        self._n = chol.shape[0]
        self._alpha = np.asarray(post.alpha, np.float32)
        self._updates = 0                      # block updates since refactor
        # carried across persistent copies: diagnostics for tests/benchmarks
        self.stats = {"block_updates": 0, "refactors": 0, "forks": 0}

    def _successor(self, state, n, alpha, y_mean, y_std, *,
                   updates) -> "IncrementalEngine":
        return IncrementalEngine(
            max_points=self.max_points, refactor_every=self.refactor_every,
            _internal=(self.params, self.kind, y_mean, y_std,
                       state, n, alpha, updates, self.stats))

    # -- views -----------------------------------------------------------
    @property
    def x(self) -> np.ndarray:
        return self._state.x[:self._n]

    @property
    def y(self) -> np.ndarray:
        return self._state.y[:self._n]

    @property
    def post(self) -> gp_lib.GPPosterior:
        """This generation's `GPPosterior`, materialised to jax arrays
        on first use (one device copy per conditioning generation, paid
        off the conditioning path) — the predict-side consumers and the
        `.posterior` introspection surface read this."""
        if self._post_cache is None:
            n = self._n
            self._post_cache = gp_lib.GPPosterior(
                params=self.params,
                x=jnp.asarray(self._state.x[:n]),
                y=jnp.asarray(self._state.y[:n]),
                y_mean=jnp.asarray(self.y_mean),
                y_std=jnp.asarray(self.y_std),
                chol=jnp.asarray(self._state.chol[:n, :n]),
                alpha=jnp.asarray(self._alpha), kind=self.kind,
                linv=jnp.asarray(self._state.linv[:n, :n]))
        return self._post_cache

    # -- predict ---------------------------------------------------------
    def predict(self, x_star):
        return gp_lib.predict(self.post, x_star)

    def predict_batch(self, x_star):
        return gp_lib.predict_batch(self.post, x_star)

    # -- conditioning ----------------------------------------------------
    def condition(self, x_new, y_new) -> "IncrementalEngine":
        x_new, y_new2 = gp_lib.coerce_new_data(x_new, y_new)
        x_new = np.asarray(x_new, np.float32)
        y_new2 = np.asarray(y_new2, np.float32)
        n, k = self._n, x_new.shape[0]
        slides = self.max_points and n + k > self.max_points
        if slides or self._updates + 1 >= self.refactor_every:
            x_all = np.concatenate([self.x, x_new])
            y_all = np.concatenate([self.y, y_new2])
            if slides:
                x_all = x_all[-self.max_points:]
                y_all = y_all[-self.max_points:]
            return self._refactor(x_all, y_all)
        st = self._state
        linv_v = st.linv[:n, :n]
        b = _np_kernel(self.params, self.x, x_new, self.kind)  # [n, k]
        _, _, jitter = _np_params(self.params)
        c = _np_kernel(self.params, x_new, x_new, self.kind) \
            + jitter * np.eye(k, dtype=np.float32)
        # L⁻¹b via the maintained inverse factor: a strided BLAS gemm.
        # (solve_triangular on the [n, n] buffer view forces an O(n²)
        # F-contiguous copy per call — the copy, not the math, dominated
        # the conditioning latency at n=5k.)  Drift from the explicit
        # inverse is bounded by the periodic refactor and the Cholesky
        # breakdown fallback below.
        s12 = linv_v @ b                                       # [n, k]
        try:
            s22 = np.linalg.cholesky(c - s12.T @ s12)          # [k, k]
        except np.linalg.LinAlgError:                          # breakdown
            return self._refactor(np.concatenate([self.x, x_new]),
                                  np.concatenate([self.y, y_new2]))
        li22 = _np_solve_tri(s22, np.eye(k, dtype=np.float32))
        li21 = -(li22 @ (s12.T @ linv_v))                      # [k, n]
        state, forked = st.append(n, x_new, y_new2, s12, s22, li21, li22)
        # alpha over the full window: the standardisation tracks the
        # stream (same as exact).  Two strided gemv against the
        # maintained L⁻¹ instead of triangular solves — same copy
        # avoidance as s12 above; the refactor recomputes alpha with
        # backward-stable solves and resets any accumulated drift.
        y_all = state.y[:n + k]
        mean = y_all.mean(axis=0, dtype=np.float32)
        std = np.maximum(y_all.std(axis=0, dtype=np.float32), 1e-8)
        linv2 = state.linv[:n + k, :n + k]
        alpha = linv2.T @ (linv2 @ ((y_all - mean) / std))
        self.stats["block_updates"] += 1
        if forked:
            self.stats["forks"] += 1
        return self._successor(state, n + k, alpha, mean, std,
                               updates=self._updates + 1)

    def recondition(self, x, y) -> "IncrementalEngine":
        y = np.asarray(y, np.float32)
        return self._refactor(np.asarray(x, np.float32),
                              y if y.ndim == 2 else y[:, None])

    def _refactor(self, x_all: np.ndarray, y_all: np.ndarray
                  ) -> "IncrementalEngine":
        n = x_all.shape[0]
        _, _, jitter = _np_params(self.params)
        kmat = _np_kernel(self.params, x_all, x_all, self.kind) \
            + jitter * np.eye(n, dtype=np.float32)
        chol = np.linalg.cholesky(kmat)
        linv = _np_solve_tri(chol, np.eye(n, dtype=np.float32))
        mean = y_all.mean(axis=0, dtype=np.float32)
        std = np.maximum(y_all.std(axis=0, dtype=np.float32), 1e-8)
        alpha = _np_alpha(chol, (y_all - mean) / std)
        state = _IncrementalState.from_arrays(chol, linv, x_all, y_all)
        self.stats["refactors"] += 1
        return self._successor(state, n, alpha, mean, std, updates=0)


# ===========================================================================
# partitioned — region-routed local-GP ensemble
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class _Expert:
    """One local GP: immutable once factored (persistent engines share
    untouched experts across conditioning generations)."""
    x: jax.Array                     # [n, d]
    y: jax.Array                     # [n, m] raw
    chol: jax.Array                  # [n, n]
    alpha: jax.Array                 # [n, m]
    linv: jax.Array                  # [n, n]
    centroid: np.ndarray             # [d] routing key


def _factor_expert(params: gp_lib.GPParams, kind: str, x, y,
                   y_mean, y_std) -> _Expert:
    """Exact factorisation of one cap-bounded expert (O(cap³) — the
    bounded cost the partitioning exists to guarantee) under the SHARED
    standardisation, so expert predictions live on one scale."""
    chol = gp_lib.chol_factor(params, x, kind)
    alpha = jax.scipy.linalg.cho_solve((chol, True), (y - y_mean) / y_std)
    linv = jax.scipy.linalg.solve_triangular(
        chol, jnp.eye(int(x.shape[0]), dtype=jnp.float32), lower=True)
    return _Expert(x=x, y=y, chol=chol, alpha=alpha, linv=linv,
                   centroid=np.asarray(x, np.float64).mean(axis=0))


def _median_parts(x_np: np.ndarray, idx: np.ndarray,
                  cap: int) -> List[np.ndarray]:
    """Recursive median split along the widest dimension until every
    part holds at most `cap` points — deterministic, no RNG."""
    if len(idx) <= cap:
        return [idx]
    sub = x_np[idx]
    dim = int(np.argmax(sub.max(axis=0) - sub.min(axis=0)))
    order = np.argsort(sub[:, dim], kind="stable")
    half = len(idx) // 2
    return (_median_parts(x_np, idx[order[:half]], cap)
            + _median_parts(x_np, idx[order[half:]], cap))


class PartitionedEngine(_EngineBase):
    """Local-GP ensemble routed by nearest expert centroid.

    Every expert holds at most ``expert_cap`` training points, so
    conditioning re-factors only the experts that received new points —
    O(cap³) each, independent of the total training-set size — and an
    expert that outgrows the cap splits at the median of its widest
    dimension.  Predict routes each query to its nearest centroid and
    answers ALL experts' routed queries in one fused stacked launch
    (`kops.gp_predict_experts`: Pallas on TPU, vmapped XLA elsewhere),
    optionally sharded over the expert axis across devices
    (``shard=True``; effective on the XLA path when the expert count
    divides the device count).

    The standardisation (y_mean / y_std) is FROZEN at fit time — experts
    must share one output scale — so unlike exact/incremental the
    normalisation does not track the conditioned stream; the
    differential suite bounds the resulting predictive error.
    ``max_points`` is accepted for interface parity and ignored: memory
    is already bounded per expert, and evicting old regions would
    silently forget calibrated parts of the input space.
    """

    backend = "partitioned"

    def __init__(self, params: gp_lib.GPParams, kind: str, y_mean, y_std,
                 experts: Sequence[_Expert], *, expert_cap: int = 128,
                 shard: bool = False, _stats: Optional[dict] = None):
        self.params = params
        self.kind = kind
        self.y_mean = y_mean
        self.y_std = y_std
        self.experts = list(experts)
        self.expert_cap = int(expert_cap)
        self.shard = shard
        self.stats = _stats if _stats is not None else \
            {"splits": 0, "expert_refactors": 0}
        self._stack = None                     # cached fused-predict operands
        self._centroids = None                 # cached [E, d] routing matrix

    # -- construction ----------------------------------------------------
    @classmethod
    def fit(cls, x, y, *, expert_cap: int = 128, kind: str = "rbf",
            steps: int = 200, lr: float = 5e-2, fit_subsample: int = 512,
            shard: bool = False, **_ignored) -> "PartitionedEngine":
        """Train hyperparameters on a bounded subsample (type-II MLE is
        itself O(steps·n³) — the wall this backend removes), standardise
        over the FULL data, then partition and factor the experts."""
        x = jnp.asarray(x, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        y2 = y if y.ndim == 2 else y[:, None]
        n = int(x.shape[0])
        stride = max(1, -(-n // max(int(fit_subsample), 1)))
        base = gp_lib.fit(x[::stride], y2[::stride], kind=kind,
                          steps=steps, lr=lr)
        y_mean = jnp.mean(y2, axis=0)
        y_std = jnp.maximum(jnp.std(y2, axis=0), 1e-8)
        return cls._build(base.params, kind, y_mean, y_std, x, y2,
                          expert_cap=expert_cap, shard=shard)

    @classmethod
    def from_posterior(cls, post: gp_lib.GPPosterior, *,
                       expert_cap: int = 128, shard: bool = False,
                       **_ignored) -> "PartitionedEngine":
        """Re-partition an already-trained posterior's data under its
        hyperparameters and standardisation."""
        return cls._build(post.params, post.kind, post.y_mean, post.y_std,
                          post.x, post.y, expert_cap=expert_cap,
                          shard=shard)

    @classmethod
    def _build(cls, params, kind, y_mean, y_std, x, y2, *,
               expert_cap: int, shard: bool,
               _stats: Optional[dict] = None) -> "PartitionedEngine":
        x_np = np.asarray(x, np.float64)
        parts = _median_parts(x_np, np.arange(len(x_np)), expert_cap)
        experts = [_factor_expert(params, kind, x[ids], y2[ids],
                                  y_mean, y_std) for ids in parts]
        return cls(params, kind, y_mean, y_std, experts,
                   expert_cap=expert_cap, shard=shard, _stats=_stats)

    # -- views -----------------------------------------------------------
    @property
    def x(self):
        return jnp.concatenate([e.x for e in self.experts])

    @property
    def y(self):
        return jnp.concatenate([e.y for e in self.experts])

    def n_train(self) -> int:
        return sum(int(e.x.shape[0]) for e in self.experts)

    # -- routing ---------------------------------------------------------
    def _route(self, x_star: np.ndarray) -> np.ndarray:
        """Nearest-centroid expert index per query row."""
        if self._centroids is None:
            self._centroids = np.stack([e.centroid for e in self.experts])
        d2 = ((x_star[:, None, :].astype(np.float64)
               - self._centroids[None, :, :]) ** 2).sum(axis=2)
        return np.argmin(d2, axis=1)

    # -- conditioning ----------------------------------------------------
    def condition(self, x_new, y_new) -> "PartitionedEngine":
        x_new, y_new2 = gp_lib.coerce_new_data(x_new, y_new)
        x_np = np.asarray(x_new, np.float64)
        routed = self._route(x_np)
        experts = list(self.experts)
        for eidx in np.unique(routed):
            rows = np.nonzero(routed == eidx)[0]
            e = experts[eidx]
            x_e = jnp.concatenate([e.x, x_new[rows]])
            y_e = jnp.concatenate([e.y, y_new2[rows]])
            if int(x_e.shape[0]) > self.expert_cap:
                # split at the median of the widest dimension: two
                # cap-bounded experts replace the overgrown one
                parts = _median_parts(np.asarray(x_e, np.float64),
                                      np.arange(int(x_e.shape[0])),
                                      self.expert_cap)
                halves = [_factor_expert(self.params, self.kind, x_e[ids],
                                         y_e[ids], self.y_mean, self.y_std)
                          for ids in parts]
                experts[eidx] = halves[0]
                experts.extend(halves[1:])
                self.stats["splits"] += 1
            else:
                experts[eidx] = _factor_expert(self.params, self.kind,
                                               x_e, y_e, self.y_mean,
                                               self.y_std)
            self.stats["expert_refactors"] += 1
        return PartitionedEngine(self.params, self.kind, self.y_mean,
                                 self.y_std, experts,
                                 expert_cap=self.expert_cap,
                                 shard=self.shard, _stats=self.stats)

    def recondition(self, x, y) -> "PartitionedEngine":
        x = jnp.asarray(x, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        y2 = y if y.ndim == 2 else y[:, None]
        return self._build(self.params, self.kind, self.y_mean, self.y_std,
                           x, y2, expert_cap=self.expert_cap,
                           shard=self.shard, _stats=self.stats)

    # -- fused predict ---------------------------------------------------
    def _stacked(self):
        """Stacked fused-predict operands [E, n_max, ...], zero-padded
        (padded training rows are exact: alpha and linv are zero there).
        Cached per engine generation — conditioning returns a NEW engine,
        so a stale stack can never serve post-condition predictions."""
        if self._stack is None:
            n_max = max(int(e.x.shape[0]) for e in self.experts)
            d = self.dim()
            m = self.n_outputs()

            def padded(a, rows, *cols):
                pad = [(0, rows - a.shape[0])] + \
                    [(0, c - s) for c, s in zip(cols, a.shape[1:])]
                return jnp.pad(a, pad)

            xt = jnp.stack([padded(e.x, n_max, d) for e in self.experts])
            al = jnp.stack([padded(e.alpha, n_max, m)
                            for e in self.experts])
            li = jnp.stack([padded(e.linv, n_max, n_max)
                            for e in self.experts])
            self._stack = self._maybe_shard((xt, al, li))
        return self._stack

    def _maybe_shard(self, arrs):
        """Best-effort expert-axis sharding across devices (XLA path);
        silently unsharded when the mesh does not fit."""
        if not self.shard:
            return arrs
        try:
            devs = jax.devices()
            if len(devs) < 2 or len(self.experts) % len(devs):
                return arrs
            from jax.sharding import Mesh, NamedSharding
            from jax.sharding import PartitionSpec as P
            mesh = Mesh(np.array(devs), ("expert",))
            sharding = NamedSharding(mesh, P("expert"))
            return tuple(jax.device_put(a, sharding) for a in arrs)
        except Exception:  # noqa: BLE001
            return arrs

    def predict_batch(self, x_star) -> Tuple[jax.Array, jax.Array]:
        """Route, group by expert, answer every group in fused stacked
        launches.  Each launch carries ALL experts at a bucket-padded
        per-expert query width, so the compile-shape bill is bounded by
        len(PREDICT_BUCKETS) per (expert count, expert size) — the same
        discipline as `gp.predict_batch`."""
        x_star = np.atleast_2d(np.asarray(x_star, np.float32))
        s = x_star.shape[0]
        m = self.n_outputs()
        if s == 0:
            return (jnp.zeros((0, m), jnp.float32),
                    jnp.zeros((0, m), jnp.float32))
        routed = self._route(x_star.astype(np.float64))
        cap = gp_lib.PREDICT_BUCKETS[-1]
        # per-expert query chunks of <= cap rows, answered in rounds of
        # one chunk per expert
        chunks: List[List[np.ndarray]] = []
        for eidx in range(len(self.experts)):
            rows = np.nonzero(routed == eidx)[0]
            chunks.append([rows[lo:lo + cap]
                           for lo in range(0, len(rows), cap)] or [rows])
        xt, al, li = self._stacked()
        ls = jnp.exp(jnp.clip(self.params.log_lengthscale, -5.0, 5.0))
        var = jnp.exp(jnp.clip(self.params.log_variance, -8.0, 8.0))
        mean_out = np.zeros((s, m), np.float32)
        var_out = np.zeros((s, m), np.float32)
        n_rounds = max(len(c) for c in chunks)
        for rnd in range(n_rounds):
            groups = [c[rnd] if rnd < len(c) else c[0][:0] for c in chunks]
            width = max(len(g) for g in groups)
            if width == 0:
                continue
            bucket = gp_lib.bucket_of(width)
            xq = np.zeros((len(groups), bucket, self.dim()), np.float32)
            for e, g in enumerate(groups):
                if len(g):
                    xq[e, :len(g)] = x_star[g]
            key = ("part", len(self.experts), int(xt.shape[1]), bucket)
            gp_lib.predict_batch_shapes[key] += 1
            mean_n, qf = kops.gp_predict_experts(
                xt, jnp.asarray(xq), ls, var, al, li, self.kind)
            mean_n = np.asarray(mean_n)
            lat = np.maximum(np.asarray(qf), 0.0)
            lat = np.maximum(float(var) - lat, 1e-12)
            y_mean = np.asarray(self.y_mean, np.float32)
            y_std = np.asarray(self.y_std, np.float32)
            for e, g in enumerate(groups):
                if not len(g):
                    continue
                mean_out[g] = y_mean[None] + mean_n[e, :len(g)] * y_std[None]
                var_out[g] = lat[e, :len(g), None] * (y_std ** 2)[None, :]
        return jnp.asarray(mean_out), jnp.asarray(var_out)

    def predict(self, x_star) -> Tuple[jax.Array, jax.Array]:
        """Same routed path as `predict_batch` (one code path, one
        numerical behaviour)."""
        return self.predict_batch(x_star)


# ===========================================================================
# factories
# ===========================================================================
def wrap_posterior(post: gp_lib.GPPosterior, backend: str = "exact", *,
                   max_points: Optional[int] = None,
                   **backend_kw) -> SurrogateEngine:
    """Lift an already-trained `GPPosterior` into a backend engine."""
    if backend == "exact":
        return ExactEngine(post, max_points=max_points)
    if backend == "incremental":
        return IncrementalEngine(post, max_points=max_points, **backend_kw)
    if backend == "partitioned":
        return PartitionedEngine.from_posterior(post, **backend_kw)
    raise ValueError(f"unknown surrogate backend {backend!r}; "
                     f"expected one of {BACKENDS}")


def as_engine(obj: Any, backend: str = "exact", *,
              max_points: Optional[int] = None,
              **backend_kw) -> Optional[SurrogateEngine]:
    """Posterior -> engine (via `wrap_posterior`); engines and None pass
    through — the consumers' one-line compatibility shim."""
    if obj is None or isinstance(obj, _EngineBase):
        return obj
    return wrap_posterior(obj, backend, max_points=max_points, **backend_kw)


def fit_engine(x, y, backend: str = "exact", *, kind: str = "rbf",
               steps: int = 200, lr: float = 5e-2,
               max_points: Optional[int] = None,
               **backend_kw) -> SurrogateEngine:
    """Train hyperparameters and return a conditioned engine."""
    if backend == "partitioned":
        return PartitionedEngine.fit(x, y, kind=kind, steps=steps, lr=lr,
                                     **backend_kw)
    post = gp_lib.fit(x, y, kind=kind, steps=steps, lr=lr)
    return wrap_posterior(post, backend, max_points=max_points,
                          **backend_kw)
