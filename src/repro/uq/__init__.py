"""UQ substrate: the paper's applications (GS2 proxy, GP surrogate,
eigenproblem benchmarks, quasilinear QoI integral) plus samplers."""
from repro.uq.engine import (BACKENDS, ExactEngine, IncrementalEngine,
                             PartitionedEngine, SurrogateEngine, as_engine,
                             fit_engine, wrap_posterior)
from repro.uq.sampling import GS2_PARAM_RANGES, halton, latin_hypercube
