"""Seeded samplers: Latin hypercube (the paper's GS2 input sampler) and
Halton quasi-Monte Carlo, over the paper's Table II parameter ranges."""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

# Table II: the seven GS2 input parameters and their ranges.
GS2_PARAM_RANGES: Tuple[Tuple[str, float, float], ...] = (
    ("safety_factor", 2.0, 9.0),
    ("magnetic_shear", 0.0, 5.0),
    ("electron_density_gradient", 0.0, 10.0),
    ("electron_temperature_gradient", 0.5, 6.0),
    ("beta", 0.0, 0.3),                      # plasma/magnetic pressure ratio
    ("collision_frequency", 0.0, 0.1),
    ("binormal_wavelength", 0.0, 1.0),
)


def latin_hypercube(n: int, ranges: Sequence[Tuple[str, float, float]] =
                    GS2_PARAM_RANGES, seed: int = 0) -> np.ndarray:
    """[n, d] LHS sample, seeded for repeatability (paper §IV-B: 'the input
    parameters for GS2 are sampled from a seeded Latin hypercube')."""
    rng = np.random.default_rng(seed)
    d = len(ranges)
    u = (rng.permuted(np.tile(np.arange(n), (d, 1)), axis=1).T
         + rng.random((n, d))) / n
    lo = np.array([r[1] for r in ranges])
    hi = np.array([r[2] for r in ranges])
    return lo + u * (hi - lo)


def _van_der_corput(n: int, base: int) -> np.ndarray:
    out = np.zeros(n)
    for i in range(n):
        f, x, k = 1.0, 0.0, i + 1
        while k > 0:
            f /= base
            x += f * (k % base)
            k //= base
        out[i] = x
    return out


_PRIMES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def halton(n: int, ranges: Sequence[Tuple[str, float, float]] =
           GS2_PARAM_RANGES, skip: int = 20) -> np.ndarray:
    """[n, d] Halton QMC points scaled to `ranges`."""
    d = len(ranges)
    assert d <= len(_PRIMES)
    u = np.stack([_van_der_corput(n + skip, _PRIMES[i])[skip:]
                  for i in range(d)], axis=1)
    lo = np.array([r[1] for r in ranges])
    hi = np.array([r[2] for r in ranges])
    return lo + u * (hi - lo)
