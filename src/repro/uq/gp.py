"""Gaussian-process regression in pure JAX (paper §III-B).

Implements eqs. (3)/(4): posterior mean/variance through a Cholesky solve,
ARD RBF / Matérn-5/2 kernels (covariance assembly via the Pallas
`gp_kernel` on TPU, jnp fallback elsewhere), and marginal-likelihood
training with Adam on log-parameters.  Multi-output (the paper's GP emits
growth rate AND mode frequency) is handled as independent GPs sharing the
kernel matrix — one Cholesky, two solves.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

# Bucketed padding sizes for `predict_batch`: every query batch is padded
# up to one of these row counts (large batches are chunked at the biggest
# bucket), so scoring queues of ANY size compiles at most
# len(PREDICT_BUCKETS) distinct shapes per training-set size — instead of
# one fresh XLA compile per queue length.
PREDICT_BUCKETS = (64, 256, 1024)

# (n_train, padded_s) -> number of batched-predict launches.  Tests assert
# the bucket discipline from this counter; it is diagnostic state only.
predict_batch_shapes: collections.Counter = collections.Counter()

# Optional repro.obs.Tracer: when set, `predict_batch` emits one
# `gp.predict_batch` instant per launch (compile-shape visibility in the
# same trace as the scheduling spans).  Module-level because predict is a
# free function — there is no engine object to hang a tracer on.
_obs_tracer = None


def set_obs_tracer(tracer) -> None:
    """Attach (or detach, with None) the module-wide launch tracer."""
    global _obs_tracer
    _obs_tracer = tracer


def bucket_of(n: int) -> int:
    """The padded row count a chunk of `n` queries compiles at.  Raises
    (never a silent StopIteration — this is exported for external shape
    accounting) for chunks beyond the largest bucket: `predict_batch`
    splits those first, and so should any caller."""
    if n > PREDICT_BUCKETS[-1]:
        raise ValueError(f"chunk of {n} rows exceeds the largest predict "
                         f"bucket ({PREDICT_BUCKETS[-1]}); chunk it first")
    return next(b for b in PREDICT_BUCKETS if n <= b)


def bucket_launches(s: int) -> list:
    """The exact padded-launch sizes `predict_batch` issues for a batch
    of `s` queries: full largest-bucket chunks plus one bucketed
    remainder.  The set of distinct values is the compile-shape bill —
    callers (benchmarks, shape-discipline tests) can assert against it
    without replaying the chunk loop."""
    if s <= 0:
        return []
    cap = PREDICT_BUCKETS[-1]
    full, rest = divmod(s, cap)
    out = [cap] * full
    if rest:
        out.append(bucket_of(rest))
    return out


@dataclasses.dataclass
class GPParams:
    log_lengthscale: jax.Array       # [D]
    log_variance: jax.Array          # []
    log_noise: jax.Array             # []

    @staticmethod
    def init(d: int) -> "GPParams":
        return GPParams(jnp.zeros((d,)), jnp.zeros(()), jnp.log(jnp.float32(0.1)))

    def tree(self):
        return {"ls": self.log_lengthscale, "var": self.log_variance,
                "noise": self.log_noise}

    @staticmethod
    def from_tree(t) -> "GPParams":
        return GPParams(t["ls"], t["var"], t["noise"])


@dataclasses.dataclass
class GPPosterior:
    """Trained GP conditioned on (x, y); y may be [N] or [N, M].
    Outputs are standardised internally (per-column mean/std) — predict()
    returns results on the original scale."""
    params: GPParams
    x: jax.Array                     # [N, D]
    y: jax.Array                     # [N, M] raw observations
    y_mean: jax.Array                # [M]
    y_std: jax.Array                 # [M]
    chol: jax.Array                  # [N, N]
    alpha: jax.Array                 # [N, M]  (K + s2 I)^-1 (y - mean)/std
    kind: str = "rbf"
    # cached L^-1 (inverse Cholesky factor) for the batched predict path
    # (built lazily on the first predict_batch call; condition() rebuilds
    # the posterior so the cache naturally resets).  The quadratic form is
    # ||L^-1 ks||^2 — same conditioning as predict()'s triangular solve,
    # unlike an explicit (K + s2 I)^-1 which underestimates tiny variances
    linv: Optional[jax.Array] = None


def _kernel(params: GPParams, x1, x2, kind: str) -> jax.Array:
    # clip log-params: keeps NLML optimisation from walking the noise or
    # lengthscales into Cholesky-breaking territory
    ls = jnp.exp(jnp.clip(params.log_lengthscale, -5.0, 5.0))
    var = jnp.exp(jnp.clip(params.log_variance, -8.0, 8.0))
    return kops.gp_kernel_matrix(x1, x2, ls, var, kind)


def _chol_factor(params: GPParams, x, kind: str) -> jax.Array:
    n = x.shape[0]
    k = _kernel(params, x, x, kind)
    s2 = jnp.exp(2.0 * jnp.clip(params.log_noise, -5.0, 5.0))
    # jitter scales with the signal variance: keeps the f32 Cholesky
    # conditioned even in the noiseless-interpolation regime the NLML
    # optimum sometimes reaches (large var, lengthscale >> data range)
    var = jnp.exp(jnp.clip(params.log_variance, -8.0, 8.0))
    return jnp.linalg.cholesky(k + (s2 + 1e-5 * (var + 1.0)) * jnp.eye(n))


# Public aliases for the surrogate engines (`repro.uq.engine`): the
# incremental and partitioned backends assemble cross-covariances and
# cap-bounded factors out of the SAME primitives the exact path uses, so
# their results can be pinned to `recondition` at tight tolerance.
kernel_matrix = _kernel
chol_factor = _chol_factor


def nlml(tree, x, y, kind: str = "rbf") -> jax.Array:
    """Negative log marginal likelihood, summed over output columns."""
    params = GPParams.from_tree(tree)
    y2 = y if y.ndim == 2 else y[:, None]
    yc = y2 - jnp.mean(y2, axis=0, keepdims=True)
    n, m = yc.shape
    chol = _chol_factor(params, x, kind)
    alpha = jax.scipy.linalg.cho_solve((chol, True), yc)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    quad = jnp.sum(yc * alpha)
    return 0.5 * (quad + m * logdet + m * n * jnp.log(2.0 * jnp.pi))


@functools.partial(jax.jit, static_argnames=("kind", "steps", "lr"))
def _fit(x, y, kind: str, steps: int, lr: float):
    tree0 = GPParams.init(x.shape[1]).tree()
    grad_fn = jax.value_and_grad(lambda t: nlml(t, x, y, kind))

    clip_lo = {"ls": -5.0, "var": -8.0, "noise": -5.0}
    clip_hi = {"ls": 5.0, "var": 8.0, "noise": 2.0}

    def adam_step(state, _):
        tree, m, v, t = state
        loss, g = grad_fn(tree)
        # a NaN gradient (transient Cholesky breakdown) must not poison
        # the parameters: zero it and let the next step recover
        g = jax.tree.map(lambda a: jnp.nan_to_num(a), g)
        t = t + 1
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        tree = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8),
                            tree, mh, vh)
        tree = {k: jnp.clip(x, clip_lo[k], clip_hi[k])
                for k, x in tree.items()}
        return (tree, m, v, t), loss

    zeros = jax.tree.map(jnp.zeros_like, tree0)
    (tree, _, _, _), losses = jax.lax.scan(
        adam_step, (tree0, zeros, zeros, jnp.float32(0)), None, length=steps)
    return tree, losses


def fit(x: jax.Array, y: jax.Array, kind: str = "rbf", steps: int = 200,
        lr: float = 5e-2) -> GPPosterior:
    """Type-II MLE: optimise (lengthscales, variance, noise) by Adam."""
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    y2 = y if y.ndim == 2 else y[:, None]
    mean = jnp.mean(y2, axis=0)
    std = jnp.maximum(jnp.std(y2, axis=0), 1e-8)
    yn = (y2 - mean) / std
    tree, _ = _fit(x, yn, kind, steps, lr)
    params = GPParams.from_tree(tree)
    chol = _chol_factor(params, x, kind)
    alpha = jax.scipy.linalg.cho_solve((chol, True), yn)
    return GPPosterior(params=params, x=x, y=y2, y_mean=mean, y_std=std,
                       chol=chol, alpha=alpha, kind=kind)


@functools.partial(jax.jit, static_argnames=("kind",))
def _predict(params_tree, x_train, y_mean, y_std, chol, alpha, x_star, kind):
    params = GPParams.from_tree(params_tree)
    ks = _kernel(params, x_train, x_star, kind)                 # [N, S]
    mean = y_mean[None] + (ks.T @ alpha) * y_std[None]          # [S, M]
    v = jax.scipy.linalg.solve_triangular(chol, ks, lower=True)  # [N, S]
    prior = jnp.exp(params.log_variance)
    var = jnp.maximum(prior - jnp.sum(v * v, axis=0), 1e-12)    # [S]
    # original scale PER OUTPUT: the outputs were standardised per column,
    # so the latent variance maps back through each column's own y_std^2 —
    # pooling the scale (mean(y_std)^2) is wrong for every column whenever
    # the outputs differ in magnitude (growth rate vs mode frequency)
    var = var[:, None] * (y_std ** 2)[None, :]                  # [S, M]
    return mean, var


def predict(post: GPPosterior, x_star: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """Posterior mean [S, M] and per-output variance [S, M] at x_star
    (eqs. 3-4); the latent variance is shared across outputs (one kernel),
    scaled back by each column's standardisation std."""
    x_star = jnp.asarray(x_star, jnp.float32)
    if x_star.ndim == 1:
        x_star = x_star[None]
    return _predict(post.params.tree(), post.x, post.y_mean, post.y_std,
                    post.chol, post.alpha, x_star, post.kind)


def _ensure_linv(post: GPPosterior) -> jax.Array:
    """Cache L^-1 on the posterior: the batched predict path trades one
    triangular inversion at first use for a predict that is a single
    fused launch (no per-call triangular solve).

    Staleness contract: `linv` is valid iff it matches `chol`.  Every
    update path constructs a NEW GPPosterior (`recondition`, `fit`, the
    engine block-update), so a cached inverse can never outlive its
    factor on an aliased posterior — `invalidate_linv` exists for code
    that mutates a posterior's factor in place (none in-tree; the
    regression test in test_surrogate_engine.py holds the line)."""
    if post.linv is None:
        n = post.x.shape[0]
        post.linv = jax.scipy.linalg.solve_triangular(
            post.chol, jnp.eye(n, dtype=jnp.float32), lower=True)
    return post.linv


# public alias: the engines maintain / rebuild this cache explicitly
ensure_linv = _ensure_linv


def invalidate_linv(post: GPPosterior) -> None:
    """Drop the cached L^-1 so the next `predict_batch` rebuilds it.
    Required after any in-place change to `post.chol` — serving a stale
    inverse silently corrupts every batched variance."""
    post.linv = None


@functools.partial(jax.jit, static_argnames=("kind",))
def _predict_batch(params_tree, x_train, y_mean, y_std, linv, alpha,
                   x_star, kind):
    params = GPParams.from_tree(params_tree)
    ls = jnp.exp(jnp.clip(params.log_lengthscale, -5.0, 5.0))
    var = jnp.exp(jnp.clip(params.log_variance, -8.0, 8.0))
    mean_n, qf = kops.gp_predict(x_train, x_star, ls, var, alpha, linv, kind)
    mean = y_mean[None] + mean_n * y_std[None]                  # [S, M]
    lat = jnp.maximum(var - qf, 1e-12)                          # [S]
    return mean, lat[:, None] * (y_std ** 2)[None, :]           # [S, M]


def predict_batch(post: GPPosterior, x_star: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Bucket-padded batched posterior predict: mean [S, M], variance
    [S, M].

    Same contract as `predict`, but the query batch is padded up to a
    fixed bucket size (`PREDICT_BUCKETS`; oversize batches are chunked at
    the largest bucket) and evaluated through the one-launch
    `kops.gp_predict` path (Pallas on TPU, fused XLA elsewhere).  Scoring
    a whole dispatch queue therefore hits at most len(PREDICT_BUCKETS)
    distinct compile shapes per training-set size, instead of one fresh
    XLA compile per queue length — the per-task `predict` calls the
    offload router would otherwise issue.
    """
    x_star = jnp.asarray(x_star, jnp.float32)
    if x_star.ndim == 1:
        x_star = x_star[None]
    s = x_star.shape[0]
    if s == 0:
        m = post.y.shape[1]
        return (jnp.zeros((0, m), jnp.float32),
                jnp.zeros((0, m), jnp.float32))
    linv = _ensure_linv(post)
    tree = post.params.tree()
    cap = PREDICT_BUCKETS[-1]
    means, variances = [], []
    for lo in range(0, s, cap):
        chunk = x_star[lo:lo + cap]
        bucket = bucket_of(chunk.shape[0])
        pad = bucket - chunk.shape[0]
        if pad:
            chunk = jnp.pad(chunk, ((0, pad), (0, 0)))
        predict_batch_shapes[(int(post.x.shape[0]), bucket)] += 1
        if _obs_tracer is not None:
            _obs_tracer.instant(
                "gp.predict_batch",
                args={"n": int(chunk.shape[0]) - pad, "bucket": bucket,
                      "train_n": int(post.x.shape[0])})
        mean, var = _predict_batch(tree, post.x, post.y_mean, post.y_std,
                                   linv, post.alpha, chunk, post.kind)
        means.append(mean[:bucket - pad])
        variances.append(var[:bucket - pad])
    if len(means) == 1:
        return means[0], variances[0]
    return jnp.concatenate(means), jnp.concatenate(variances)


def recondition(post: GPPosterior, x: jax.Array, y: jax.Array
                ) -> GPPosterior:
    """Posterior with the SAME hyperparameters on a replacement dataset
    (recency-capped surrogates, sliding windows): one Cholesky rebuild,
    no re-training."""
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    y2 = y if y.ndim == 2 else y[:, None]
    mean = jnp.mean(y2, axis=0)
    std = jnp.maximum(jnp.std(y2, axis=0), 1e-8)
    chol = _chol_factor(post.params, x, post.kind)
    alpha = jax.scipy.linalg.cho_solve((chol, True), (y2 - mean) / std)
    return GPPosterior(params=post.params, x=x, y=y2, y_mean=mean,
                       y_std=std, chol=chol, alpha=alpha, kind=post.kind)


def coerce_new_data(x_new: jax.Array, y_new: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Normalise a conditioning batch to (x [K, D], y [K, M]): a 1-D y is
    a column when x carries several rows, and a single multi-output row
    otherwise.  Shared by `condition` and every engine backend so all
    conditioning paths accept identical shapes."""
    x_new = jnp.atleast_2d(jnp.asarray(x_new, jnp.float32))
    y_new2 = jnp.asarray(y_new, jnp.float32)
    if y_new2.ndim == 1:
        y_new2 = y_new2[:, None] if x_new.shape[0] > 1 else y_new2[None, :]
    return x_new, y_new2


def condition(post: GPPosterior, x_new: jax.Array, y_new: jax.Array
              ) -> GPPosterior:
    """Add observations and re-condition (adaptive/Bayesian-quadrature use);
    hyperparameters are kept — only the Cholesky is rebuilt."""
    x_new, y_new2 = coerce_new_data(x_new, y_new)
    return recondition(post, jnp.concatenate([post.x, x_new]),
                       jnp.concatenate([post.y, y_new2]))
