"""Adaptive surrogate delegation (paper §VI: the stated future workflow).

"delegating costly simulation to the surrogate at points with low
uncertainty": for each requested input, query the GP posterior first —
if its predictive sd is below `sd_threshold`, accept the surrogate mean
(cheap); otherwise schedule the expensive forward model through the
executor and CONDITION the GP on the result, so later nearby requests
hit the cheap path.  The workload is therefore a mixed stream of
millisecond surrogate hits and minutes-equivalent simulator runs with a
data-dependent mix — exactly the scheduling profile the paper's load
balancer exists for.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executor import Executor
from repro.core.task import EvalRequest
from repro.uq import engine as engine_lib
from repro.uq import gp as gp_lib


@dataclasses.dataclass
class AdaptiveResult:
    outputs: np.ndarray              # [n, m] accepted outputs
    used_simulator: np.ndarray       # [n] bool — which requests ran the model
    posterior: gp_lib.GPPosterior    # final (enriched) surrogate
    n_sim_calls: int


def evaluate_stream(executor: Executor, model_name: str,
                    post: gp_lib.GPPosterior, inputs: np.ndarray, *,
                    sd_threshold: float = 0.05, timeout: float = 600.0,
                    batch_condition: bool = True,
                    backend: str = "exact") -> AdaptiveResult:
    """Process `inputs` in order, delegating to the surrogate where its
    uncertainty allows and to the scheduled simulator where it does not.

    `backend` picks the conditioning engine: the per-simulation
    `condition()` was an O(n³) refit each time on "exact" (the default,
    reference behaviour); "incremental" pays O(n²) per accepted
    simulation, which is what makes long delegation streams viable.  The
    result's `posterior` is the underlying `GPPosterior` on
    exact/incremental and the engine itself on "partitioned"."""
    engine = engine_lib.as_engine(post, backend)
    inputs = np.asarray(inputs, np.float32)
    n = len(inputs)
    m = engine.n_outputs()
    outputs = np.zeros((n, m), np.float32)
    used_sim = np.zeros(n, bool)
    n_sim = 0

    for i, x in enumerate(inputs):
        mean, var = engine.predict(x[None])
        # variance is per output column [1, M]; gate on the LEAST trusted
        # output — one confidently-wrong column must not unlock the
        # surrogate for the whole vector
        sd = float(np.max(np.sqrt(np.asarray(var)[0])))
        if sd <= sd_threshold:
            outputs[i] = np.asarray(mean)[0]
            continue
        req = EvalRequest(model_name, [x.tolist()],
                          time_request=None)       # unpredictable runtime
        executor.submit(req)
        res = executor.result(req.task_id, timeout)
        if res.status != "ok":
            # fault-tolerant degradation: accept the surrogate rather
            # than fail the stream; flagged via used_simulator=False
            outputs[i] = np.asarray(mean)[0]
            continue
        y = np.asarray(res.value[0], np.float32)
        outputs[i] = y
        used_sim[i] = True
        n_sim += 1
        if batch_condition:
            engine = engine.condition(x[None], y[None])
    return AdaptiveResult(outputs=outputs, used_simulator=used_sim,
                          posterior=getattr(engine, "post", engine),
                          n_sim_calls=n_sim)
