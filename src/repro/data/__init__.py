from repro.data.pipeline import (SyntheticLM, MemmapCorpus, make_pipeline,
                                 host_shard)
