"""Token data pipeline: deterministic synthetic LM stream + memmap corpus.

Multi-host discipline: every host computes the *global* batch spec but
materialises only its own shard (`host_shard`), so the pipeline never
allocates global_batch arrays on one host.  Synthetic data is a seeded
function of (seed, step) — restartable from a checkpointed step with no
state files, and identical across runs (bitwise).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

import jax


def host_shard(global_batch: int,
               process_index: Optional[int] = None,
               process_count: Optional[int] = None) -> Tuple[int, int]:
    """(offset, size) of this host's slice of the global batch."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    assert global_batch % pc == 0, (global_batch, pc)
    size = global_batch // pc
    return pi * size, size


@dataclasses.dataclass
class SyntheticLM:
    """Markov-flavoured synthetic tokens: next-token structure exists (so
    loss actually decreases) but generation is a pure seeded function of
    the step."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embeddings_dim: int = 0          # >0 -> emit embeddings (audio/vlm stubs)

    def batch(self, step: int, *, process_index: Optional[int] = None,
              process_count: Optional[int] = None) -> Dict[str, np.ndarray]:
        off, size = host_shard(self.global_batch, process_index,
                               process_count)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, off]))
        if self.embeddings_dim:
            emb = rng.standard_normal(
                (size, self.seq_len, self.embeddings_dim)).astype(np.float32)
            labels = rng.integers(0, self.vocab_size,
                                  (size, self.seq_len), dtype=np.int32)
            return {"embeddings": emb, "labels": labels}
        # structured stream: x_{t+1} = (a * x_t + drift + noise) mod V
        a = 6364136223846793005 % self.vocab_size or 1
        x0 = rng.integers(0, self.vocab_size, (size, 1), dtype=np.int64)
        noise = (rng.random((size, self.seq_len - 1)) < 0.1)
        toks = [x0[:, 0]]
        for t in range(self.seq_len - 1):
            nxt = (toks[-1] * a + 7) % self.vocab_size
            rnd = rng.integers(0, self.vocab_size, size, dtype=np.int64)
            toks.append(np.where(noise[:, t], rnd, nxt))
        tokens = np.stack(toks, 1).astype(np.int32)
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass
class MemmapCorpus:
    """Fixed token corpus in a flat binary file (np.memmap), sampled in
    seq_len windows.  `build_demo` writes a synthetic corpus to disk so
    the memmap path is exercised end-to-end without external data."""
    path: Path
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        self.path = Path(self.path)
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")

    @staticmethod
    def build_demo(path: Path, vocab_size: int, n_tokens: int = 1 << 20,
                   seed: int = 0) -> "MemmapCorpus":
        rng = np.random.default_rng(seed)
        arr = rng.integers(0, vocab_size, n_tokens, dtype=np.int32)
        arr.tofile(path)
        return path

    def batch(self, step: int, *, process_index: Optional[int] = None,
              process_count: Optional[int] = None) -> Dict[str, np.ndarray]:
        off, size = host_shard(self.global_batch, process_index,
                               process_count)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, off]))
        max_start = len(self._data) - self.seq_len - 1
        starts = rng.integers(0, max_start, size)
        tokens = np.stack([np.asarray(self._data[s:s + self.seq_len])
                           for s in starts])
        return {"tokens": tokens.astype(np.int32)}


def make_pipeline(kind: str, *, vocab_size: int, seq_len: int,
                  global_batch: int, seed: int = 0,
                  embeddings_dim: int = 0, corpus_path: Optional[Path] = None):
    if kind == "synthetic":
        return SyntheticLM(vocab_size, seq_len, global_batch, seed,
                           embeddings_dim)
    if kind == "memmap":
        assert corpus_path is not None
        return MemmapCorpus(corpus_path, vocab_size, seq_len, global_batch,
                            seed)
    raise ValueError(kind)
