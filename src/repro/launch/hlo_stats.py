"""Parse collective traffic and op statistics out of compiled/optimized HLO.

`cost_analysis()` reports flops and bytes but NOT collective bytes, so the
roofline's collective term comes from summing operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
in the optimized HLO text.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,1280,7168]{2,1,0} all-gather(...)"
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\([^=]*\)|[\w\[\],\{\} ]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """-> {op_kind: {"count": n, "bytes": output bytes summed}}.

    `-done` ops are skipped so async (start/done) pairs count once."""
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += _shape_bytes(shape_str)
    return dict(stats)


def total_collective_bytes(hlo_text: str) -> int:
    return int(sum(v["bytes"] for v in collective_stats(hlo_text).values()))


def op_histogram(hlo_text: str, top: int = 20) -> Dict[str, int]:
    """Count opcodes (fusion-level view of what the program does)."""
    counts: Dict[str, int] = defaultdict(int)
    opre = re.compile(r"=\s*(?:\([^)]*\)\s+)?[\w\[\],\{\} ]*?\s([a-z][\w\-]*)\(")
    for line in hlo_text.splitlines():
        m = opre.search(line)
        if m:
            counts[m.group(1)] += 1
    return dict(sorted(counts.items(), key=lambda kv: -kv[1])[:top])
