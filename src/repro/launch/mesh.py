"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the 512-placeholder-device dry-run must set
XLA_FLAGS before the first jax initialization.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(*, data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


def mesh_device_count(mesh: Mesh) -> int:
    return mesh.devices.size
