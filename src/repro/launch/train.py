"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduced \
        --steps 100 --batch 8 --seq 128

Runs the full production path on whatever devices exist: mesh build,
sharded param/optimizer init, synthetic (or memmap) data pipeline,
jit-compiled train_step with in/out shardings, periodic async
checkpointing with crash-safe restore, gradient accumulation and optional
int8 gradient compression.  On a pod the same script scales out — the
mesh is (data, model) over all devices.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import make_pipeline
from repro.launch import specs
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step
from repro.models import model as model_lib
from repro.models import sharding
from repro.optim import AdamWConfig, init_opt_state


def train(arch: str, *, reduced: bool = True, steps: int = 50,
          batch: int = 8, seq: int = 128, ckpt_dir: str = "",
          ckpt_every: int = 25, data_kind: str = "synthetic",
          mesh_data: int = 1, mesh_model: int = 1, seed: int = 0,
          compress_grads: bool = False, log_every: int = 10,
          accum_steps: int = 1) -> dict:
    cfg = configs.get_reduced(arch) if reduced else configs.get(arch)
    cfg = cfg.replace(accum_steps=accum_steps)
    mesh = make_local_mesh(data=mesh_data, model=mesh_model)
    opt_cfg = AdamWConfig(moments_dtype=cfg.moments_dtype,
                          total_steps=max(steps, 2))

    pipe = make_pipeline(data_kind, vocab_size=cfg.vocab_size, seq_len=seq,
                         global_batch=batch, seed=seed,
                         embeddings_dim=(cfg.d_model if cfg.input_mode ==
                                         "embeddings" else 0))

    psh = specs.param_shardings(cfg, mesh)
    osh = specs.opt_shardings(cfg, opt_cfg, mesh)
    step_fn = make_train_step(cfg, opt_cfg, compress_grads=compress_grads)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    with sharding.use_mesh(mesh):
        params = jax.device_put(
            model_lib.init_params(cfg, jax.random.PRNGKey(seed)), psh)
        opt_state = jax.device_put(init_opt_state(params, opt_cfg), osh)
        if compress_grads:
            from repro.optim import init_compression_state
            opt_state["comp_err"] = init_compression_state(params)
        if mgr is not None:
            restored, meta = mgr.restore_latest(
                {"params": params, "opt": opt_state})
            if restored is not None:
                params = jax.device_put(restored["params"], psh)
                opt_state = jax.device_put(restored["opt"], osh)
                start_step = int(meta["step"]) + 1
                print(f"[train] restored step {start_step - 1} "
                      f"from {ckpt_dir}")

        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        losses = []
        t0 = time.time()
        for step in range(start_step, steps):
            np_batch = pipe.batch(step)
            jbatch = {k: jax.numpy.asarray(v) for k, v in np_batch.items()}
            params, opt_state, metrics = jit_step(params, opt_state, jbatch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t0
                print(f"[train {arch}] step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"({dt:.1f}s)")
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step, {"params": params, "opt": opt_state})
        if mgr is not None:
            mgr.save(steps - 1, {"params": params, "opt": opt_state})
            mgr.wait()
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "losses": losses, "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_NAMES))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "memmap"])
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(args.arch, reduced=args.reduced, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, data_kind=args.data,
                mesh_data=args.mesh_data, mesh_model=args.mesh_model,
                seed=args.seed, compress_grads=args.compress_grads,
                accum_steps=args.accum_steps)
    print(f"[train] loss {out['first_loss']:.4f} -> {out['last_loss']:.4f}")


if __name__ == "__main__":
    main()
