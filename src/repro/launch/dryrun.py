import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import: jax locks the device
# count at first initialization.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step for train
shapes, prefill/decode steps otherwise) against abstract ShapeDtypeStruct
inputs with full production shardings, compiles it, and records:
  * memory_analysis()  — per-device bytes (proves the cell fits),
  * cost_analysis()    — per-device HLO flops / bytes accessed,
  * collective traffic — parsed from optimized HLO (all-gather/all-reduce/
    reduce-scatter/all-to-all/collective-permute operand bytes),
  * derived roofline terms for the v5e-class target
    (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro.launch import hlo_cost, hlo_stats, specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import model as model_lib
from repro.models import sharding
from repro.optim import AdamWConfig

# target hardware constants (TPU v5e class)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, one direction)
HBM_PER_CHIP = 16 * 2**30    # v5e: 16 GiB

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def step_for(cfg, shape, opt_cfg):
    """-> (step_fn, donate_argnums).  Donation aliases the streaming state
    (params+opt for train, the KV/recurrent cache for serving) so XLA
    updates buffers in place instead of double-buffering them — without it
    a decode step carries two copies of a multi-GiB cache."""
    if shape.mode == "train":
        return make_train_step(cfg, opt_cfg), (0, 1)
    if shape.mode == "prefill":
        return make_prefill_step(cfg), (2,)
    return make_decode_step(cfg), (1,)


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             save: bool = True, verbose: bool = True,
             overrides: dict = None, tag: str = "") -> dict:
    """overrides: ModelConfig.replace kwargs (perf-hillclimb knobs);
    tag: suffix for the result file so variants never clobber baselines."""
    cfg = configs.get(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = next(s for s in configs.shapes() if s.name == shape_name)
    if not cfg.runnable(shape):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped",
               "reason": "long_500k requires sub-quadratic attention"}
        if save:
            _save(rec)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    opt_cfg = AdamWConfig(moments_dtype=cfg.moments_dtype)
    step, donate = step_for(cfg, shape, opt_cfg)
    args, in_sh = specs.cell_arguments(cfg, shape, mesh, opt_cfg)
    t0 = time.time()
    with sharding.use_mesh(mesh):
        lowered = jax.jit(step, in_shardings=in_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis() or {}
    mem = _mem_dict(compiled.memory_analysis())
    hlo = compiled.as_text()
    # trip-count-aware walk of the optimized HLO (cost_analysis counts
    # scanned layer bodies only once; see launch/hlo_cost.py)
    walked = hlo_cost.analyze(hlo)
    flops_dev = float(walked["flops"])
    bytes_dev = float(walked["bytes"])
    coll_bytes = float(walked["collective_bytes"])

    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    n_active = model_lib.count_active_params(cfg)
    mult = 6 if shape.mode == "train" else 2
    model_flops = mult * n_active * tokens

    compute_term = flops_dev / PEAK_FLOPS
    memory_term = bytes_dev / HBM_BW
    collective_term = coll_bytes / ICI_BW
    terms = {"compute_s": compute_term, "memory_s": memory_term,
             "collective_s": collective_term}
    dominant = max(terms, key=terms.get)
    temp_b = mem.get("temp_size_in_bytes", 0)
    arg_b = mem.get("argument_size_in_bytes", 0)
    fits = (temp_b + arg_b) <= HBM_PER_CHIP

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "tag": tag, "overrides": dict(overrides or {}),
        "status": "ok", "devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_bytes,
        "collectives": walked["collectives"],
        "bytes_by_opcode": walked.get("bytes_by_opcode", {}),
        "xla_cost_analysis_raw": {"flops": float(cost.get("flops", 0.0)),
                                  "bytes": float(cost.get("bytes accessed",
                                                          0.0))},
        "memory_analysis": mem,
        "fits_hbm_16g": bool(fits),
        "roofline": {**terms, "dominant": dominant},
        "model_flops_global": float(model_flops),
        "hlo_flops_global": flops_dev * n_dev,
        "useful_flops_ratio": (model_flops / (flops_dev * n_dev)
                               if flops_dev else 0.0),
        "active_params": int(n_active),
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_kind}] "
              f"compile={t_compile:.1f}s flops/dev={flops_dev:.3e} "
              f"bytes/dev={bytes_dev:.3e} coll/dev={coll_bytes:.3e} "
              f"mem(arg+temp)={(arg_b + temp_b)/2**30:.2f}GiB "
              f"fits16G={fits} dominant={dominant}")
    if save:
        _save(rec)
    return rec


def _save(rec: dict):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"__{rec['tag']}" if rec.get("tag") else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    (RESULTS_DIR / name).write_text(json.dumps(rec, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s.name) for a, s, _run in configs.cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shp in cells:
        for mk in meshes:
            out = RESULTS_DIR / f"{arch}__{shp}__{mk}.json"
            if args.skip_existing and out.exists():
                prev = json.loads(out.read_text())
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[{arch} x {shp} x {mk}] cached: {prev['status']}")
                    continue
            try:
                run_cell(arch, shp, mk)
            except Exception as e:  # a failure here is a sharding bug
                traceback.print_exc()
                failures.append((arch, shp, mk, repr(e)))
                _save({"arch": arch, "shape": shp, "mesh": mk,
                       "status": "failed", "error": repr(e)})
    if failures:
        print(f"\n{len(failures)} FAILED cells:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll requested dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
