"""jit-able train / prefill / decode step factories."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model, sharding
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update, compress_with_feedback


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    *, compress_grads: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Gradient accumulation: the global batch is split into cfg.accum_steps
    microbatches scanned sequentially; gradients accumulate in f32 (bf16 when
    the config opts into bf16 moments, halving peak optimizer-path HBM)."""
    accum = max(cfg.accum_steps, 1)
    acc_dtype = (jnp.bfloat16 if cfg.moments_dtype == "bfloat16"
                 else jnp.float32)

    def micro_loss(params, mb):
        return model.loss_fn(params, mb, cfg)

    grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def body(carry, mb):
                g_acc, loss_acc = carry
                mb = jax.tree.map(
                    lambda x: sharding.constrain(
                        x, *(("act_batch",) + (None,) * (x.ndim - 1))), mb)
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     g_acc, g)
                return (g_acc, loss_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (grads, loss_sum), ms = jax.lax.scan(body, (g0, jnp.float32(0)),
                                                 split)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        if compress_grads:
            grads, err = compress_with_feedback(grads, opt_state["comp_err"])
        new_params, new_opt, om = adamw_update(params, grads,
                                               {k: v for k, v in
                                                opt_state.items()
                                                if k != "comp_err"}, opt_cfg)
        if compress_grads:
            new_opt["comp_err"] = err
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        logits, new_cache, _ = model.prefill(params, batch, cfg, cache,
                                             last_only=True)
        return logits[:, -1], new_cache

    return prefill_step


def make_bucketed_prefill_step(cfg: ModelConfig):
    """Prefill over a right-padded prompt bucket; the LM head runs on the
    true last token only (`last_index`, per-row).  Padding rows write
    garbage KV beyond last_index, but causal masking means nothing ever
    reads them before decode overwrites them position by position."""
    def prefill_step(params, batch, cache, last_index):
        logits, new_cache, _ = model.forward(params, batch, cfg,
                                             cache=cache,
                                             last_index=last_index)
        return logits[:, -1], new_cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, batch, pos):
        logits, new_cache = model.decode_step(params, batch, cfg, cache, pos)
        return logits, new_cache

    return decode_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = model.loss_fn(params, batch, cfg)
        return metrics

    return eval_step
