"""Abstract input specs + shardings for every (arch x shape x mesh) cell.

`input_specs()` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input (no device allocation), per the dry-run contract.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model, sharding
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import AdamWConfig, abstract_opt_state, opt_state_axes


def batch_input_specs(cfg: ModelConfig, shape: ShapeConfig
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    b = shape.global_batch
    s = 1 if shape.mode == "decode" else shape.seq_len
    if cfg.input_mode == "embeddings":
        specs = {"embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                    cfg.activation_dtype)}
        if shape.mode == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def batch_axes_tree(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, tuple]:
    out = {}
    for k, v in batch_input_specs(cfg, shape).items():
        out[k] = ("act_batch",) + (None,) * (len(v.shape) - 1)
    return out


def fsdp_axes(cfg: ModelConfig, mesh: Mesh) -> Tuple[str, ...]:
    ax = ("pod", "data") if cfg.fsdp_pod else ("data",)
    return tuple(a for a in ax if a in mesh.axis_names)


def _param_rules(cfg: ModelConfig):
    if not cfg.ep_over_data:
        return None
    # EP over (data x model): experts fully resident, no FSDP on the
    # expert hidden dim (serving layout; see moe._moe_body_ep_all)
    rules = dict(sharding.PARAM_RULES)
    rules["expert"] = (("data", "model"), ("model",))
    rules["expert_mlp"] = ((),)
    return rules


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    return sharding.tree_shardings(model.param_axes(cfg),
                                   model.abstract_params(cfg), mesh,
                                   fsdp_axes=fsdp_axes(cfg, mesh),
                                   rules=_param_rules(cfg))


def opt_shardings(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh: Mesh):
    ax = opt_state_axes(model.param_axes(cfg))
    ab = abstract_opt_state(model.abstract_params(cfg), opt_cfg)
    return sharding.tree_shardings(ax, ab, mesh,
                                   fsdp_axes=fsdp_axes(cfg, mesh),
                                   rules=_param_rules(cfg))


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    return sharding.tree_shardings(batch_axes_tree(cfg, shape),
                                   batch_input_specs(cfg, shape), mesh,
                                   rules=sharding.ACT_RULES)


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    ab = model.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    ax = model.cache_axes(cfg, shape.global_batch, shape.seq_len)
    return sharding.tree_shardings(ax, ab, mesh, rules=sharding.ACT_RULES)


def cell_arguments(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   opt_cfg: Optional[AdamWConfig] = None):
    """-> (abstract_args tuple, in_shardings tuple) for the cell's step fn."""
    opt_cfg = opt_cfg or AdamWConfig(moments_dtype=cfg.moments_dtype)
    ap = model.abstract_params(cfg)
    psh = param_shardings(cfg, mesh)
    batch = batch_input_specs(cfg, shape)
    bsh = batch_shardings(cfg, shape, mesh)
    if shape.mode == "train":
        aopt = abstract_opt_state(ap, opt_cfg)
        osh = opt_shardings(cfg, opt_cfg, mesh)
        return (ap, aopt, batch), (psh, osh, bsh)
    acache = model.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    csh = cache_shardings(cfg, shape, mesh)
    if shape.mode == "prefill":
        return (ap, batch, acache), (psh, bsh, csh)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    possh = NamedSharding(mesh, P())
    return (ap, acache, batch, pos), (psh, csh, bsh, possh)
