"""Serving driver: LM decode requests scheduled through the paper's
load balancer.

The paper's workload shape — many evaluations of one expensive map with
widely varying per-request cost — is exactly LM serving with mixed
sequence lengths.  This driver wraps an LM's prefill+decode loop as an
UM-Bridge `Model` and pushes batched requests through the persistent-
worker executor (HQ semantics: the jit cache is the warm server) or the
naive per-request mode (SLURM semantics: fresh compile every request),
so the paper's comparison is measurable on real JAX serving.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \
        --requests 16 --max-new 8
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import EvalRequest, Executor, LambdaModel
from repro.core.metrics import summarize
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import model as model_lib
from repro.models.config import ModelConfig


class LMServer:
    """A persistent LM model server: holds params + compiled steps.

    Prompts are right-padded to power-of-two BUCKETS so the warm server's
    jit cache hits across requests of different lengths — without this,
    every distinct prompt length recompiles and a 'persistent' server is
    no faster than a fresh one (measured; see EXPERIMENTS.md §Perf-serve).
    Causal masking keeps the padded KV rows unread until decode overwrites
    them position by position."""

    def __init__(self, cfg: ModelConfig, *, batch: int = 1,
                 max_len: int = 256, seed: int = 0, min_bucket: int = 16):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.min_bucket = min_bucket
        self.params = model_lib.init_params(cfg, jax.random.PRNGKey(seed))
        from repro.launch.steps import make_bucketed_prefill_step
        self._prefill = jax.jit(make_bucketed_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg))

    def warmup(self, prompt_len: int = 8):
        self.generate(np.zeros((self.batch, prompt_len), np.int32), 1)

    def _bucket(self, s: int) -> int:
        # Recurrent archs (SSM/RWKV/hybrid) integrate every input token
        # into their state — right-padding would corrupt it (causal
        # masking only protects attention caches).  They use exact
        # lengths; attention archs bucket.
        if self.cfg.block_kind != "attn+mlp":
            return s
        b = self.min_bucket
        while b < s:
            b *= 2
        return min(b, self.max_len)

    def generate(self, prompt_tokens: np.ndarray, max_new: int
                 ) -> np.ndarray:
        b, s = prompt_tokens.shape
        assert b == self.batch
        bucket = self._bucket(s)
        padded = np.zeros((b, bucket), np.int32)
        padded[:, :s] = prompt_tokens
        cache = model_lib.init_cache(self.cfg, b, self.max_len)
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(padded)}, cache,
            jnp.full((b,), s - 1, jnp.int32))
        outs = []
        tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1)
        outs.append(tok)
        for i in range(max_new - 1):
            pos = jnp.int32(s + i)
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": tok[:, None]}, pos)
            tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1)
            outs.append(tok)
        return np.stack([np.asarray(t) for t in outs], axis=1)


def make_lm_model_factory(cfg: ModelConfig, *, max_len: int = 256,
                          seed: int = 0):
    """UM-Bridge model factory: parameters = [prompt tokens]; config may
    set max_new.  Request cost scales with prompt length + new tokens —
    the mixed-cost profile the scheduler is for."""

    def factory():
        server = LMServer(cfg, batch=1, max_len=max_len, seed=seed)

        def fn(parameters, config):
            prompt = np.asarray(parameters, np.int32).reshape(1, -1)
            max_new = int((config or {}).get("max_new", 8))
            out = server.generate(prompt, max_new)
            return [out[0].tolist()]

        model = LambdaModel(f"lm-{cfg.name}", fn, input_size=-1,
                            output_size=-1,
                            warmup_fn=lambda: server.warmup())
        return model

    return factory


def serve_benchmark(arch: str, *, n_requests: int = 16, max_new: int = 8,
                    n_workers: int = 2, persistent: bool = True,
                    max_len: int = 256, seed: int = 0,
                    reduced: bool = True) -> Dict:
    cfg = configs.get_reduced(arch) if reduced else configs.get(arch)
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, max_len // 2, n_requests)
    prompts = [rng.integers(0, cfg.vocab_size, (int(l),)).tolist()
               for l in lens]
    factory = make_lm_model_factory(cfg, max_len=max_len, seed=seed)
    name = f"lm-{cfg.name}"
    t0 = time.monotonic()
    with Executor({name: factory}, n_workers=n_workers,
                  persistent_servers=persistent,
                  name="hq" if persistent else "slurm") as ex:
        reqs = [EvalRequest(name, p, config={"max_new": max_new},
                            time_request=0.001 * len(p))
                for p in prompts]
        results = ex.run_all(reqs, timeout=1200.0)
        recs = ex.records()
    wall = time.monotonic() - t0
    assert all(r.status == "ok" for r in results)
    summary = summarize(f"serve-{arch}", "hq" if persistent else "slurm",
                        recs)
    return {"wall": wall, "summary": summary,
            "tokens": sum(len(r.value[0]) for r in results)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b",
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for persistent in (True, False):
        out = serve_benchmark(args.arch, n_requests=args.requests,
                              max_new=args.max_new, n_workers=args.workers,
                              persistent=persistent, max_len=args.max_len,
                              reduced=not args.full)
        s = out["summary"]
        mode = "persistent (HQ)" if persistent else "per-request (SLURM)"
        print(f"[serve {args.arch}] {mode:22s} wall={out['wall']:.2f}s "
              f"cpu={s.total_cpu_time:.2f}s overhead={s.scheduling_overhead:.3f}s "
              f"SLR={s.slr:.2f}")


if __name__ == "__main__":
    main()
