"""Trip-count-aware cost analysis of compiled (optimized) HLO text.

`compiled.cost_analysis()` visits every instruction ONCE — a model scanned
over L layers (`jax.lax.scan`, our default for compile-time sanity at 512
devices) is under-counted by ~L in FLOPs, bytes and collective traffic.
This walker fixes that from the artifact itself:

  * parse every computation and its ops;
  * FLOPs: 2 * |out| * contraction for every `dot` (recursing into fusion
    bodies, where the dots actually live after fusion);
  * HBM bytes: operand+output bytes of top-level ops (fusion boundaries
    only — fused interiors never touch HBM), excluding pure plumbing
    (tuple/get-tuple-element/parameter/bitcast/while shells);
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (async `-start`
    counted once);
  * `while` bodies are multiplied by `backend_config.known_trip_count`
    (fallback 1 when XLA could not prove a trip count);
  * call graph walked from ENTRY through fusion/call/while/conditional.

The result is the per-device roofline input for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"         # result name
    r"((?:\([^)]*\)|[\w\[\],\{\}\. ]+?))\s+"         # result shape (tuple ok)
    r"([\w\-]+)\(")                                   # opcode
_CALLED_RE = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)="
    r"(\{[^}]*\}|%?[\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*\\?\{\\?"n\\?":\\?"(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "ragged-all-to-all", "collective-permute")
_SKIP_BYTES = {"tuple", "get-tuple-element", "parameter", "constant",
               "bitcast", "while", "conditional", "call", "after-all",
               "opt-barrier", "copy-start", "copy-done"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[List[int]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        out.append([int(d) for d in m.group(2).split(",") if d])
    return out


@dataclasses.dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Optional[Dict[str, float]] = None
    by_opcode: Optional[Dict[str, float]] = None   # bytes per opcode

    def add_bytes(self, opcode: str, b: float):
        self.bytes += b
        if self.by_opcode is None:
            self.by_opcode = defaultdict(float)
        self.by_opcode[opcode] += b


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_shape: str
    line: str
    called: List[str]
    operands: List[str]
    trip_count: int = 1
    is_root: bool = False


_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _parse_computations(hlo: str) -> Tuple[Dict[str, List[_Op]], str,
                                           Dict[str, str]]:
    comps: Dict[str, List[_Op]] = {}
    shapes: Dict[str, str] = {}          # op name -> result shape string
    entry = ""
    current: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                current = m.group(1)
                comps[current] = []
                if line.strip().startswith("ENTRY"):
                    entry = current
                continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, opcode = m.group(1), m.group(2), m.group(3)
        is_root = line.lstrip().startswith("ROOT")
        shapes[name] = shape
        # operand region: between the opcode's '(' and its closing ')'
        op_pos = line.find(opcode + "(")
        lp = op_pos + len(opcode)
        rp = line.find(")", lp)
        operand_blob = line[lp + 1:rp] if rp > lp else ""
        operands = _OPERAND_RE.findall(operand_blob)
        called: List[str] = []
        for cm in _CALLED_RE.finditer(line):
            blob = cm.group(1)
            if blob.startswith("{"):
                called += [c.strip().lstrip("%") for c in
                           blob.strip("{}").split(",") if c.strip()]
            else:
                called.append(blob.lstrip("%"))
        trip = 1
        tm = _TRIP_RE.search(line)
        if tm:
            trip = int(tm.group(1))
        comps[current].append(_Op(name, opcode, shape, line, called,
                                  operands, trip, is_root))
    return comps, entry, shapes


def _dot_flops(op: _Op, shapes: Dict[str, str]) -> float:
    dims_list = _shape_dims(op.result_shape)
    if not dims_list:
        return 0.0
    out_elems = 1
    for d in dims_list[0]:
        out_elems *= d
    cm = _CONTRACT_RE.search(op.line)
    if not cm:
        return 2.0 * out_elems
    cdims = [int(x) for x in cm.group(1).split(",") if x]
    lhs_shape = shapes.get(op.operands[0], "") if op.operands else ""
    lhs_dims_list = _shape_dims(lhs_shape)
    if not lhs_dims_list:
        return 2.0 * out_elems
    lhs_dims = lhs_dims_list[0]
    contract = 1
    for c in cdims:
        if c < len(lhs_dims):
            contract *= lhs_dims[c]
    return 2.0 * out_elems * contract


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps, self.entry, self.shapes = _parse_computations(hlo_text)
        self._memo: Dict[Tuple[str, bool], OpCost] = {}

    @staticmethod
    def _merge(total: OpCost, sub: OpCost, scale: float = 1.0,
               flops_only: bool = False):
        total.flops += sub.flops * scale
        if flops_only:
            return
        total.bytes += sub.bytes * scale
        total.coll_bytes += sub.coll_bytes * scale
        for k, v in (sub.coll_counts or {}).items():
            total.coll_counts[k] += v * scale
        for k, v in (sub.by_opcode or {}).items():
            if total.by_opcode is None:
                total.by_opcode = defaultdict(float)
            total.by_opcode[k] += v * scale

    def _comp_cost(self, comp: str, fused: bool) -> OpCost:
        """Cost of one execution of `comp`.  `fused=True` -> interior of a
        fusion: only FLOPs count (no HBM traffic, no collectives expected)."""
        key = (comp, fused)
        if key in self._memo:
            return self._memo[key]
        total = OpCost(coll_counts=defaultdict(float),
                       by_opcode=defaultdict(float))
        for op in self.comps.get(comp, ()):
            oc = op.opcode
            if oc == "fusion":
                for c in op.called:
                    self._merge(total, self._comp_cost(c, True),
                                flops_only=True)
                if not fused:
                    total.add_bytes("fusion",
                                    sum(self._fusion_bytes(c)
                                        for c in op.called))
            elif oc in ("while",):
                for c in op.called:
                    self._merge(total, self._comp_cost(c, fused),
                                scale=op.trip_count)
            elif oc in ("call", "conditional", "custom-call", "reduce",
                        "sort", "scatter", "map", "reduce-window",
                        "select-and-scatter", "all-reduce", "reduce-scatter"):
                for c in op.called:
                    self._merge(total,
                                self._comp_cost(c, fused or oc == "reduce"))
                if oc.startswith("all-") or oc == "reduce-scatter":
                    b = self._op_bytes(op, output_only=True)
                    total.coll_bytes += b
                    total.coll_counts[oc] += 1
                    if not fused:
                        total.add_bytes(oc, self._op_bytes(op))
                elif not fused and oc not in _SKIP_BYTES:
                    total.add_bytes(oc, self._op_bytes(op))
            elif oc == "dot":
                total.flops += _dot_flops(op, self.shapes)
                if not fused:
                    total.add_bytes(oc, self._op_bytes(op))
            elif any(oc == c or oc == c + "-start" for c in _COLLECTIVES):
                base = oc[:-6] if oc.endswith("-start") else oc
                b = self._op_bytes(op, output_only=True)
                total.coll_bytes += b
                total.coll_counts[base] += 1
                if not fused:
                    total.add_bytes(base, self._op_bytes(op))
            elif oc.endswith("-done") or oc in _SKIP_BYTES:
                continue
            else:
                if not fused:
                    total.add_bytes(oc, self._op_bytes(op))
        total.coll_counts = dict(total.coll_counts)
        total.by_opcode = dict(total.by_opcode)
        self._memo[key] = total
        return total

    def _fusion_bytes(self, body: str) -> float:
        """HBM traffic of one fusion execution, use-def-aware: a body
        parameter consumed ONLY by slice-type ops is read at the slice
        size (XLA's FusionCalculateUtilization does the same), otherwise
        at full size; writes = the root's output."""
        key = ("__fusion_bytes__", body)
        if key in self._memo:
            return self._memo[key].bytes
        ops = self.comps.get(body, ())
        consumers: Dict[str, List[_Op]] = defaultdict(list)
        for op in ops:
            for o in op.operands:
                consumers[o].append(op)
        total = 0.0
        for op in ops:
            if op.opcode == "parameter":
                cons = consumers.get(op.name, [])
                if cons and all(c.opcode in ("dynamic-slice", "slice",
                                             "gather") for c in cons):
                    total += sum(_shape_bytes(c.result_shape) for c in cons)
                else:
                    total += _shape_bytes(op.result_shape)
            elif op.is_root:
                total += _shape_bytes(op.result_shape)
            elif op.opcode == "fusion":           # nested fusion
                total += sum(self._fusion_bytes(c) for c in op.called)
        self._memo[key] = OpCost(bytes=total)
        return total

    def _op_bytes(self, op: _Op, output_only: bool = False) -> float:
        out_b = _shape_bytes(op.result_shape)
        if output_only:
            # collective payload proxy: the op's RESULT bytes (gathered /
            # reduced tensor), per the roofline brief's operand-size sum
            return float(out_b)
        oc = op.opcode
        # slicing ops read only the slice, not the whole operand (matching
        # XLA's bytes-accessed); update ops read+write only the update
        # window (in-place buffer semantics)
        if oc in ("dynamic-slice", "slice", "gather"):
            return float(2.0 * out_b)
        if oc in ("dynamic-update-slice", "scatter"):
            upd = (_shape_bytes(self.shapes.get(op.operands[1], ""))
                   if len(op.operands) > 1 else out_b)
            return float(2.0 * upd)
        in_b = sum(_shape_bytes(self.shapes.get(o, "")) for o in op.operands)
        return float(out_b + in_b)

    def total(self) -> OpCost:
        return self._comp_cost(self.entry, False)


def analyze(hlo_text: str, top_ops: int = 12) -> Dict[str, float]:
    c = HloCost(hlo_text).total()
    by = sorted((c.by_opcode or {}).items(), key=lambda kv: -kv[1])[:top_ops]
    return {"flops": c.flops, "bytes": c.bytes,
            "collective_bytes": c.coll_bytes,
            "collectives": c.coll_counts or {},
            "bytes_by_opcode": dict(by)}
