"""Overhead attribution: decompose `TaskRecord.overhead` from spans.

The paper (§IV-A) defines per-task scheduling overhead as
``(end - submit) - cpu_time`` with ``cpu_time = init + compute`` — one
scalar.  This module splits that scalar into additive components using
the span trace:

  queue_wait_s — time spent queued while open real capacity existed
                 (workers were busy with other tasks);
  alloc_wait_s — time spent queued with NO open real allocation (the
                 autoalloc bootstrap / SLURM-queue share of the wait);
  dispatch_s   — dispatch decision -> occupancy (the per-task dispatch
                 latency the paper measures in milliseconds on HQ);
  retry_s      — work burned by walltime kills plus retry backoff: each
                 killed attempt's ``[dispatch mark, release]`` interval
                 (its partial init + run cannot be split from the trace
                 — the attempt never completed — so the whole interval
                 is retry; with a `RetryPolicy` the interval extends
                 through the backoff delay to the requeue release);
  quarantine_s — the final burned interval of a poison task that was
                 quarantined after killing `quarantine_after` workers
                 (earlier burned attempts are retry_s as usual);
  speculation_s— hedged-execution surcharge: for tasks that were
                 speculatively re-executed (``task.speculate`` /
                 ``task.hedge_cancel`` in the trace), the share of the
                 record's overhead not explained by the winner lineage's
                 queue/dispatch/retry components — the loser lineage's
                 cost.  Exactly zero for non-hedged tasks;
  init_s       — reported alongside, NOT summed into overhead: the
                 final attempt's server init is part of ``cpu_time`` by
                 the §IV-A definition, but it is the cost warm-start
                 scheduling exists to avoid, so the breakdown surfaces
                 it.

Additivity: ``queue_wait + alloc_wait + dispatch + retry + quarantine +
speculation`` equals the record's unclamped overhead exactly for tasks
that completed or were killed (see `tests/test_obs.py` and the hard
assert in `benchmarks/overhead_attribution.py`, which covers hedged
runs); `attribute_overhead` returns per-task
breakdowns plus aggregate totals, and the drivers surface the totals in
`Executor.metrics()["overhead_attribution"]` and
`ClusterResult.overhead_attribution`.

Multi-tenant runs additionally get ``"by_tenant"``: the same overhead
components aggregated per tenant, plus served ``cpu_s`` (init + compute
from the attempt spans) and the deadline SLO tallies
(``deadline_total`` / ``deadline_missed`` / ``deadline_miss_rate``) —
the per-tenant accounting the broker service reports.  Tasks with no
recorded tenant fall under ``"default"``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

_TERMINAL = ("task.ok", "task.failed", "task.timeout", "task.killed",
             "task.lost", "task.quarantined")


@dataclasses.dataclass
class OverheadBreakdown:
    """Additive decomposition of one task's scheduling overhead."""
    task_id: str
    queue_wait_s: float = 0.0
    alloc_wait_s: float = 0.0
    dispatch_s: float = 0.0
    retry_s: float = 0.0
    quarantine_s: float = 0.0
    speculation_s: float = 0.0
    init_s: float = 0.0           # informational: final-attempt init
    status: str = ""

    @property
    def overhead_s(self) -> float:
        """The §IV-A overhead this breakdown decomposes (init excluded:
        it is cpu_time by definition)."""
        return (self.queue_wait_s + self.alloc_wait_s + self.dispatch_s
                + self.retry_s + self.quarantine_s + self.speculation_s)

    def as_dict(self) -> Dict[str, float]:
        return {"queue_wait_s": self.queue_wait_s,
                "alloc_wait_s": self.alloc_wait_s,
                "dispatch_s": self.dispatch_s,
                "retry_s": self.retry_s,
                "quarantine_s": self.quarantine_s,
                "speculation_s": self.speculation_s,
                "init_s": self.init_s,
                "overhead_s": self.overhead_s}


def _merge(intervals: List[Tuple[float, float]]
           ) -> List[Tuple[float, float]]:
    out: List[Tuple[float, float]] = []
    for lo, hi in sorted(intervals):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def _overlap(lo: float, hi: float,
             merged: List[Tuple[float, float]]) -> float:
    total = 0.0
    for a, b in merged:
        if b <= lo:
            continue
        if a >= hi:
            break
        total += min(hi, b) - max(lo, a)
    return total


def capacity_intervals(events: Iterable) -> List[Tuple[float, float]]:
    """Merged wall-time intervals during which at least one open REAL
    allocation was running (virtual surrogate allocations are not
    capacity).  Derived from the ``alloc.running`` B/E spans; an
    unclosed B extends to the last event timestamp."""
    events = list(events)
    end_of_trace = max((e[0] for e in events), default=0.0)
    open_b: Dict[Tuple[int, int], float] = {}
    spans: List[Tuple[float, float]] = []
    for ts, ph, name, pid, tid, _dur, args in events:
        if name != "alloc.running":
            continue
        if ph == "B":
            if args and args.get("virtual"):
                continue
            open_b[(pid, tid)] = ts
        elif ph == "E":
            start = open_b.pop((pid, tid), None)
            if start is not None:
                spans.append((start, ts))
    spans.extend((start, end_of_trace) for start in open_b.values())
    return _merge(spans)


def attribute_overhead(events: Iterable) -> Dict[str, Any]:
    """Per-task `OverheadBreakdown`s + aggregate totals from a tracer's
    event list (`Tracer.events()`).  Tasks with incomplete data (events
    dropped by the ring buffer) are still reported with what survived.
    """
    events = list(events)
    capacity = capacity_intervals(events)
    tasks: Dict[str, OverheadBreakdown] = {}
    # per-tenant SLO sidecar state, keyed by task id (tenant/deadline
    # ride on the first-attempt task.queued instant; cpu and terminal
    # time come from the attempt spans)
    tenant_of: Dict[str, str] = {}
    deadline_of: Dict[str, float] = {}
    cpu_of: Dict[str, float] = {}
    end_of: Dict[str, float] = {}
    submit_of: Dict[str, float] = {}
    hedged: set = set()

    def task(args) -> Optional[OverheadBreakdown]:
        tid = args.get("task") if args else None
        if tid is None:
            return None
        bd = tasks.get(tid)
        if bd is None:
            bd = tasks[tid] = OverheadBreakdown(task_id=tid)
        return bd

    for ts, ph, name, _pid, _tid, dur, args in events:
        if name == "task.queued" and ph == "X":
            bd = task(args)
            if bd is not None:
                busy = _overlap(ts, ts + dur, capacity)
                bd.queue_wait_s += busy
                bd.alloc_wait_s += dur - busy
        elif name == "task.queued" and ph == "i" and args:
            tid = args.get("task")
            if tid is not None:
                if tid not in submit_of or ts < submit_of[tid]:
                    submit_of[tid] = ts
                if "tenant" in args:
                    tenant_of[tid] = args["tenant"]
                if "deadline" in args:
                    deadline_of[tid] = float(args["deadline"])
        elif name == "task.dispatch" and ph == "X":
            bd = task(args)
            if bd is not None:
                bd.dispatch_s += dur
        elif name == "task.init" and ph == "X":
            bd = task(args)
            if bd is not None:
                bd.init_s += dur
                tid = args.get("task")
                if tid is not None:
                    cpu_of[tid] = cpu_of.get(tid, 0.0) + \
                        float(args.get("init", dur))
        elif name == "task.run" and ph == "X" and args:
            tid = args.get("task")
            if tid is not None:
                cpu_of[tid] = cpu_of.get(tid, 0.0) + \
                    float(args.get("compute", dur))
        elif name in ("task.requeue", "task.killed") and ph == "i":
            bd = task(args)
            if bd is not None and args and "since" in args:
                # a backoff requeue is *released* later than the kill;
                # the retry interval runs to the release so it abuts the
                # next attempt's queued span (additivity)
                until = float(args.get("release", ts))
                bd.retry_s += max(until - float(args["since"]), 0.0)
        elif name == "task.quarantined" and ph == "i":
            bd = task(args)
            if bd is not None and args and "since" in args:
                bd.quarantine_s += max(ts - float(args["since"]), 0.0)
        elif name in ("task.speculate", "task.hedge_cancel") \
                and ph == "i" and args:
            tid = args.get("task")
            if tid is not None:
                hedged.add(tid)
        if name in _TERMINAL and ph == "i":
            bd = task(args)
            if bd is not None:
                bd.status = name.split(".", 1)[1]
                end_of[bd.task_id] = ts

    # hedged tasks: the loser lineage's cost never shows up as spans
    # (its queued entry is dropped at hedge_cancel), so the record's
    # overhead exceeds what the winner-lineage components explain.  The
    # remainder IS the speculation surcharge — assigned by balancing
    # against the trace-measured overhead so the decomposition stays
    # exactly additive.
    for tid in hedged:
        bd = tasks.get(tid)
        end = end_of.get(tid)
        sub = submit_of.get(tid)
        if bd is None or end is None or sub is None:
            continue
        measured = max((end - sub) - cpu_of.get(tid, 0.0), 0.0)
        accounted = (bd.queue_wait_s + bd.alloc_wait_s + bd.dispatch_s
                     + bd.retry_s + bd.quarantine_s)
        bd.speculation_s = max(measured - accounted, 0.0)

    totals = {"queue_wait_s": 0.0, "alloc_wait_s": 0.0, "dispatch_s": 0.0,
              "retry_s": 0.0, "quarantine_s": 0.0, "speculation_s": 0.0,
              "init_s": 0.0, "overhead_s": 0.0}
    by_tenant: Dict[str, Dict[str, float]] = {}
    for bd in tasks.values():
        d = bd.as_dict()
        for k in totals:
            totals[k] += d[k]
        tenant = tenant_of.get(bd.task_id, "default")
        agg = by_tenant.get(tenant)
        if agg is None:
            agg = by_tenant[tenant] = dict.fromkeys(totals, 0.0)
            agg.update(n_tasks=0.0, cpu_s=0.0, deadline_total=0.0,
                       deadline_missed=0.0, deadline_miss_rate=0.0)
        for k in totals:
            agg[k] += d[k]
        agg["n_tasks"] += 1.0
        agg["cpu_s"] += cpu_of.get(bd.task_id, 0.0)
        deadline = deadline_of.get(bd.task_id)
        if deadline is not None:
            agg["deadline_total"] += 1.0
            end = end_of.get(bd.task_id)
            # no terminal event in the trace window counts as a miss:
            # an SLO that never resolved is not an SLO that was met
            if end is None or end > deadline:
                agg["deadline_missed"] += 1.0
    for agg in by_tenant.values():
        if agg["deadline_total"]:
            agg["deadline_miss_rate"] = (agg["deadline_missed"]
                                         / agg["deadline_total"])
    return {"per_task": tasks, "totals": totals, "by_tenant": by_tenant,
            "n_tasks": len(tasks)}


def format_breakdown(result: Dict[str, Any]) -> str:
    """Human-readable aggregate table (benchmarks print this)."""
    totals = result["totals"]
    overhead = totals["overhead_s"]
    lines = [f"overhead attribution over {result['n_tasks']} tasks "
             f"(total {overhead:.3f}s):"]
    for key in ("queue_wait_s", "alloc_wait_s", "dispatch_s", "retry_s",
                "quarantine_s", "speculation_s"):
        share = totals[key] / overhead if overhead > 0 else 0.0
        lines.append(f"  {key:<13} {totals[key]:>12.3f}s  "
                     f"({share:6.1%})")
    lines.append(f"  {'init_s':<13} {totals['init_s']:>12.3f}s  "
                 f"(cpu_time by definition, not overhead)")
    return "\n".join(lines)
