"""Trace replay: reconstruct a recorded run's workload and overheads.

`repro.obs.calib` fits *distributions* from a trace; this module goes
one step further and replays the *specific run*: the recorded arrivals
become a `TraceTask` list, the recorded per-task compute seconds become
the replay runtimes, and the recorded overhead draws (queue waits, cold
inits, dispatch latency) become a `ReplayBackendSpec` — a drop-in
`BackendSpec` whose `draw_queue_wait` pops the recorded values in
submission order (FIFO) instead of sampling.

The exactness contract (asserted in `tests/test_calib.py`): a trace
recorded by a seeded `simulate_cluster` run, replayed through
`simulate_cluster` with the same configuration and `replay.spec(base)`,
reproduces the original per-task records and makespan EXACTLY — bitwise,
not approximately.  That works because the sim's only randomness is the
queue-wait draws (replayed FIFO from the exact values recorded in
``alloc.queued`` args, including draws of allocations later cancelled
while queued), and every other overhead is a spec constant recorded
exactly by the ``trace.spec`` instant (span durations are endpoint
differences and lose the last ulp; the args route does not).

For traces that did not capture a task's runtime — killed-terminal tasks
never completed an attempt, lost tasks never started one — the replay
substitutes: ``inf`` for killed tasks (a task that outlives every
allocation it is given is killed on the same attempt schedule as the
original; a finite guess could let it finish early and change the run),
and prior / per-model median / time_request / `default_runtime` for lost
tasks (whose runtime cannot influence a faithful replay anyway — a task
the original run never served is never served by the replay either).

Live traces replay the same way, just without the bitwise guarantee:
the live executor's overheads are wall-clock facts, so the replayed sim
is the *model under test* — `benchmarks/calibration.py` compares its
phase attribution against the live trace's, before and after
calibration.  Surrogate-offloaded attempts are replayed as real runs of
their recorded compute (the offload decision itself is policy state the
trace does not carry).
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from collections import deque
from typing import Any, Deque, Dict, List, Mapping, Optional, Sequence

from repro.core.backends import BackendSpec
from repro.cluster.traces import TraceTask
from repro.obs.trace import TraceEvent, read_jsonl


@dataclasses.dataclass(frozen=True)
class ReplayBackendSpec(BackendSpec):
    """A `BackendSpec` that replays recorded overheads.

    `draw_queue_wait` pops the recorded queue waits in submission order
    (falling back to the base parametric draw when the recording runs
    dry — e.g. a replay configured to submit more allocations than the
    original run did); `queue_wait_median` stays the base model, so
    autoalloc cost scoring is unchanged.  `server_init_for` answers the
    per-model recorded cold-init cost.  Scalar `dispatch_latency` /
    `server_init` / `queue_wait_sigma` fields carry the originating
    spec's exact constants when the trace recorded a ``trace.spec``
    instant (sim and parity traces do), else medians of the observed
    spans.  Build instances via `TraceReplay.spec` — each call gets a
    fresh FIFO, so one recording can feed many replays.
    """
    queue_fifo: Any = dataclasses.field(default=None, compare=False,
                                        repr=False)
    init_by_model: Mapping[str, float] = \
        dataclasses.field(default_factory=dict, compare=False, repr=False)
    replayed_from: str = ""

    def draw_queue_wait(self, rng, alloc_request_s: float,
                        n_cpus: int = 1) -> float:
        if self.queue_fifo:
            return self.queue_fifo.popleft()
        return super().draw_queue_wait(rng, alloc_request_s, n_cpus)

    def server_init_for(self, model: str) -> float:
        return self.init_by_model.get(model, self.server_init)


class TraceReplay:
    """Parsed form of one recorded trace, ready to re-run.

    Parameters
    ----------
    events:          `TraceEvent` tuples (a `Tracer.events()` list or
                     `read_jsonl` output).
    priors:          optional ``{model: runtime_seconds}`` analytical
                     priors (e.g. `repro.obs.calib.hlo_runtime_prior`
                     over an `HloCost`) used for tasks the trace never
                     timed.
    default_runtime: last-resort runtime for an untimed task of an
                     unobserved model with no prior and no time_request.
    """

    def __init__(self, events: Sequence[TraceEvent], *,
                 priors: Optional[Mapping[str, float]] = None,
                 default_runtime: float = 1.0,
                 label: str = "trace"):
        self.priors = dict(priors or {})
        self.default_runtime = float(default_runtime)
        self.label = label
        self.meta: Dict[str, Any] = {}
        # arrival-order reconstruction state
        self._arrivals: List[Dict[str, Any]] = []     # attempt-1 queued args
        self._runtimes: Dict[Any, float] = {}         # task -> compute
        self._model_of: Dict[Any, str] = {}
        self._killed: set = set()
        self._completed: set = set()
        self.queue_waits: List[float] = []            # submission order
        self._init_samples: Dict[str, List[float]] = {}
        self._dispatch_samples: List[float] = []
        self._parse(events)

    @classmethod
    def from_jsonl(cls, path: str, **kw) -> "TraceReplay":
        kw.setdefault("label", path)
        return cls(read_jsonl(path), **kw)

    # ------------------------------------------------------------------
    def _parse(self, events: Sequence[TraceEvent]) -> None:
        for ts, ph, name, pid, tid, dur, args in events:
            a = args or {}
            if ph == "i":
                if name == "trace.spec":
                    self.meta = dict(a)
                elif name == "task.queued" and a.get("attempt", 1) == 1:
                    row = dict(a)
                    row["t"] = ts
                    self._arrivals.append(row)
                    if "model" in a:
                        self._model_of[a.get("task")] = a["model"]
                elif name == "task.killed":
                    self._killed.add(a.get("task"))
            elif ph == "X":
                if name == "task.run":
                    if a.get("status", "ok") == "ok":
                        tid_ = a.get("task")
                        self._runtimes[tid_] = a.get("compute", dur)
                        self._completed.add(tid_)
                        if "model" in a:
                            self._model_of.setdefault(tid_, a["model"])
                elif name == "task.init":
                    model = a.get("model")
                    if model is not None:
                        self._init_samples.setdefault(model, []).append(
                            a.get("init", dur))
                elif name == "task.dispatch":
                    self._dispatch_samples.append(dur)
            elif ph == "B" and name == "alloc.queued" \
                    and not a.get("virtual") and "queue_wait" in a:
                self.queue_waits.append(float(a["queue_wait"]))

    # ------------------------------------------------------------------
    def runtime_of(self, task: Any) -> float:
        """The replay runtime for one recorded task (see module doc for
        the untimed-task substitution ladder)."""
        rt = self._runtimes.get(task)
        if rt is not None:
            return rt
        if task in self._killed:
            return math.inf
        model = self._model_of.get(task)
        if model in self.priors:
            return float(self.priors[model])
        timed = [v for t, v in self._runtimes.items()
                 if self._model_of.get(t) == model and math.isfinite(v)]
        if timed:
            return float(statistics.median(timed))
        row = next((r for r in self._arrivals if r.get("task") == task),
                   None)
        if row is not None and row.get("time_request") is not None:
            return float(row["time_request"])
        return self.default_runtime

    def trace(self) -> List[TraceTask]:
        """The recorded workload as a `TraceTask` list, in arrival order
        (so `trace_requests` re-derives the original task indexing)."""
        out: List[TraceTask] = []
        for row in self._arrivals:
            out.append(TraceTask(
                t=float(row["t"]),
                runtime=self.runtime_of(row.get("task")),
                model_name=row.get("model", "model"),
                time_request=row.get("time_request"),
                n_cpus=int(row.get("n_cpus", 1)),
                parameters=row.get("parameters"),
                tenant=row.get("tenant", "default")))
        return out

    def spec(self, base: BackendSpec) -> ReplayBackendSpec:
        """A fresh replay spec over `base` (fresh queue-wait FIFO per
        call): exact recorded constants where the trace has them, base
        values elsewhere."""
        fields = {f.name: getattr(base, f.name)
                  for f in dataclasses.fields(BackendSpec)}
        if "dispatch_latency" in self.meta:
            fields["dispatch_latency"] = float(self.meta["dispatch_latency"])
        elif self._dispatch_samples:
            fields["dispatch_latency"] = \
                float(statistics.median(self._dispatch_samples))
        init_by_model = {m: float(statistics.median(v))
                         for m, v in self._init_samples.items() if v}
        if "server_init" in self.meta:
            fields["server_init"] = float(self.meta["server_init"])
        elif init_by_model:
            fields["server_init"] = \
                float(statistics.median(list(init_by_model.values())))
        if "queue_wait_sigma" in self.meta:
            fields["queue_wait_sigma"] = float(self.meta["queue_wait_sigma"])
        fields["name"] = f"{base.name}+replay"
        fifo: Deque[float] = deque(self.queue_waits)
        return ReplayBackendSpec(queue_fifo=fifo,
                                 init_by_model=init_by_model,
                                 replayed_from=self.label, **fields)

    def summary(self) -> Dict[str, Any]:
        return {"n_tasks": len(self._arrivals),
                "n_timed": len(self._runtimes),
                "n_killed": len(self._killed),
                "n_queue_waits": len(self.queue_waits),
                "has_spec_meta": bool(self.meta),
                "models": sorted({r.get("model", "model")
                                  for r in self._arrivals})}


def replay_cluster(base_spec: BackendSpec, source: Any, **sim_kw):
    """One-call replay: parse `source` (JSONL path, event list, or a
    `TraceReplay`) and run it through `simulate_cluster` over
    `base_spec` with the recorded workload and overhead draws."""
    from repro.cluster.sim import simulate_cluster
    if isinstance(source, TraceReplay):
        replay = source
    elif isinstance(source, str):
        replay = TraceReplay.from_jsonl(source)
    else:
        replay = TraceReplay(source)
    return simulate_cluster(replay.spec(base_spec), replay.trace(),
                            **sim_kw)
