"""Trace-driven calibration of the `BackendSpec` overhead model.

The parity harness (`repro.cluster.parity`) proves sim == live *given*
the `BackendSpec` lognormal overhead model; nothing there checks the
model against observed behaviour.  This module closes that gap, after
"An Approach for Realistically Simulating the Performance of Scientific
Applications on HPC Systems" (PAPERS.md): ingest a recorded trace
(`repro.obs.trace` JSONL from a live `Executor`, a traced sim run, or
any real-cluster log serialised to the same schema) into per-phase
empirical distributions and fit them with the *same parametric form the
spec draws from* — `lognormal(rng, median, sigma)` — so the fitted
parameters drop straight into `simulate_cluster` / `Executor`.

Pipeline:

  * `extract_phase_samples` pulls per-phase samples out of trace events,
    keyed the way the spec's draws are keyed: queue waits by
    (allocation walltime request, group size) from ``alloc.queued``
    spans (the DRAWN value recorded in args, not the span length — a
    cancelled allocation's span is shorter than its draw), cold-start
    init and runtime by model from ``task.init`` / ``task.run``,
    dispatch pooled (a backend property, not a model property);
  * `fit_phase` runs lognormal MLE (mu/sigma on logs; median = e^mu)
    and a Kolmogorov–Smirnov goodness-of-fit test; when KS rejects
    lognormal at `alpha`, the `PhaseFit` keeps the empirical CDF and
    `draw` falls back to inverse-ECDF sampling with linear
    interpolation — heavy tails and bimodal phases calibrate too;
  * `calibrate` assembles a `CalibratedBackendSpec`: a frozen
    `BackendSpec` subclass whose `queue_wait_median` / `draw_queue_wait`
    / `server_init_for` answer from the fits (nearest-request-key
    matching for queue waits) and fall back to the base spec wherever
    the trace has no coverage.  It is a drop-in spec: every consumer
    (`simulate_cluster`, `AutoAllocator`, `Executor`) works unchanged.

For jax tasks with no recorded runtimes, `hlo_runtime_prior` turns a
`repro.launch.hlo_cost` analysis into a roofline runtime estimate
(max(flops/peak, bytes/bandwidth)) that `calibrate(priors=...)` installs
as an analytical prior `PhaseFit` — the simulator can cost a model it
has never observed.

`CalibrationMonitor` is the online half: the drivers stream observed
per-attempt overheads (`observe_attempt`) and granted queue waits
(`observe_queue_wait`, from the shared `LifecycleStepper`) into it; the
monitor tracks rolling log-ratio residuals between model-predicted and
observed values per phase, writes ``calib_*`` metrics into a
`MetricsRegistry`, and emits ``calib.drift`` instants into the Tracer
when a phase's rolling mean leaves the band — with hysteresis, so one
excursion is one alarm.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.backends import (QUEUE_WAIT_SATURATION_S, BackendSpec,
                                 lognormal)
from repro.obs.trace import TraceEvent, read_jsonl

# below this, a log() would blow up; observed zeros (live ms-dispatch)
# are floored here for fitting and the KS test does the rejecting
_EPS = 1e-9

# phases a PhaseFit can describe; "runtime" is per-model compute, the
# other three are the spec's overhead components
PHASES = ("queue_wait", "init", "dispatch", "runtime")


# ---------------------------------------------------------------------------
# lognormal MLE + Kolmogorov–Smirnov goodness of fit (no scipy)
# ---------------------------------------------------------------------------
def fit_lognormal(samples: Sequence[float]) -> Tuple[float, float]:
    """MLE for the `lognormal(rng, median, sigma)` parameterisation:
    ``median = exp(mean(log x))``, ``sigma = std(log x)`` (population).
    Non-positive samples are floored at a tiny epsilon — if they carry
    real mass the KS test will reject and the ECDF fallback takes over."""
    if not len(samples):
        raise ValueError("fit_lognormal needs at least one sample")
    logs = np.log(np.maximum(np.asarray(samples, dtype=float), _EPS))
    return float(math.exp(logs.mean())), float(logs.std())


def _phi(z: float) -> float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))


def _kolmogorov_pvalue(d: float, n: int) -> float:
    """Asymptotic Kolmogorov p-value with the Stephens small-sample
    correction ``lambda = (sqrt(n) + 0.12 + 0.11/sqrt(n)) * D``.  The
    parameters were estimated from the same sample, which makes this
    p-value conservative towards *accepting* lognormal (the Lilliefors
    critical values are tighter) — acceptable here because the cost of a
    false accept is a lognormal approximation, not a wrong answer: the
    fitted median still matches the sample's log-mean."""
    lam = (math.sqrt(n) + 0.12 + 0.11 / math.sqrt(n)) * d
    if lam < 1e-3:
        return 1.0
    s = 0.0
    for k in range(1, 101):
        term = 2.0 * (-1.0) ** (k - 1) * math.exp(-2.0 * k * k * lam * lam)
        s += term
        if abs(term) < 1e-10:
            break
    return float(min(max(s, 0.0), 1.0))


def ks_lognormal(samples: Sequence[float], median: float,
                 sigma: float) -> Tuple[float, float]:
    """KS statistic and p-value of `samples` against
    LogNormal(median, sigma).  Degenerate fits (sigma ~ 0) are judged by
    whether the sample itself is (nearly) constant."""
    xs = np.sort(np.maximum(np.asarray(samples, dtype=float), _EPS))
    n = len(xs)
    if n == 0:
        return 0.0, 1.0
    if sigma <= _EPS or median <= 0:
        # the model is a point mass at `median`: perfect iff the sample
        # is that constant
        spread = float(xs[-1] - xs[0])
        rel = spread / max(abs(median), _EPS)
        return (0.0, 1.0) if rel < 1e-9 else (1.0, 0.0)
    mu = math.log(median)
    cdf = np.array([_phi((math.log(x) - mu) / sigma) for x in xs])
    i = np.arange(n, dtype=float)
    d_plus = float(np.max((i + 1.0) / n - cdf))
    d_minus = float(np.max(cdf - i / n))
    d = max(d_plus, d_minus, 0.0)
    return d, _kolmogorov_pvalue(d, n)


# ---------------------------------------------------------------------------
# one fitted phase distribution
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PhaseFit:
    """One phase's fitted distribution: lognormal when KS accepts it
    (`lognormal_ok`), empirical CDF otherwise.  `samples` is the sorted
    sample tuple (empty for analytical priors), so the ECDF fallback and
    any later re-fit carry their own evidence."""
    phase: str                       # one of PHASES
    key: Any                         # model name, (walltime, n) — or None
    n: int
    median: float
    sigma: float
    mean: float
    ks_stat: float
    ks_pvalue: float
    lognormal_ok: bool
    samples: Tuple[float, ...] = ()
    source: str = "trace"            # "trace" | "prior"

    def draw(self, rng) -> float:
        """One seeded draw from the fitted distribution (the same rng
        contract as `BackendSpec.draw_queue_wait`)."""
        if self.lognormal_ok or len(self.samples) < 2:
            return lognormal(rng, self.median, self.sigma)
        return self.quantile(float(rng.uniform()))

    def quantile(self, u: float) -> float:
        """Inverse empirical CDF with linear interpolation."""
        s = self.samples
        if not s:
            return self.median
        u = min(max(u, 0.0), 1.0)
        pos = u * (len(s) - 1)
        i = int(pos)
        if i >= len(s) - 1:
            return float(s[-1])
        frac = pos - i
        return float(s[i] + (s[i + 1] - s[i]) * frac)

    def describe(self) -> str:
        form = "lognormal" if self.lognormal_ok else "ecdf"
        key = "*" if self.key is None else self.key
        return (f"{self.phase:>10s} {key!s:>20s} n={self.n:<5d} "
                f"median={self.median:.4g}s sigma={self.sigma:.3f} "
                f"[{form}, ks p={self.ks_pvalue:.3f}, {self.source}]")


def fit_phase(phase: str, key: Any, samples: Sequence[float], *,
              alpha: float = 0.05) -> PhaseFit:
    """Fit one phase sample set: lognormal MLE, KS gate at `alpha`."""
    arr = np.maximum(np.asarray(samples, dtype=float), 0.0)
    median, sigma = fit_lognormal(arr)
    if float(arr.max(initial=0.0)) <= _EPS:
        # all-zero phase (live ms dispatch measures as 0): the honest
        # fit is a point mass at zero, which lognormal represents as
        # median 0 (lognormal() returns 0.0 for median <= 0)
        median, sigma = 0.0, 0.0
    stat, pvalue = ks_lognormal(arr, median, sigma)
    return PhaseFit(
        phase=phase, key=key, n=int(len(arr)), median=median, sigma=sigma,
        mean=float(arr.mean()) if len(arr) else 0.0,
        ks_stat=stat, ks_pvalue=pvalue,
        lognormal_ok=bool(pvalue >= alpha),
        samples=tuple(float(x) for x in np.sort(arr)))


def prior_fit(phase: str, key: Any, median: float,
              sigma: float = 0.3) -> PhaseFit:
    """An analytical prior posing as a fit (``n=0``, no samples): used
    for models the trace never observed — e.g. an `hlo_runtime_prior`
    roofline estimate for a jax task."""
    return PhaseFit(phase=phase, key=key, n=0, median=float(median),
                    sigma=float(sigma), mean=float(median), ks_stat=0.0,
                    ks_pvalue=1.0, lognormal_ok=True, samples=(),
                    source="prior")


def hlo_runtime_prior(cost: Any, *, peak_flops: float = 1.0e12,
                      mem_bw: float = 1.0e11,
                      coll_bw: float = 2.5e10,
                      latency_floor_s: float = 1e-4) -> float:
    """Roofline runtime estimate (seconds) from a `repro.launch.hlo_cost`
    analysis: the kernel is bound by whichever of compute, HBM traffic
    or collective traffic takes longest, plus a launch-latency floor.
    `cost` is an `OpCost` (or anything with ``flops`` / ``bytes`` /
    ``coll_bytes`` attributes, or a dict with those keys)."""
    def _get(name: str) -> float:
        if isinstance(cost, dict):
            return float(cost.get(name, 0.0))
        return float(getattr(cost, name, 0.0))

    t = max(_get("flops") / max(peak_flops, 1.0),
            _get("bytes") / max(mem_bw, 1.0),
            _get("coll_bytes") / max(coll_bw, 1.0))
    return t + latency_floor_s


# ---------------------------------------------------------------------------
# trace ingestion
# ---------------------------------------------------------------------------
def extract_phase_samples(
        events: Sequence[TraceEvent]
) -> Dict[Tuple[str, Any], List[float]]:
    """Group a trace's per-phase samples under the keys the spec's draws
    use.  Exact-args values (``init`` / ``compute`` / ``queue_wait``)
    are preferred over span durations; older traces without them fall
    back to the span length.

      * ``("queue_wait", (walltime_s | None, n_workers | None))`` — one
        sample per real allocation submission;
      * ``("init", model)`` and ``("init", None)`` (pooled) — cold-start
        server init per attempt that paid one;
      * ``("dispatch", None)`` — pooled per-attempt dispatch latency;
      * ``("runtime", model)`` — compute seconds of ok/timeout runs.
    """
    out: Dict[Tuple[str, Any], List[float]] = {}
    open_queued: Dict[int, Tuple[float, dict]] = {}   # pid -> (ts, args)

    def add(phase: str, key: Any, value: float) -> None:
        out.setdefault((phase, key), []).append(float(value))

    for ts, ph, name, pid, tid, dur, args in events:
        a = args or {}
        if ph == "X":
            if name == "task.init":
                v = a.get("init", dur)
                model = a.get("model")
                add("init", None, v)             # pooled
                if model is not None:
                    add("init", model, v)
            elif name == "task.dispatch":
                add("dispatch", None, a.get("latency", dur))
            elif name == "task.run":
                if a.get("status", "ok") in ("ok", "timeout"):
                    add("runtime", a.get("model"), a.get("compute", dur))
        elif name == "alloc.queued" and not a.get("virtual"):
            if ph == "B":
                if "queue_wait" in a:
                    add("queue_wait",
                        (a.get("walltime_s"), a.get("n_workers")),
                        a["queue_wait"])
                else:
                    open_queued[pid] = (ts, a)
            elif ph == "E" and pid in open_queued:
                b_ts, b_args = open_queued.pop(pid)
                add("queue_wait",
                    (b_args.get("walltime_s"), b_args.get("n_workers")),
                    max(ts - b_ts, 0.0))
    return out


def _wall_key(alloc_request_s: Optional[float]) -> float:
    """Queue-wait matching distance coordinate: unbounded requests sit
    at the saturation walltime, exactly as `queue_wait_median` treats
    them (``min(walltime, saturation)``)."""
    if alloc_request_s is None or not math.isfinite(alloc_request_s):
        return QUEUE_WAIT_SATURATION_S
    return min(float(alloc_request_s), QUEUE_WAIT_SATURATION_S)


# ---------------------------------------------------------------------------
# the calibrated spec
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CalibratedBackendSpec(BackendSpec):
    """A `BackendSpec` whose overhead answers come from trace fits.

    Drop-in: `queue_wait_median` / `draw_queue_wait` consult the fitted
    queue-wait distribution whose recorded request signature is nearest
    (log-walltime distance, saturation applied) and fall back to the
    base parametric model when the trace recorded no allocations;
    `server_init` / `dispatch_latency` scalar fields already hold the
    pooled fitted medians (see `calibrate`), and `server_init_for`
    refines init per model.  `runtime_fit` exposes per-model runtime
    distributions for predictors/replay; it is not consulted by the
    simulator's dispatch (runtimes come from the trace being run).
    """
    fits: Mapping[Tuple[str, Any], PhaseFit] = \
        dataclasses.field(default_factory=dict, compare=False, repr=False)
    calibrated_from: str = ""

    # -- fit lookup ------------------------------------------------------
    def fit_for(self, phase: str, key: Any = None) -> Optional[PhaseFit]:
        f = self.fits.get((phase, key))
        if f is None and key is not None:
            f = self.fits.get((phase, None))     # pooled fallback
        return f

    def _queue_fit(self, alloc_request_s: float) -> Optional[PhaseFit]:
        want = _wall_key(alloc_request_s)
        best: Optional[PhaseFit] = None
        best_d = math.inf
        for (phase, key), f in self.fits.items():
            if phase != "queue_wait":
                continue
            wall = key[0] if isinstance(key, tuple) else key
            d = abs(math.log((_wall_key(wall) + 1.0) / (want + 1.0)))
            if d < best_d or (d == best_d and best is not None
                              and f.n > best.n):
                best, best_d = f, d
        return best

    # -- BackendSpec surface ---------------------------------------------
    def queue_wait_median(self, alloc_request_s: float,
                          n_cpus: int = 1) -> float:
        f = self._queue_fit(alloc_request_s)
        if f is None:
            return super().queue_wait_median(alloc_request_s, n_cpus)
        return f.median

    def draw_queue_wait(self, rng, alloc_request_s: float,
                        n_cpus: int = 1) -> float:
        f = self._queue_fit(alloc_request_s)
        if f is None:
            return super().draw_queue_wait(rng, alloc_request_s, n_cpus)
        return f.draw(rng)

    def server_init_for(self, model: str) -> float:
        f = self.fit_for("init", model)
        return f.median if f is not None else self.server_init

    def runtime_fit(self, model: str) -> Optional[PhaseFit]:
        return self.fit_for("runtime", model)

    def describe_fits(self) -> str:
        lines = [f"{self.name}: calibrated from "
                 f"{self.calibrated_from or 'trace'} "
                 f"({len(self.fits)} phase fits)"]
        for (_phase, _key), f in sorted(
                self.fits.items(),
                key=lambda kv: (kv[0][0], repr(kv[0][1]))):
            lines.append("  " + f.describe())
        return "\n".join(lines)


def calibrate(source: Any, base: BackendSpec, *,
              alpha: float = 0.05, min_samples: int = 3,
              priors: Optional[Mapping[str, float]] = None,
              label: str = "") -> CalibratedBackendSpec:
    """Fit a `CalibratedBackendSpec` from a trace.

    `source` is a JSONL path (loaded via `read_jsonl`) or an iterable of
    `TraceEvent` tuples.  Phases with fewer than `min_samples` samples
    keep the base model (queue waits are exempt — one real allocation is
    one whole sample of the distribution that matters most, and a
    single-sample fit is an honest point estimate).  ``priors`` maps
    model name -> analytical runtime median (e.g. `hlo_runtime_prior`)
    installed for models the trace never ran."""
    if isinstance(source, str):
        events: Sequence[TraceEvent] = read_jsonl(source)
        label = label or source
    else:
        events = list(source)
        label = label or f"{len(events)} events"
    groups = extract_phase_samples(events)
    fits: Dict[Tuple[str, Any], PhaseFit] = {}
    for (phase, key), samples in groups.items():
        need = 1 if phase == "queue_wait" else min_samples
        if len(samples) < need:
            continue
        fits[(phase, key)] = fit_phase(phase, key, samples, alpha=alpha)
    if priors:
        for model, median in priors.items():
            if ("runtime", model) not in fits:
                fits[("runtime", model)] = prior_fit("runtime", model,
                                                     median)

    fields = {f.name: getattr(base, f.name)
              for f in dataclasses.fields(BackendSpec)}
    init_pool = fits.get(("init", None))
    if init_pool is not None:
        fields["server_init"] = init_pool.median
    disp = fits.get(("dispatch", None))
    if disp is not None:
        fields["dispatch_latency"] = disp.median
    fields["name"] = f"{base.name}+calib"
    return CalibratedBackendSpec(fits=fits, calibrated_from=label,
                                 **fields)


# ---------------------------------------------------------------------------
# SLURM sacct ingestion
# ---------------------------------------------------------------------------
# the canonical accounting columns the adapter consumes — the default
# `sacct --parsable2 --format=` selection for calibration-grade logs
SACCT_DEFAULT_FIELDS = ("JobID", "JobName", "State", "Submit", "Start",
                        "End", "Elapsed", "Timelimit", "NNodes")

# sacct State (first word; "CANCELLED by 123" and "OUT_OF_MEMORY" included)
# -> the trace schema's task status vocabulary
_SACCT_STATUS = {"COMPLETED": "ok", "TIMEOUT": "timeout",
                 "FAILED": "failed", "CANCELLED": "failed",
                 "NODE_FAIL": "failed", "OUT_OF_MEMORY": "failed",
                 "OUT_OF_ME+": "failed", "PREEMPTED": "failed"}


def parse_slurm_duration(s: Optional[str]) -> Optional[float]:
    """``[DD-]HH:MM:SS[.fff]`` (also ``MM:SS``) -> seconds; None for
    empty/UNLIMITED/Partition_Limit/INVALID — "no bound" and "no value"
    both mean the field contributes nothing."""
    if not s:
        return None
    s = s.strip()
    if not s or s.upper() in ("UNLIMITED", "PARTITION_LIMIT", "INVALID",
                              "NONE", "UNKNOWN"):
        return None
    days = 0.0
    if "-" in s:
        d, s = s.split("-", 1)
        days = float(d)
    parts = s.split(":")
    try:
        nums = [float(p) for p in parts]
    except ValueError:
        return None
    if len(nums) == 3:
        h, m, sec = nums
    elif len(nums) == 2:
        h, (m, sec) = 0.0, nums
    elif len(nums) == 1:
        h, m, sec = 0.0, 0.0, nums[0]
    else:
        return None
    return days * 86400.0 + h * 3600.0 + m * 60.0 + sec


def parse_slurm_time(s: Optional[str]) -> Optional[float]:
    """sacct timestamp (ISO ``YYYY-MM-DDTHH:MM:SS``, or epoch seconds)
    -> epoch seconds; naive timestamps are read as UTC so queue waits
    are environment-independent.  None for Unknown/None/empty."""
    if not s:
        return None
    s = s.strip()
    if not s or s.upper() in ("UNKNOWN", "NONE", "N/A"):
        return None
    try:
        return float(s)                        # epoch-seconds export
    except ValueError:
        pass
    import calendar
    import datetime
    try:
        dt = datetime.datetime.fromisoformat(s)
    except ValueError:
        return None
    if dt.tzinfo is not None:
        return dt.timestamp()
    return float(calendar.timegm(dt.timetuple())) + dt.microsecond / 1e6


def read_sacct(source: Any, *,
               field_map: Optional[Mapping[str, str]] = None,
               delimiter: str = "|",
               strict: bool = True) -> List[TraceEvent]:
    """Ingest real SLURM accounting output as `TraceEvent` tuples — the
    field-mapping adapter that lets `sacct` logs feed `calibrate`
    directly (the `read_jsonl` schema's real-cluster on-ramp).

    `source` is a path to ``sacct --parsable2`` output (or an iterable
    of its lines).  The first row may be the sacct header; without one,
    columns are assumed to be `SACCT_DEFAULT_FIELDS` in order.
    `field_map` renames: canonical field -> the column name the site's
    export uses (e.g. ``{"JobName": "Account"}`` keys runtimes by
    account instead), on top of the header/default layout.

    Per completed job two trace structures come out, keyed exactly the
    way `extract_phase_samples` groups them:

      * an ``alloc.queued`` B/E pair at (Submit, Start) whose B args
        carry ``queue_wait`` = Start − Submit, ``walltime_s`` from
        Timelimit and ``n_workers`` from NNodes — one queue-wait sample
        under the (walltime, size) request signature;
      * a ``task.run`` X span at Start of length Elapsed with
        ``model`` = JobName and ``status`` mapped from State
        (COMPLETED -> ok, TIMEOUT -> timeout, failure states -> failed —
        excluded from runtime fits by the extractor, like any failed
        attempt).

    Job *steps* (``JobID`` containing '.', e.g. ``4242.batch``) are
    accounting detail of their parent job and are skipped.  Jobs still
    pending/running are skipped (no complete sample yet).  Timestamps
    are rebased so the earliest Submit is t=0 — calibration consumes
    differences only.  With ``strict=True`` a malformed row raises
    `ValueError` naming the line; otherwise bad rows are skipped.
    """
    if isinstance(source, str):
        with open(source) as fh:
            lines = fh.read().splitlines()
        label = source
    else:
        lines = [str(ln).rstrip("\n") for ln in source]
        label = "<lines>"
    lines = [ln for ln in lines if ln.strip()]
    if not lines:
        return []

    header = lines[0].split(delimiter)
    if "JobID" in header or (field_map and
                             any(v in header for v in field_map.values())):
        rows = lines[1:]
        columns = header
    else:
        rows = lines
        columns = list(SACCT_DEFAULT_FIELDS)
    fmap = dict(field_map or {})
    index: Dict[str, int] = {}
    for canon in SACCT_DEFAULT_FIELDS:
        name = fmap.get(canon, canon)
        if name in columns:
            index[canon] = columns.index(name)
    missing = [c for c in ("JobID", "State") if c not in index]
    if missing:
        raise ValueError(f"{label}: sacct columns {missing} not found in "
                         f"{columns} (field_map={fmap or None})")

    def field(parts: List[str], canon: str) -> Optional[str]:
        i = index.get(canon)
        if i is None or i >= len(parts):
            return None
        return parts[i]

    jobs: List[Tuple[str, str, str, Optional[float], Optional[float],
                     Optional[float], Optional[float], int]] = []
    for lineno, ln in enumerate(rows, 2 if rows is not lines else 1):
        parts = ln.split(delimiter)
        job_id = field(parts, "JobID") or ""
        if "." in job_id:
            continue                           # a job STEP, not a job
        state = (field(parts, "State") or "").split()[0:1]
        state = state[0].upper() if state else ""
        status = _SACCT_STATUS.get(state)
        if status is None:
            if state in ("", "PENDING", "RUNNING", "REQUEUED",
                         "SUSPENDED"):
                continue                       # not a complete sample yet
            if strict:
                raise ValueError(f"{label}:{lineno}: unknown sacct state "
                                 f"{state!r} for job {job_id}")
            continue
        submit = parse_slurm_time(field(parts, "Submit"))
        start = parse_slurm_time(field(parts, "Start"))
        elapsed = parse_slurm_duration(field(parts, "Elapsed"))
        limit = parse_slurm_duration(field(parts, "Timelimit"))
        try:
            nnodes = int(field(parts, "NNodes") or 1)
        except ValueError:
            nnodes = 1
        name = field(parts, "JobName") or job_id
        jobs.append((job_id, name, status, submit, start, elapsed,
                     limit, nnodes))

    t0 = min((j[3] for j in jobs if j[3] is not None), default=0.0)
    events: List[TraceEvent] = []
    for pid, (job_id, name, status, submit, start, elapsed, limit,
              nnodes) in enumerate(jobs, 1):
        if submit is not None and start is not None and start >= submit:
            args = {"queue_wait": start - submit, "walltime_s": limit,
                    "n_workers": nnodes, "alloc": job_id}
            events.append((submit - t0, "B", "alloc.queued", pid, 0,
                           0.0, args))
            events.append((start - t0, "E", "alloc.queued", pid, 0,
                           0.0, None))
        if start is not None and elapsed is not None:
            events.append((start - t0, "X", "task.run", pid, 0, elapsed,
                           {"model": name, "compute": elapsed,
                            "status": status, "task": job_id}))
    events.sort(key=lambda e: (e[0], e[1] != "B"))
    return events


def sacct_to_jsonl(source: Any, dst: str, **read_kw) -> int:
    """Convert sacct accounting output to the `read_jsonl` trace schema
    on disk (every row `validate_jsonl_row`-clean), so real-cluster logs
    flow through the same files as recorded traces.  Returns the number
    of rows written."""
    import json
    from repro.obs.trace import validate_jsonl_row
    events = read_sacct(source, **read_kw)
    with open(dst, "w") as fh:
        for ts, ph, name, pid, tid, dur, args in events:
            row: Dict[str, Any] = {"ts": ts, "ph": ph, "name": name,
                                   "pid": pid, "tid": tid}
            if ph == "X":
                row["dur"] = dur
            if args is not None:
                row["args"] = args
            problem = validate_jsonl_row(row)
            if problem is not None:            # schema drift = a bug here
                raise AssertionError(f"sacct row fails trace schema: "
                                     f"{problem}")
            fh.write(json.dumps(row) + "\n")
    return len(events)


# ---------------------------------------------------------------------------
# online drift detection
# ---------------------------------------------------------------------------
class CalibrationMonitor:
    """Rolling per-phase residual tracker: model-predicted vs observed.

    The drivers feed it observations at the shared choke points
    (`Executor._complete` / `simulate_cluster` completions via
    `observe_attempt`; `LifecycleStepper._grant` via
    `observe_queue_wait`).  Per phase it keeps a rolling window of
    ``log(observed / predicted)`` ratios; when the window mean's
    magnitude exceeds `drift_logratio` (default ln 2: off by 2x) with at
    least `min_n` observations, one ``calib.drift`` instant is emitted
    into the tracer and ``calib_drift_alarms`` increments — then the
    phase re-arms only after the mean recovers below half the threshold
    (hysteresis), so a sustained excursion is one alarm, not one per
    observation.

    `spec` is the model under test — a plain `BackendSpec` or a
    `CalibratedBackendSpec` (whose per-model init and runtime fits are
    used for prediction when available).
    """

    def __init__(self, spec: BackendSpec, *, registry: Any = None,
                 tracer: Any = None, window: int = 64,
                 drift_logratio: float = math.log(2.0),
                 min_n: int = 8, eps: float = 1e-6,
                 on_alarm: Any = None):
        self.spec = spec
        self.registry = registry
        self.tracer = tracer
        self.window = int(window)
        self.drift_logratio = float(drift_logratio)
        self.min_n = int(min_n)
        self.eps = float(eps)
        # callback fired (best-effort) on every drift alarm with
        # (alarm_dict, now) — e.g. SurrogateOffload.note_drift_alarm, so
        # a drifting cost model auto-disables offload for a cool-down
        self.on_alarm = on_alarm
        self._ratios: Dict[str, deque] = {}
        self._armed: Dict[str, bool] = {}
        self.alarms: List[Dict[str, Any]] = []
        self.n_observed = 0

    # -- feeding ---------------------------------------------------------
    def observe_attempt(self, model: str, *, dispatch_s: float,
                        init_s: float, compute_s: Optional[float] = None,
                        now: float = 0.0) -> None:
        """One completed attempt's observed overheads (and optionally
        compute) against the spec's predictions."""
        self.observe("dispatch", self.spec.dispatch_latency, dispatch_s,
                     now, key=model)
        if init_s > 0:
            pred = (self.spec.server_init_for(model)
                    if hasattr(self.spec, "server_init_for")
                    else self.spec.server_init)
            self.observe("init", pred, init_s, now, key=model)
        if compute_s is not None and hasattr(self.spec, "runtime_fit"):
            fit = self.spec.runtime_fit(model)
            if fit is not None:
                self.observe("runtime", fit.median, compute_s, now,
                             key=model)

    def observe_queue_wait(self, alloc: Any, now: float) -> None:
        """A granted allocation's observed queue wait vs the model."""
        pred = self.spec.queue_wait_median(
            getattr(alloc, "walltime_s", math.inf))
        self.observe("queue_wait", pred, float(alloc.queue_wait), now,
                     key=getattr(alloc, "alloc_id", None))

    def observe(self, phase: str, predicted: float, observed: float,
                now: float, key: Any = None) -> None:
        self.n_observed += 1
        ratio = math.log((max(observed, 0.0) + self.eps)
                         / (max(predicted, 0.0) + self.eps))
        if self.registry is not None:
            self.registry.observe(f"calib_{phase}_abs_residual",
                                  abs(observed - predicted))
        win = self._ratios.get(phase)
        if win is None:
            win = self._ratios[phase] = deque(maxlen=self.window)
            self._armed[phase] = True
        win.append(ratio)
        if len(win) < self.min_n:
            return
        mean = sum(win) / len(win)
        if self.registry is not None:
            self.registry.set_gauge(f"calib_{phase}_mean_logratio", mean)
        if abs(mean) >= self.drift_logratio:
            if self._armed[phase]:
                self._armed[phase] = False
                self._alarm(phase, mean, predicted, observed, now, key)
        elif abs(mean) <= self.drift_logratio / 2.0:
            self._armed[phase] = True          # recovered: re-arm

    def consume(self, events: Sequence[TraceEvent]) -> int:
        """Offline feeding: replay a recorded trace's observations into
        the monitor (attempts and queue waits, in trace order).  Returns
        the number of observations fed — the after-the-fact drift check
        for logs recorded without a live monitor."""
        fed = 0
        pending_init: Dict[Tuple[Any, int], float] = {}
        pending_disp: Dict[Tuple[Any, int], float] = {}
        for ts, ph, name, pid, tid, dur, args in events:
            a = args or {}
            if ph == "X" and name == "task.init":
                pending_init[(a.get("task"), a.get("attempt", 1))] = \
                    a.get("init", dur)
            elif ph == "X" and name == "task.dispatch":
                pending_disp[(a.get("task"), a.get("attempt", 1))] = dur
            elif ph == "X" and name == "task.run":
                key = (a.get("task"), a.get("attempt", 1))
                self.observe_attempt(
                    a.get("model", ""),
                    dispatch_s=pending_disp.pop(key, 0.0),
                    init_s=pending_init.pop(key, 0.0),
                    compute_s=a.get("compute", dur),
                    now=ts + dur)
                fed += 1
            elif name == "alloc.queued" and not a.get("virtual"):
                if ph == "B" and "queue_wait" in a:
                    wall = a.get("walltime_s")
                    pred = self.spec.queue_wait_median(
                        wall if wall is not None else math.inf)
                    self.observe("queue_wait", pred, a["queue_wait"], ts,
                                 key=a.get("alloc"))
                    fed += 1
        return fed

    # -- alarm plumbing --------------------------------------------------
    def _alarm(self, phase: str, mean: float, predicted: float,
               observed: float, now: float, key: Any) -> None:
        alarm = {"phase": phase, "t": float(now),
                 "mean_logratio": float(mean),
                 "predicted": float(predicted),
                 "observed": float(observed), "key": key}
        self.alarms.append(alarm)
        if self.registry is not None:
            self.registry.inc("calib_drift_alarms")
            self.registry.inc(f"calib_drift_alarms_{phase}")
        if self.tracer is not None:
            self.tracer.instant(
                "calib.drift", ts=now,
                args={"phase": phase,
                      "mean_logratio": float(mean),
                      "predicted": float(predicted),
                      "observed": float(observed)})
        if self.on_alarm is not None:
            try:
                self.on_alarm(alarm, now)
            except Exception:  # noqa: BLE001 — alarms must never kill a run
                pass

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"n_observed": self.n_observed,
                               "n_alarms": len(self.alarms),
                               "phases": {}}
        for phase, win in self._ratios.items():
            if win:
                out["phases"][phase] = {
                    "n": len(win),
                    "mean_logratio": sum(win) / len(win),
                }
        return out
