"""Structured span tracing for sim and live dispatch (`repro.obs`).

One tracer covers both drivers because it is instrumented at the shared
choke points — `Broker.push`, the `LifecycleStepper` phases, and the
completion paths of `simulate_cluster` / `Executor._complete` — and is
timestamped by the *injected clock* (the sim binds its virtual event
time, the executor binds `self._clock`).  A seeded parity run therefore
produces the same span sequence from both drivers: same span names,
task/alloc ids, and virtual-clock timestamps (asserted in
`tests/test_parity.py`).

Event model (Chrome trace-event phases):

  * per-task spans on the scheduler process (pid 0, tid = task index):
    ``task.queued`` (X: queue entry -> dispatch decision),
    ``task.dispatch`` (X: decision -> occupancy), terminal instants
    ``task.ok`` / ``task.failed`` / ``task.timeout`` / ``task.lost``;
  * per-attempt execution spans on the owning allocation's process
    (pid = alloc_id + 1, tid = worker id): ``task.init``, ``task.run``;
  * per-allocation lifecycle spans (pid = alloc_id + 1, tid 0):
    ``alloc.queued`` / ``alloc.running`` / ``alloc.draining`` as B/E
    pairs, terminal ``alloc.expired`` instant — timestamped from the
    `Allocation`'s own fields (submit/grant/end), so they are
    parity-exact and monotone per track;
  * instants for scheduling decisions: ``offload.decide``,
    ``task.steal``, ``task.migrate``, ``task.requeue``, ``task.killed``,
    ``task.quarantined``, ``task.speculate``, ``task.hedge_cancel``,
    ``alloc.spawn`` / ``alloc.kill`` / ``alloc.drain-dry`` /
    ``alloc.cancel``, ``autoalloc.submit`` / ``autoalloc.drain``, and
    ``gp.predict_batch`` compile-shape launches.

Everything lands in a bounded ring buffer (oldest events drop first;
`n_dropped` says how many), exportable as JSONL (`write_jsonl`, loadable
back with schema validation via `read_jsonl`, or streamed incrementally
while the run is live via `stream_to`) and Chrome trace-event JSON
(`to_chrome` / `write_chrome`, loadable in Perfetto).  Tracing is opt-in
everywhere (`tracer=None` default) and the hot-path cost of one event is
a tuple append into a deque (plus one buffered line write when a stream
sink is attached).

Spans carry the exact model inputs calibration needs (`repro.obs.calib`
/ `repro.obs.replay` consume them): ``task.queued`` instants record the
request's model / time_request / n_cpus / parameters on first submit,
``task.init`` / ``task.run`` record the exact init and compute seconds
passed by the driver (a span's ``dur`` is a float *difference* of
endpoints, which is not bit-exact), and ``alloc.queued`` records the
drawn queue wait plus the allocation's shape — so a sim-recorded trace
replays to the original records exactly.
"""
from __future__ import annotations

import json
import math
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

# (ts, ph, name, pid, tid, dur, args): ph in {"B","E","X","i"}; dur is
# meaningful for "X" only; args is a small dict or None
TraceEvent = Tuple[float, str, str, int, int, float, Optional[dict]]

_ALLOC_RANK = {None: -1, "pending": -1, "queued": 0, "running": 1,
               "draining": 2, "expired": 3}


class RingBuffer:
    """Bounded append-only event store: O(1) append, oldest-first drop.

    Also serves as the `LifecycleStepper.events` audit trail bound (the
    unbounded-growth fix), so it supports the list-ish surface the
    drivers use: iteration, `len`, and `list(buf)`.
    """

    __slots__ = ("_buf", "n_seen")

    def __init__(self, capacity: int = 65536):
        self._buf: deque = deque(maxlen=int(capacity))
        self.n_seen = 0

    @property
    def capacity(self) -> int:
        return self._buf.maxlen or 0

    @property
    def n_dropped(self) -> int:
        return self.n_seen - len(self._buf)

    def append(self, item) -> None:
        self.n_seen += 1
        self._buf.append(item)

    def clear(self) -> None:
        self._buf.clear()
        self.n_seen = 0

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self):
        return iter(self._buf)

    def __getitem__(self, i):
        return list(self._buf)[i]

    def __repr__(self) -> str:
        return (f"RingBuffer(len={len(self._buf)}, "
                f"capacity={self.capacity}, dropped={self.n_dropped})")


class Tracer:
    """Low-overhead span/instant recorder shared by sim and live.

    `clock` supplies default timestamps for instants; drivers bind their
    injected clock (`bind_clock`) so both paths stamp the same virtual
    seconds.  All helpers are plain tuple appends — safe under the
    executor's dispatch lock.
    """

    def __init__(self, capacity: int = 65536,
                 clock: Optional[Callable[[], float]] = None):
        self.buf = RingBuffer(capacity)
        self._clock: Callable[[], float] = clock or (lambda: 0.0)
        self._task_tids: Dict[str, int] = {}
        self._queued: Dict[Tuple[str, int], float] = {}
        self._alloc_state: Dict[int, Optional[str]] = {}
        self._alloc_open: Dict[int, str] = {}
        self._pid_labels: Dict[int, str] = {0: "scheduler"}
        self._sink = None                      # incremental JSONL stream

    def bind_clock(self, clock: Callable[[], float]) -> "Tracer":
        self._clock = clock
        return self

    # -- low-level emission ---------------------------------------------
    def emit(self, ph: str, name: str, ts: float, *, pid: int = 0,
             tid: int = 0, dur: float = 0.0,
             args: Optional[dict] = None) -> None:
        ev = (float(ts), ph, name, pid, tid, float(dur), args)
        self.buf.append(ev)
        if self._sink is not None:
            self._sink.write(_jsonl_line(ev))

    def instant(self, name: str, ts: Optional[float] = None, *,
                pid: int = 0, tid: int = 0,
                args: Optional[dict] = None) -> None:
        if ts is None:
            ts = self._clock()
        self.emit("i", name, ts, pid=pid, tid=tid, args=args)

    def span(self, name: str, start: float, end: float, *, pid: int = 0,
             tid: int = 0, args: Optional[dict] = None) -> None:
        self.emit("X", name, start, pid=pid, tid=tid,
                  dur=max(float(end) - float(start), 0.0), args=args)

    # -- task protocol ---------------------------------------------------
    def _tid(self, task_id: str) -> int:
        tid = self._task_tids.get(task_id)
        if tid is None:
            tid = len(self._task_tids)
            self._task_tids[task_id] = tid
        return tid

    def task_queued(self, task_id: str, attempt: int,
                    ts: Optional[float] = None, req: Any = None) -> None:
        """A (task, attempt) entered a scheduler queue (submit, requeue).

        Passing the `EvalRequest` as ``req`` records the request's shape
        (model / time_request / n_cpus / parameters) on the first-attempt
        instant — the metadata `repro.obs.replay` needs to reconstruct
        the workload from the trace alone.  Requeues (attempt > 1) stay
        minimal: the task's identity was already recorded."""
        if ts is None:
            ts = self._clock()
        self._queued[(task_id, attempt)] = float(ts)
        args: dict = {"task": task_id, "attempt": attempt}
        if req is not None and attempt == 1:
            args["model"] = req.model_name
            if getattr(req, "time_request", None) is not None:
                args["time_request"] = float(req.time_request)
            if getattr(req, "n_cpus", 1) != 1:
                args["n_cpus"] = int(req.n_cpus)
            tenant = getattr(req, "tenant", "default")
            if tenant and tenant != "default":
                # default omitted: single-tenant traces stay byte-stable
                args["tenant"] = tenant
            if getattr(req, "deadline", None) is not None:
                args["deadline"] = float(req.deadline)
            params = getattr(req, "parameters", None)
            if _jsonable_matrix(params):
                args["parameters"] = params
        self.instant("task.queued", ts=ts, pid=0, tid=self._tid(task_id),
                     args=args)

    def task_attempt(self, task_id: str, alloc_id: int, wid: int,
                     mark_t: float, start_t: float, init_t: float,
                     end_t: float, attempt: int, status: str,
                     model: Optional[str] = None,
                     compute: Optional[float] = None) -> None:
        """One completed attempt: closes the queued span, records the
        dispatch/init/run spans on the worker track, and stamps the
        terminal instant (``task.<status>``).

        ``model`` and ``compute`` (the driver's exact compute seconds)
        land in the init/run span args so calibration can key samples by
        model and replay can reproduce runtimes bit-exactly (a span's
        ``dur`` is an endpoint difference, which loses the last ulp)."""
        tid = self._tid(task_id)
        q_ts = self._queued.pop((task_id, attempt), mark_t)
        a = {"task": task_id, "attempt": attempt}
        self.span("task.queued", q_ts, mark_t, pid=0, tid=tid, args=a)
        self.span("task.dispatch", mark_t, start_t, pid=0, tid=tid,
                  args={"task": task_id, "attempt": attempt,
                        "alloc": alloc_id})
        pid = alloc_id + 1
        if init_t > 0:
            ia = dict(a)
            ia["init"] = float(init_t)
            if model is not None:
                ia["model"] = model
            self.span("task.init", start_t, start_t + init_t, pid=pid,
                      tid=wid, args=ia)
        ra: dict = {"task": task_id, "attempt": attempt, "status": status}
        if model is not None:
            ra["model"] = model
        if compute is not None:
            ra["compute"] = float(compute)
        self.span("task.run", start_t + init_t, end_t, pid=pid, tid=wid,
                  args=ra)
        self.instant(f"task.{status}", ts=end_t, pid=0, tid=tid, args=a)

    def task_requeue(self, task_id: str, attempt: int, now: float,
                     since: float,
                     release: Optional[float] = None) -> None:
        """An in-flight attempt died with its allocation and was requeued
        at attempt+1.  ``since`` is the killed attempt's dispatch mark:
        the burned ``[since, now]`` interval is retry overhead.  With a
        `RetryPolicy` backoff the requeue is *released* later than the
        kill; ``release`` extends the retry interval to ``[since,
        release]`` (omitted when the requeue is immediate, which keeps
        legacy traces byte-identical)."""
        self._close_queued(task_id, attempt, since)
        args: dict = {"task": task_id, "attempt": attempt,
                      "since": float(since)}
        if release is not None and release > now:
            args["release"] = float(release)
        self.instant("task.requeue", ts=now, pid=0,
                     tid=self._tid(task_id), args=args)

    def task_killed(self, task_id: str, attempt: int, now: float,
                    since: float) -> None:
        """Killed with every attempt spent (terminal walltime kill)."""
        self._close_queued(task_id, attempt, since)
        self.instant("task.killed", ts=now, pid=0,
                     tid=self._tid(task_id),
                     args={"task": task_id, "attempt": attempt,
                           "since": float(since)})

    def task_quarantined(self, task_id: str, attempt: int, now: float,
                         since: float) -> None:
        """Poison task quarantined: it killed `quarantine_after` workers
        and is terminal instead of requeued (repro.chaos hardening).
        Same shape as `task_killed` — burned ``[since, now]`` billed to
        the allocation — under a distinct terminal name."""
        self._close_queued(task_id, attempt, since)
        self.instant("task.quarantined", ts=now, pid=0,
                     tid=self._tid(task_id),
                     args={"task": task_id, "attempt": attempt,
                           "since": float(since)})

    def task_hedge_cancel(self, task_id: str, attempt: int, now: float,
                          since: float) -> None:
        """The losing copy of a speculatively re-executed task was
        cancelled at the winner's completion.  The loser's pending queued
        entry is dropped WITHOUT emitting a span — the loser lineage is
        accounted as a single `speculation` overhead component
        (`obs.attribution`), not as queue/dispatch time — and the burned
        ``[since, now]`` interval (zero when the loser never dispatched)
        feeds billing conservation."""
        self._queued.pop((task_id, attempt), None)
        self.instant("task.hedge_cancel", ts=now, pid=0,
                     tid=self._tid(task_id),
                     args={"task": task_id, "attempt": attempt,
                           "since": float(since)})

    def task_speculate(self, task_id: str, attempt: int, now: float,
                       since: float) -> None:
        """A p95-straggler hedge copy was pushed at ``attempt``.
        ``since`` is the original attempt's dispatch mark (what made it a
        straggler)."""
        self.instant("task.speculate", ts=now, pid=0,
                     tid=self._tid(task_id),
                     args={"task": task_id, "attempt": attempt,
                           "since": float(since)})

    def task_failed(self, task_id: str, attempt: int,
                    ts: Optional[float] = None) -> None:
        """Terminal failure outside the walltime-kill path (exceptions)."""
        if ts is None:
            ts = self._clock()
        self.instant("task.failed", ts=ts, pid=0, tid=self._tid(task_id),
                     args={"task": task_id, "attempt": attempt})

    def task_lost(self, task_id: str, now: float) -> None:
        """The run ended with this task still queued (never served)."""
        tid = self._tid(task_id)
        for key in sorted(k for k in self._queued if k[0] == task_id):
            q_ts = self._queued.pop(key)
            self.span("task.queued", q_ts, now, pid=0, tid=tid,
                      args={"task": task_id, "attempt": key[1]})
        self.instant("task.lost", ts=now, pid=0, tid=tid,
                     args={"task": task_id})

    def _close_queued(self, task_id: str, attempt: int,
                      until: float) -> None:
        q_ts = self._queued.pop((task_id, attempt), None)
        if q_ts is not None:
            self.span("task.queued", q_ts, until, pid=0,
                      tid=self._tid(task_id),
                      args={"task": task_id, "attempt": attempt})

    # -- allocation protocol ---------------------------------------------
    def alloc_state(self, alloc, ts: Optional[float] = None) -> None:
        """Record an allocation's lifecycle state, emitting every
        transition since the last recorded one (so a tracer attached to
        a broker with live allocations backfills their history).  The
        timestamps come from the `Allocation`'s own fields — identical
        between sim and live by the parity contract — except DRAINING,
        which is a decision with no field (the caller passes ``ts``)."""
        state = alloc.state
        aid = alloc.alloc_id
        if self._alloc_state.get(aid) == state:
            return
        pid = aid + 1
        self._pid_labels.setdefault(
            pid, f"alloc{aid}" + (" (virtual)" if alloc.virtual else ""))
        # draining is a decision, not a fact with a timestamp field: it
        # only exists as a state if drain() was actually called (in which
        # case alloc_state ran then) — never synthesise it in passing on
        # a direct RUNNING -> EXPIRED kill
        t_of = {"queued": alloc.submit_t, "running": alloc.ready_t,
                "draining": ts if state == "draining" else None,
                "expired": alloc.end_t}
        prev_rank = _ALLOC_RANK.get(self._alloc_state.get(aid), -1)
        target_rank = _ALLOC_RANK.get(state, -1)
        for st in ("queued", "running", "draining", "expired"):
            rank = _ALLOC_RANK[st]
            if rank <= prev_rank or rank > target_rank:
                continue
            t = t_of.get(st)
            if t is None:
                if st != state:
                    continue               # state skipped (e.g. cancel)
                t = ts if ts is not None else self._clock()
            self._alloc_transition(aid, pid, st, float(t),
                                   virtual=alloc.virtual, alloc=alloc)
        self._alloc_state[aid] = state

    def _alloc_transition(self, aid: int, pid: int, state: str, t: float,
                          *, virtual: bool = False,
                          alloc: Any = None) -> None:
        open_name = self._alloc_open.pop(aid, None)
        if open_name is not None:
            self.emit("E", open_name, t, pid=pid, tid=0)
        if state == "expired":
            self.instant("alloc.expired", ts=t, pid=pid, tid=0,
                         args={"alloc": aid})
        else:
            args: dict = {"alloc": aid, "virtual": virtual}
            if state == "queued" and alloc is not None:
                # the request shape + the DRAWN queue wait: a cancelled
                # allocation's B/E span is shorter than its draw, so the
                # drawn value must be recorded, not recovered from ts —
                # this is what keeps replay's queue-wait FIFO aligned
                qw = getattr(alloc, "queue_wait", None)
                if qw is not None:
                    args["queue_wait"] = float(qw)
                nw = getattr(alloc, "n_workers", None)
                if nw is not None:
                    args["n_workers"] = int(nw)
                wt = getattr(alloc, "walltime_s", None)
                if wt is not None and math.isfinite(wt):
                    args["walltime_s"] = float(wt)
            self.emit("B", f"alloc.{state}", t, pid=pid, tid=0, args=args)
            self._alloc_open[aid] = f"alloc.{state}"

    # -- export ----------------------------------------------------------
    def events(self) -> List[TraceEvent]:
        return list(self.buf)

    @property
    def n_dropped(self) -> int:
        return self.buf.n_dropped

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (Perfetto-loadable): ts/dur in
        microseconds, pid = allocation (+1; 0 is the scheduler), tid =
        worker (or task index on the scheduler process).  Events are
        globally sorted by timestamp, so per-track timestamps are
        monotone — `validate_chrome_trace` checks exactly that."""
        out: List[Dict[str, Any]] = []
        for pid in sorted(self._pid_labels):
            out.append({"name": "process_name", "ph": "M", "pid": pid,
                        "tid": 0,
                        "args": {"name": self._pid_labels[pid]}})
        # stable sort by timestamp only: same-ts events keep emission
        # order, which is the correct B/E nesting order per track (a
        # phase-priority tiebreak would split zero-length B/E pairs)
        for ts, ph, name, pid, tid, dur, args in sorted(
                self.buf, key=lambda e: e[0]):
            ev: Dict[str, Any] = {"name": name, "ph": ph,
                                  "ts": ts * 1e6, "pid": pid, "tid": tid}
            if ph == "X":
                ev["dur"] = dur * 1e6
            if ph == "i":
                ev["s"] = "t"
            if args:
                ev["args"] = args
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"n_dropped": self.buf.n_dropped}}

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_chrome(), fh)

    def write_jsonl(self, path: str) -> None:
        """One JSON object per event, in emission order (seconds),
        written one line at a time (never materialises the event list)."""
        with open(path, "w") as fh:
            for ev in self.buf:
                fh.write(_jsonl_line(ev))

    # -- incremental streaming -------------------------------------------
    def stream_to(self, path: str) -> "Tracer":
        """Open an incremental JSONL sink: events already buffered are
        written now, and every subsequent `emit` appends one line — so a
        crash mid-run still leaves a usable trace, and a run longer than
        the ring buffer is recorded in full (the buffer may drop, the
        stream does not).  Call `close_stream` (or rely on interpreter
        exit) when done."""
        self.close_stream()
        self._sink = open(path, "w")
        for ev in self.buf:
            self._sink.write(_jsonl_line(ev))
        return self

    def close_stream(self) -> None:
        if self._sink is not None:
            try:
                self._sink.close()
            finally:
                self._sink = None


def _jsonl_line(ev: TraceEvent) -> str:
    """The one JSONL encoding shared by `write_jsonl` and `stream_to`."""
    ts, ph, name, pid, tid, dur, args = ev
    row: Dict[str, Any] = {"ts": ts, "ph": ph, "name": name, "pid": pid,
                           "tid": tid}
    if ph == "X":
        row["dur"] = dur
    if args:
        row["args"] = args
    return json.dumps(row) + "\n"


def _jsonable_matrix(params: Any) -> bool:
    """True for a plain [[float, ...], ...] payload that survives a JSON
    round trip exactly (np.float32 etc. are excluded — they are not JSON
    serialisable and their repr is not the double the driver computed
    with)."""
    if not isinstance(params, list) or not params:
        return False
    for row in params:
        if not isinstance(row, list):
            return False
        for v in row:
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                return False
    return True


_PHASES = ("B", "E", "X", "i")


def validate_jsonl_row(row: Any) -> Optional[str]:
    """Schema check for one decoded JSONL trace row; None means valid."""
    if not isinstance(row, dict):
        return f"not an object: {row!r}"
    ph = row.get("ph")
    if ph not in _PHASES:
        return f"unknown phase {ph!r}"
    if not isinstance(row.get("name"), str) or not row["name"]:
        return f"missing name: {row!r}"
    ts = row.get("ts")
    if not isinstance(ts, (int, float)) or isinstance(ts, bool) \
            or not math.isfinite(ts):
        return f"bad ts {ts!r}"
    for key in ("pid", "tid"):
        v = row.get(key, 0)
        if not isinstance(v, int) or isinstance(v, bool):
            return f"bad {key} {v!r}"
    if ph == "X":
        dur = row.get("dur", 0.0)
        if not isinstance(dur, (int, float)) or isinstance(dur, bool) \
                or not math.isfinite(dur) or dur < 0:
            return f"bad X dur {dur!r}"
    if "args" in row and not isinstance(row["args"], dict):
        return f"bad args {row['args']!r}"
    return None


def read_jsonl(path: str, *, strict: bool = True) -> List[TraceEvent]:
    """Load a `write_jsonl` / `stream_to` trace back into `TraceEvent`
    tuples (the inverse of the export, in file order).

    Every row is schema-validated (`validate_jsonl_row`); with
    ``strict=True`` (default) a malformed line raises `ValueError` naming
    the line, otherwise bad lines are skipped.  This is the entry point
    real-cluster logs take into `repro.obs.calib` / `repro.obs.replay`:
    anything that serialises to this schema calibrates the simulator."""
    out: List[TraceEvent] = []
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError as e:
                if strict:
                    raise ValueError(
                        f"{path}:{lineno}: not JSON ({e})") from e
                continue
            problem = validate_jsonl_row(row)
            if problem is not None:
                if strict:
                    raise ValueError(f"{path}:{lineno}: {problem}")
                continue
            out.append((float(row["ts"]), row["ph"], row["name"],
                        int(row.get("pid", 0)), int(row.get("tid", 0)),
                        float(row.get("dur", 0.0)), row.get("args")))
    return out


def span_sequence(tracer: Tracer) -> List[Tuple]:
    """Canonical comparable form of a trace: events sorted by
    (timestamp, phase, name, pid, tid, dur, frozen-args).  Two parity
    drivers emit the same events at the same virtual times but not
    always in the same buffer order (the live executor grants its
    initial allocation inside ``__init__``), so sequence comparison is
    on this sorted normal form."""
    out = []
    for ts, ph, name, pid, tid, dur, args in tracer.buf:
        frozen = tuple(sorted(args.items())) if args else ()
        out.append((ts, ph, name, pid, tid, dur, frozen))
    out.sort(key=lambda e: (e[:6], repr(e[6])))
    return out


def validate_chrome_trace(obj: Any) -> List[str]:
    """Validate a Chrome trace-event JSON object (the CI smoke gate).

    Checks: known phases only (B/E/X/i/M), finite numeric timestamps,
    non-negative X durations, per-(pid, tid) monotone non-decreasing
    timestamps in list order, and well-nested B/E pairs per track
    (an E must close the most recent open B of the same name; unclosed
    B at end-of-trace is allowed — a ring buffer may have dropped the
    tail).  Returns a list of problems; empty means valid."""
    problems: List[str] = []
    events = obj.get("traceEvents") if isinstance(obj, dict) else obj
    if not isinstance(events, list):
        return ["no traceEvents list"]
    last_ts: Dict[Tuple[int, int], float] = {}
    stacks: Dict[Tuple[int, int], List[str]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("B", "E", "X", "i", "M"):
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            problems.append(f"event {i}: missing name")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts):
            problems.append(f"event {i}: bad ts {ts!r}")
            continue
        track = (ev.get("pid", 0), ev.get("tid", 0))
        prev = last_ts.get(track)
        if prev is not None and ts < prev - 1e-6:
            problems.append(f"event {i}: ts {ts} < {prev} on track "
                            f"{track} (non-monotone)")
        last_ts[track] = max(ts, prev if prev is not None else ts)
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or not \
                    math.isfinite(dur) or dur < 0:
                problems.append(f"event {i}: bad X dur {dur!r}")
        elif ph == "B":
            stacks.setdefault(track, []).append(ev.get("name", ""))
        elif ph == "E":
            stack = stacks.get(track)
            if not stack:
                problems.append(f"event {i}: E without open B on track "
                                f"{track}")
            elif stack[-1] != ev.get("name", ""):
                problems.append(
                    f"event {i}: E {ev.get('name')!r} does not close "
                    f"open B {stack[-1]!r} on track {track}")
            else:
                stack.pop()
    return problems
