"""`repro.obs`: unified tracing, metrics registry, overhead attribution.

One observability layer for both execution paths: because the spans and
counters are instrumented at the shared `LifecycleStepper` / `Broker`
choke points and timestamped by the injected clock, a seeded parity run
produces identical span sequences from `simulate_cluster` and the live
`Executor` (see `tests/test_parity.py`).  Everything is opt-in:
``tracer=None`` / ``registry=None`` defaults keep the hot paths free of
even the tuple-append cost.
"""
from repro.obs.attribution import (OverheadBreakdown, attribute_overhead,
                                   capacity_intervals, format_breakdown)
from repro.obs.registry import DEFAULT_EDGES, Histogram, MetricsRegistry
from repro.obs.trace import (RingBuffer, TraceEvent, Tracer,
                             span_sequence, validate_chrome_trace)

__all__ = [
    "DEFAULT_EDGES",
    "Histogram",
    "MetricsRegistry",
    "OverheadBreakdown",
    "RingBuffer",
    "TraceEvent",
    "Tracer",
    "attribute_overhead",
    "capacity_intervals",
    "format_breakdown",
    "span_sequence",
    "validate_chrome_trace",
]
