"""`repro.obs`: tracing, metrics, attribution, calibration, replay.

One observability layer for both execution paths: because the spans and
counters are instrumented at the shared `LifecycleStepper` / `Broker`
choke points and timestamped by the injected clock, a seeded parity run
produces identical span sequences from `simulate_cluster` and the live
`Executor` (see `tests/test_parity.py`).  Everything is opt-in:
``tracer=None`` / ``registry=None`` defaults keep the hot paths free of
even the tuple-append cost.

On top of the recording layer sit the consumers that close the
sim-to-reality gap: `repro.obs.calib` fits per-phase overhead
distributions from a trace into a drop-in `CalibratedBackendSpec` and
watches for drift online (`CalibrationMonitor`), and `repro.obs.replay`
re-runs a recorded workload — bitwise-exactly for sim-recorded traces —
through `simulate_cluster` (`TraceReplay` / `replay_cluster`).
"""
from repro.obs.attribution import (OverheadBreakdown, attribute_overhead,
                                   capacity_intervals, format_breakdown)
from repro.obs.calib import (SACCT_DEFAULT_FIELDS, CalibratedBackendSpec,
                             CalibrationMonitor, PhaseFit, calibrate,
                             extract_phase_samples, fit_lognormal,
                             fit_phase, hlo_runtime_prior, ks_lognormal,
                             parse_slurm_duration, parse_slurm_time,
                             prior_fit, read_sacct, sacct_to_jsonl)
from repro.obs.registry import DEFAULT_EDGES, Histogram, MetricsRegistry
from repro.obs.replay import (ReplayBackendSpec, TraceReplay,
                              replay_cluster)
from repro.obs.trace import (RingBuffer, TraceEvent, Tracer, read_jsonl,
                             span_sequence, validate_chrome_trace,
                             validate_jsonl_row)

__all__ = [
    "DEFAULT_EDGES",
    "CalibratedBackendSpec",
    "CalibrationMonitor",
    "Histogram",
    "MetricsRegistry",
    "OverheadBreakdown",
    "PhaseFit",
    "ReplayBackendSpec",
    "RingBuffer",
    "TraceEvent",
    "TraceReplay",
    "Tracer",
    "attribute_overhead",
    "calibrate",
    "capacity_intervals",
    "extract_phase_samples",
    "fit_lognormal",
    "fit_phase",
    "format_breakdown",
    "hlo_runtime_prior",
    "ks_lognormal",
    "parse_slurm_duration",
    "parse_slurm_time",
    "prior_fit",
    "read_jsonl",
    "read_sacct",
    "replay_cluster",
    "sacct_to_jsonl",
    "SACCT_DEFAULT_FIELDS",
    "span_sequence",
    "validate_chrome_trace",
    "validate_jsonl_row",
]
