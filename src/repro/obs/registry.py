"""Named counters / gauges / histograms sampled each stepper tick.

The registry is the numeric side of `repro.obs`: where the tracer
records *events*, the registry records *state over time* — queue depth,
backlog cost, busy workers, open/pending allocations, offload rate, and
predictor absolute-residual calibration — one row per
`LifecycleStepper.step`, into a bounded sample buffer.  `timeseries()`
pivots the rows into parallel arrays benchmarks can dump next to their
`BENCH_*.json` (see `benchmarks/overhead_attribution.py`).

Contract for third-party policies / drivers:

  * `inc(name)` for monotone counters, `set_gauge(name, v)` for
    point-in-time values, `observe(name, v)` for distributions (fixed
    bucket edges; also maintains a running ``<name>_mean`` gauge);
  * every write accepts ``labels={"tenant": ...}`` — the series is then
    keyed ``name{k=v,...}`` (keys sorted, Prometheus-style).  Label
    cardinality is BOUNDED per base name (`max_label_sets`, default 64):
    writes that would mint a series beyond the cap are dropped and
    counted in ``labels_dropped``, so an adversarial stream of unique
    tenant names cannot grow the registry without limit;
  * `sample(now)` snapshots every counter and gauge with timestamp
    ``now`` — the stepper calls it once per tick when a registry is
    attached, so drivers never need to;
  * `timeseries()` returns ``{"t": [...], "<metric>": [...]}`` with one
    aligned entry per sample (NaN before a metric first appeared).

Everything is plain python (no numpy): `sample_cluster` runs under the
executor's dispatch lock.
"""
from __future__ import annotations

from bisect import bisect_right
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.trace import RingBuffer

# seconds-scale default bucket edges (residuals, waits); the last bucket
# is an effective overflow catch-all
DEFAULT_EDGES = (0.0, 0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0, 100.0,
                 1e9)


class Histogram:
    """Fixed-bucket histogram: O(log buckets) observe, no rebinning."""

    __slots__ = ("edges", "counts", "n", "total")

    def __init__(self, edges: Sequence[float] = DEFAULT_EDGES):
        self.edges = [float(e) for e in edges]
        if len(self.edges) < 2:
            raise ValueError("need at least two bucket edges")
        self.counts = [0] * (len(self.edges) - 1)
        self.n = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect_right(self.edges, v) - 1
        self.counts[min(max(i, 0), len(self.counts) - 1)] += 1
        self.n += 1
        self.total += v

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {"edges": list(self.edges), "counts": list(self.counts),
                "n": self.n, "mean": self.mean}


class MetricsRegistry:
    """Counters + gauges + histograms with a bounded sample history."""

    def __init__(self, max_samples: int = 4096,
                 max_label_sets: int = 64):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Histogram] = {}
        self.max_label_sets = max_label_sets
        self._label_sets: Dict[str, set] = {}  # base name -> series keys
        self._rows = RingBuffer(max_samples)

    def _series(self, name: str,
                labels: Optional[Dict[str, str]]) -> Optional[str]:
        """Resolve (name, labels) to a series key, or None when the
        write must be dropped: a base name may mint at most
        `max_label_sets` labelled series, and overflow increments the
        unlabelled ``labels_dropped`` counter instead of allocating —
        cardinality abuse costs the abuser a counter bump, not memory."""
        if not labels:
            return name
        key = "{}{{{}}}".format(
            name, ",".join(f"{k}={labels[k]}" for k in sorted(labels)))
        seen = self._label_sets.setdefault(name, set())
        if key not in seen:
            if len(seen) >= self.max_label_sets:
                self.counters["labels_dropped"] = \
                    self.counters.get("labels_dropped", 0.0) + 1.0
                return None
            seen.add(key)
        return key

    # -- writes ----------------------------------------------------------
    def inc(self, name: str, v: float = 1.0,
            labels: Optional[Dict[str, str]] = None) -> None:
        series = self._series(name, labels)
        if series is None:
            return
        self.counters[series] = self.counters.get(series, 0.0) + v

    def set_gauge(self, name: str, v: float,
                  labels: Optional[Dict[str, str]] = None) -> None:
        series = self._series(name, labels)
        if series is None:
            return
        self.gauges[series] = float(v)

    def observe(self, name: str, v: float,
                edges: Optional[Sequence[float]] = None,
                labels: Optional[Dict[str, str]] = None) -> None:
        series = self._series(name, labels)
        if series is None:
            return
        h = self.hists.get(series)
        if h is None:
            h = self.hists[series] = Histogram(edges or DEFAULT_EDGES)
        h.observe(v)
        self.gauges[series + "_mean"] = h.mean

    # -- sampling --------------------------------------------------------
    def sample(self, now: float) -> None:
        row: Dict[str, float] = {"t": float(now)}
        row.update(self.gauges)
        row.update(self.counters)
        self._rows.append(row)

    def sample_cluster(self, now: float, broker, busy_workers: int) -> None:
        """The per-tick cluster snapshot the `LifecycleStepper` records:
        everything the autoallocator and offload router see, as gauges."""
        g = self.set_gauge
        g("queue_depth", float(len(broker)))
        # pass the broker's CURRENT default so the probe cannot perturb
        # the backlog-cost ledger another caller configured
        cost = getattr(broker, "backlog_cost", None)
        if callable(cost):
            g("backlog_cost_s",
              cost(getattr(broker, "default_cost", 1.0)))
        g("busy_workers", float(busy_workers))
        allocs = getattr(broker, "allocations", lambda: [])()
        g("allocations_open", float(len(
            [a for a in allocs if a.open and not a.virtual])))
        g("allocations_pending", float(len(
            [a for a in allocs if a.state == "queued" and not a.virtual])))
        sur = getattr(broker, "surrogate", None)
        if sur is not None:
            considered = getattr(sur, "n_considered", 0)
            g("offload_rate",
              getattr(sur, "n_offloaded", 0) / considered
              if considered else 0.0)
        tb = getattr(broker, "tenant_backlogs", None)
        if callable(tb):
            # per-tenant depth gauges exist only when a tenant-aware
            # policy (fairshare) is queuing — single-tenant rows keep
            # their exact pre-multi-tenant schema
            for tenant, n in sorted(tb().items()):
                g("queue_depth", float(n), labels={"tenant": tenant})
        self.sample(now)

    # -- reads -----------------------------------------------------------
    @property
    def n_samples(self) -> int:
        return len(self._rows)

    def timeseries(self) -> Dict[str, List[float]]:
        rows = list(self._rows)
        keys = sorted({k for r in rows for k in r} - {"t"})
        out: Dict[str, List[float]] = {"t": [r["t"] for r in rows]}
        nan = float("nan")
        for k in keys:
            out[k] = [r.get(k, nan) for r in rows]
        return out

    def snapshot(self) -> Dict[str, Any]:
        """Current values of everything (one JSON-able dict)."""
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: h.as_dict()
                               for k, h in self.hists.items()},
                "n_samples": len(self._rows)}
