"""Pluggable scheduling policies for the UQ task queue.

One `SchedulingPolicy` object is the queue: the live `Executor`'s worker
threads and the discrete-event `simulate_policy` loop both push submitted
requests into it and pop the next request to run — the SAME objects drive
both, so a policy can be validated deterministically in simulation before
it schedules real work.

Policies see an optional `WorkerView` at pop time (who is asking: which
model servers it already has warm, how much of its allocation remains) and
an optional `RuntimePredictor` for per-task cost estimates.  Cost fallback
order: predictor estimate -> the request's `time_request` hint (HQ's
static per-job hint) -> 0.

Implementations:
  * `FCFSPolicy`      — arrival order (the repo's former hard-coded queue).
  * `SJFPolicy`       — shortest predicted job first (minimises mean wait;
                        what `pack_by_cost=True` used to approximate with
                        the static time request).
  * `LPTPolicy`       — longest predicted job first (classic 4/3-approx
                        list scheduling for makespan on parallel workers).
  * `PackingPolicy`   — LPT order + allocation awareness, generalising
                        HQ's time-request/time-limit split: a worker near
                        the end of its bulk allocation is handed the
                        longest task that still FITS its remaining budget,
                        so short tasks backfill the allocation tail.
  * `WorkStealingPolicy` — locality-aware per-worker queues: tasks follow
                        the worker holding a warm server for their model
                        (skipping the ~1 s re-init the paper measures);
                        idle workers steal from the most loaded peer.

Thread-safety: the executor serialises push/pop under its own lock, so
policies are plain data structures (and stay deterministic in simulation).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import TYPE_CHECKING, Deque, Dict, List, Optional, Tuple

from repro.sched.registry import register_policy

if TYPE_CHECKING:                              # hint-only: keeps repro.sched
    from repro.core.task import EvalRequest    # import-cycle-free

QueueItem = Tuple["EvalRequest", int]          # (request, attempt)


@dataclasses.dataclass(frozen=True)
class WorkerView:
    """What a policy may know about the worker asking for work."""
    wid: int = -1
    warm_models: frozenset = frozenset()       # models with a live server
    budget_left: Optional[float] = None        # seconds left in allocation
    alloc_id: Optional[int] = None             # owning allocation (cluster)


class SchedulingPolicy:
    """Queue interface shared by the live executor and the simulator."""

    name = "base"

    def __init__(self, predictor=None):
        self.predictor = predictor
        self._tick = itertools.count()         # deterministic FIFO tiebreak

    def bind(self, predictor) -> "SchedulingPolicy":
        """Attach a runtime predictor (no-op if one is already set)."""
        if predictor is not None and self.predictor is None:
            self.predictor = predictor
        return self

    def cost(self, req: EvalRequest) -> float:
        """Estimated compute seconds: predictor, else time_request, else 0."""
        if self.predictor is not None:
            c = self.predictor.predict(req)
            if c is not None:
                return float(c)
        if req.time_request:
            return float(req.time_request)
        return 0.0

    def _predictor_version(self) -> object:
        """Opaque token that changes when predictions may have changed —
        `version()` where available (the GP bumps it only on posterior
        updates, so O(queue) re-costing doesn't run on every pop),
        falling back to the observation count.  Shared by the cost-
        ordered heaps and the broker's backlog-cost cache."""
        v = getattr(self.predictor, "version", None)
        if callable(v):
            return v()
        n = getattr(self.predictor, "n_observed", None)
        return n() if callable(n) else 0

    # -- queue protocol -------------------------------------------------
    def push(self, req: EvalRequest, attempt: int) -> None:
        raise NotImplementedError

    def pop(self, worker: Optional[WorkerView] = None) -> Optional[QueueItem]:
        raise NotImplementedError

    def pending(self) -> List[QueueItem]:
        """Snapshot of queued items (checkpointing; no pops)."""
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def remove_worker(self, wid: int) -> None:
        """A worker left the pool (death, descale): policies holding
        per-worker state must reflow it so no queued task is stranded."""


@register_policy("fcfs")
class FCFSPolicy(SchedulingPolicy):
    """First-come-first-served — the baseline every dispatch path used."""

    name = "fcfs"

    def __init__(self, predictor=None):
        super().__init__(predictor)
        self._q: Deque[QueueItem] = deque()

    def push(self, req, attempt):
        self._q.append((req, attempt))

    def pop(self, worker=None):
        return self._q.popleft() if self._q else None

    def pending(self):
        return list(self._q)

    def __len__(self):
        return len(self._q)


class _CostOrderedPolicy(SchedulingPolicy):
    """Heap on (sign * cost, arrival tick): sign=+1 -> SJF, -1 -> LPT.

    Costs are evaluated at push time and lazily RE-evaluated whenever the
    predictor has absorbed new completions since the heap was last built —
    so a queue submitted up front (the UQ batch pattern) still benefits
    from runtime estimates learned online during the run.
    """

    sign = 1.0

    def __init__(self, predictor=None):
        super().__init__(predictor)
        self._heap: List[Tuple[float, int, QueueItem]] = []
        self._built_version: object = None

    def _maybe_rebuild(self):
        if self.predictor is None or not self._heap:
            return
        v = self._predictor_version()
        if v != self._built_version:
            self._heap = [(self.sign * self.cost(item[0]), tick, item)
                          for _, tick, item in self._heap]
            heapq.heapify(self._heap)
            self._built_version = v

    def push(self, req, attempt):
        heapq.heappush(self._heap,
                       (self.sign * self.cost(req), next(self._tick),
                        (req, attempt)))

    def pop(self, worker=None):
        self._maybe_rebuild()
        return heapq.heappop(self._heap)[2] if self._heap else None

    def pending(self):
        return [item for _, _, item in sorted(self._heap)]

    def __len__(self):
        return len(self._heap)


@register_policy("sjf")
class SJFPolicy(_CostOrderedPolicy):
    """Shortest predicted job first."""
    name = "sjf"
    sign = 1.0


@register_policy("lpt")
class LPTPolicy(_CostOrderedPolicy):
    """Longest predicted job first."""
    name = "lpt"
    sign = -1.0


@register_policy("pack")
class PackingPolicy(_CostOrderedPolicy):
    """Cost-aware allocation packing.

    LPT ordering, but a worker with finite `budget_left` gets the longest
    task that fits its remaining allocation (plus `init_margin` for server
    startup).  If nothing fits, the shortest task is handed out anyway —
    progress beats idling, and the time *limit* still bounds the overrun.
    This generalises HQ's split between the time request (packing hint)
    and the time limit (kill bound).
    """

    name = "pack"
    sign = -1.0

    def __init__(self, predictor=None, init_margin: float = 1.0):
        super().__init__(predictor)
        self.init_margin = init_margin

    def pop(self, worker=None):
        self._maybe_rebuild()
        if not self._heap:
            return None
        if worker is None or worker.budget_left is None:
            return heapq.heappop(self._heap)[2]
        budget = worker.budget_left - self.init_margin
        order = sorted(self._heap)             # cost desc (sign = -1)
        for entry in order:                    # longest task that fits
            if -entry[0] <= budget:
                self._heap.remove(entry)
                heapq.heapify(self._heap)
                return entry[2]
        entry = order[-1]                      # nothing fits: shortest
        self._heap.remove(entry)
        heapq.heapify(self._heap)
        return entry[2]


@register_policy("edf")
class EDFPolicy(SchedulingPolicy):
    """Earliest-deadline-first: SLO-aware ordering once requests carry a
    `deadline` (absolute seconds on the scheduler's clock).  Deadline-less
    requests sort after every deadlined one, FIFO among themselves —
    best-effort work never starves an SLO."""

    name = "edf"

    def __init__(self, predictor=None):
        super().__init__(predictor)
        self._heap: List[Tuple[float, int, QueueItem]] = []

    def push(self, req, attempt):
        key = req.deadline if getattr(req, "deadline", None) is not None \
            else float("inf")
        heapq.heappush(self._heap, (key, next(self._tick), (req, attempt)))

    def pop(self, worker=None):
        return heapq.heappop(self._heap)[2] if self._heap else None

    def pending(self):
        return [item for _, _, item in sorted(self._heap)]

    def __len__(self):
        return len(self._heap)


@register_policy("steal")
class WorkStealingPolicy(SchedulingPolicy):
    """Locality-aware work stealing.

    Each worker owns a local deque.  A request whose model already has an
    affinity (a worker that ran it before, hence holds a warm server under
    persistent-server semantics) is queued locally on that worker; others
    go to a shared global deque.  A worker pops its own queue first, then
    takes a global task (preferring one whose model it has warm), then
    steals from the back of the most loaded peer — the classic stealing
    end, so locality of the victim's imminent work is preserved.
    """

    name = "steal"

    def __init__(self, predictor=None):
        super().__init__(predictor)
        self._local: Dict[int, Deque[QueueItem]] = {}
        self._global: Deque[QueueItem] = deque()
        self._affinity: Dict[str, int] = {}    # model name -> worker id

    def push(self, req, attempt):
        wid = self._affinity.get(req.model_name)
        if wid is not None and wid in self._local:
            self._local[wid].append((req, attempt))
        else:
            self._global.append((req, attempt))

    def pop(self, worker=None):
        if worker is None:                     # anonymous consumer
            if self._global:
                return self._global.popleft()
            for q in self._local.values():
                if q:
                    return q.popleft()
            return None
        mine = self._local.setdefault(worker.wid, deque())
        if mine:
            return mine.popleft()
        if self._global:                       # prefer a warm-model task
            for i, (req, attempt) in enumerate(self._global):
                if req.model_name in worker.warm_models:
                    del self._global[i]
                    self._affinity[req.model_name] = worker.wid
                    return req, attempt
            req, attempt = self._global.popleft()
            self._affinity[req.model_name] = worker.wid
            return req, attempt
        victim = max((q for w, q in self._local.items() if w != worker.wid),
                     key=len, default=None)
        if victim:
            req, attempt = victim.pop()        # steal from the back
            self._affinity[req.model_name] = worker.wid
            return req, attempt
        return None

    def pending(self):
        out = list(self._global)
        for q in self._local.values():
            out.extend(q)
        return out

    def __len__(self):
        return len(self._global) + sum(len(q) for q in self._local.values())

    def remove_worker(self, wid):
        """Reflow a gone worker's local tasks to the FRONT of the global
        queue (they arrived earliest) and drop its affinities, so nothing
        starves waiting for a worker that will never pop again."""
        q = self._local.pop(wid, None)
        if q:
            self._global.extendleft(reversed(q))
        self._affinity = {m: w for m, w in self._affinity.items()
                          if w != wid}
