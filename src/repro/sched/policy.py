"""Pluggable scheduling policies for the UQ task queue.

One `SchedulingPolicy` object is the queue: the live `Executor`'s worker
threads and the discrete-event `simulate_policy` loop both push submitted
requests into it and pop the next request to run — the SAME objects drive
both, so a policy can be validated deterministically in simulation before
it schedules real work.

Policies see an optional `WorkerView` at pop time (who is asking: which
model servers it already has warm, how much of its allocation remains) and
an optional `RuntimePredictor` for per-task cost estimates.  Cost fallback
order: predictor estimate -> the request's `time_request` hint (HQ's
static per-job hint) -> 0.

Implementations:
  * `FCFSPolicy`      — arrival order (the repo's former hard-coded queue).
  * `SJFPolicy`       — shortest predicted job first (minimises mean wait;
                        what `pack_by_cost=True` used to approximate with
                        the static time request).
  * `LPTPolicy`       — longest predicted job first (classic 4/3-approx
                        list scheduling for makespan on parallel workers).
  * `PackingPolicy`   — LPT order + allocation awareness, generalising
                        HQ's time-request/time-limit split: a worker near
                        the end of its bulk allocation is handed the
                        longest task that still FITS its remaining budget,
                        so short tasks backfill the allocation tail.
  * `WorkStealingPolicy` — locality-aware per-worker queues: tasks follow
                        the worker holding a warm server for their model
                        (skipping the ~1 s re-init the paper measures);
                        idle workers steal from the most loaded peer.

Thread-safety: the executor serialises push/pop under its own lock, so
policies are plain data structures (and stay deterministic in simulation).
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
import math
from collections import deque
from typing import TYPE_CHECKING, Deque, Dict, List, Optional, Tuple

from repro.sched.costq import SortedCostQueue
from repro.sched.registry import make_policy, register_policy

if TYPE_CHECKING:                              # hint-only: keeps repro.sched
    from repro.core.task import EvalRequest    # import-cycle-free

QueueItem = Tuple["EvalRequest", int]          # (request, attempt)


@dataclasses.dataclass(frozen=True)
class WorkerView:
    """What a policy may know about the worker asking for work."""
    wid: int = -1
    warm_models: frozenset = frozenset()       # models with a live server
    budget_left: Optional[float] = None        # seconds left in allocation
    alloc_id: Optional[int] = None             # owning allocation (cluster)


class SchedulingPolicy:
    """Queue interface shared by the live executor and the simulator."""

    name = "base"

    def __init__(self, predictor=None):
        self.predictor = predictor
        self._tick = itertools.count()         # deterministic FIFO tiebreak

    def bind(self, predictor) -> "SchedulingPolicy":
        """Attach a runtime predictor (no-op if one is already set)."""
        if predictor is not None and self.predictor is None:
            self.predictor = predictor
        return self

    def cost(self, req: EvalRequest) -> float:
        """Estimated compute seconds: predictor, else time_request, else 0."""
        if self.predictor is not None:
            c = self.predictor.predict(req)
            if c is not None:
                return float(c)
        if req.time_request:
            return float(req.time_request)
        return 0.0

    def costs(self, reqs: List[EvalRequest]) -> List[float]:
        """Vectorized `cost` over a whole queue — the bulk re-costing
        path.  Predictors exposing `predict_many` (both shipped ones do)
        score the batch in one pass: the GP predictor routes it through
        `gp.predict_batch`, so re-costing a 100k-task queue is a handful
        of fixed-shape fused launches instead of 100k single predicts.
        Third-party policies should call this (never a per-item `cost`
        loop) whenever they re-score more than a few requests at once."""
        ests: Optional[List[Optional[float]]] = None
        if self.predictor is not None:
            many = getattr(self.predictor, "predict_many", None)
            if callable(many):
                ests = many(reqs)
            else:
                ests = [self.predictor.predict(r) for r in reqs]
        out: List[float] = []
        for i, req in enumerate(reqs):
            c = ests[i] if ests is not None else None
            if c is not None:
                out.append(float(c))
            elif req.time_request:
                out.append(float(req.time_request))
            else:
                out.append(0.0)
        return out

    def _predictor_version(self) -> object:
        """Opaque token that changes when predictions may have changed —
        `version()` where available (the GP bumps it only on posterior
        updates, so O(queue) re-costing doesn't run on every pop),
        falling back to the observation count.  Shared by the cost-
        ordered heaps and the broker's backlog-cost cache."""
        v = getattr(self.predictor, "version", None)
        if callable(v):
            return v()
        n = getattr(self.predictor, "n_observed", None)
        return n() if callable(n) else 0

    # -- queue protocol -------------------------------------------------
    def push(self, req: EvalRequest, attempt: int) -> None:
        raise NotImplementedError

    def pop(self, worker: Optional[WorkerView] = None) -> Optional[QueueItem]:
        raise NotImplementedError

    def pending(self) -> List[QueueItem]:
        """Snapshot of queued items (checkpointing; no pops)."""
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def remove_worker(self, wid: int) -> None:
        """A worker left the pool (death, descale): policies holding
        per-worker state must reflow it so no queued task is stranded."""


@register_policy("fcfs")
class FCFSPolicy(SchedulingPolicy):
    """First-come-first-served — the baseline every dispatch path used."""

    name = "fcfs"

    def __init__(self, predictor=None):
        super().__init__(predictor)
        self._q: Deque[QueueItem] = deque()

    def push(self, req, attempt):
        self._q.append((req, attempt))

    def pop(self, worker=None):
        return self._q.popleft() if self._q else None

    def pending(self):
        return list(self._q)

    def __len__(self):
        return len(self._q)


class _CostOrderedPolicy(SchedulingPolicy):
    """Sorted store on (sign * cost, arrival tick): sign=+1 -> SJF,
    -1 -> LPT.

    Costs are evaluated at push time and lazily RE-evaluated whenever the
    predictor has absorbed new completions since the store was last built —
    so a queue submitted up front (the UQ batch pattern) still benefits
    from runtime estimates learned online during the run.  The rebuild
    re-scores the WHOLE queue through `costs()` (one batched predictor
    pass), and the `SortedCostQueue` keeps every subsequent pop — ordered
    or budget-fit — O(log n) at any queue size.
    """

    sign = 1.0

    def __init__(self, predictor=None):
        super().__init__(predictor)
        self._q = SortedCostQueue()
        self._built_version: object = None

    def _maybe_rebuild(self):
        if self.predictor is None or not len(self._q):
            return
        v = self._predictor_version()
        if v != self._built_version:
            old = self._q.entries()
            reqs = [item[0] for _, _, item in old]
            new_costs = self.costs(reqs)
            self._q.rebuild([(self.sign * c, tick, item)
                             for c, (_, tick, item) in zip(new_costs, old)])
            self._built_version = v

    def push(self, req, attempt):
        self._q.insert(self.sign * self.cost(req), next(self._tick),
                       (req, attempt))

    def pop(self, worker=None):
        self._maybe_rebuild()
        entry = self._q.pop_first()
        return entry[2] if entry is not None else None

    def pending(self):
        return [item for _, _, item in self._q]

    def __len__(self):
        return len(self._q)


@register_policy("sjf")
class SJFPolicy(_CostOrderedPolicy):
    """Shortest predicted job first."""
    name = "sjf"
    sign = 1.0


@register_policy("lpt")
class LPTPolicy(_CostOrderedPolicy):
    """Longest predicted job first."""
    name = "lpt"
    sign = -1.0


@register_policy("pack")
class PackingPolicy(_CostOrderedPolicy):
    """Cost-aware allocation packing.

    LPT ordering, but a worker with finite `budget_left` gets the longest
    task that fits its remaining allocation (plus `init_margin` for server
    startup).  If nothing fits, the shortest task is handed out anyway —
    progress beats idling, and the time *limit* still bounds the overrun.
    This generalises HQ's split between the time request (packing hint)
    and the time limit (kill bound).

    `risk_lambda` opts into uncertainty-aware packing: when the predictor
    exposes `predict_many_with_sd`, every queue key (and so every
    budget-fit comparison) becomes mean + λ·posterior-sd, so a task whose
    runtime the surrogate is unsure about must fit the allocation tail
    with λ sigmas to spare — an uncertain 50 s estimate stops being
    packed as if it were a certain one, which is what turns predictor
    variance into fewer time-limit kills.  The default λ=0 keeps the
    mean-only reference path bit-for-bit (the risk branch is never
    entered), and predictors without sd support fall back to means.
    """

    name = "pack"
    sign = -1.0

    def __init__(self, predictor=None, init_margin: float = 1.0,
                 risk_lambda: float = 0.0):
        super().__init__(predictor)
        self.init_margin = init_margin
        self.risk_lambda = risk_lambda

    def _with_sd(self):
        """The predictor's batched (mean, sd) hook, when risk-adjusted
        costing is both enabled and available."""
        if not self.risk_lambda or self.predictor is None:
            return None
        many = getattr(self.predictor, "predict_many_with_sd", None)
        return many if callable(many) else None

    def cost(self, req: EvalRequest) -> float:
        many = self._with_sd()
        if many is None:
            return super().cost(req)
        mean, sd = many([req])[0]
        if mean is None:
            return float(req.time_request) if req.time_request else 0.0
        return float(mean) + self.risk_lambda * float(sd or 0.0)

    def costs(self, reqs: List[EvalRequest]) -> List[float]:
        many = self._with_sd()
        if many is None:
            return super().costs(reqs)
        out: List[float] = []
        for (mean, sd), req in zip(many(reqs), reqs):
            if mean is not None:
                out.append(float(mean) + self.risk_lambda * float(sd or 0.0))
            elif req.time_request:
                out.append(float(req.time_request))
            else:
                out.append(0.0)
        return out

    def pop(self, worker=None):
        self._maybe_rebuild()
        if not len(self._q):
            return None
        if worker is None or worker.budget_left is None:
            return self._q.pop_first()[2]
        budget = worker.budget_left - self.init_margin
        # keys are -cost: the first entry at key >= -budget is the
        # LONGEST task with cost <= budget (earliest arrival among ties)
        entry = self._q.pop_first_at_least(-budget)
        if entry is None:                      # nothing fits: shortest
            entry = self._q.pop_last()         # (latest arrival on ties —
        return entry[2]                        # the old sorted()[-1] rule)


@register_policy("edf")
class EDFPolicy(SchedulingPolicy):
    """Earliest-deadline-first: SLO-aware ordering once requests carry a
    `deadline` (absolute seconds on the scheduler's clock).  Deadline-less
    requests sort after every deadlined one, FIFO among themselves —
    best-effort work never starves an SLO."""

    name = "edf"

    def __init__(self, predictor=None):
        super().__init__(predictor)
        self._heap: List[Tuple[float, int, QueueItem]] = []

    def push(self, req, attempt):
        key = req.deadline if getattr(req, "deadline", None) is not None \
            else float("inf")
        heapq.heappush(self._heap, (key, next(self._tick), (req, attempt)))

    def pop(self, worker=None):
        return heapq.heappop(self._heap)[2] if self._heap else None

    def pending(self):
        return [item for _, _, item in sorted(self._heap)]

    def __len__(self):
        return len(self._heap)


@register_policy("steal")
class WorkStealingPolicy(SchedulingPolicy):
    """Locality-aware work stealing.

    Each worker owns a local deque.  A request whose model already has an
    affinity (a worker that ran it before, hence holds a warm server under
    persistent-server semantics) is queued locally on that worker; others
    go to a shared global deque.  A worker pops its own queue first, then
    takes a global task (preferring one whose model it has warm), then
    steals from the back of the most loaded peer — the classic stealing
    end, so locality of the victim's imminent work is preserved.

    The global queue is doubly indexed for million-task queues: the
    arrival deque gives FIFO pops, and a per-model index of the same
    entry objects answers "earliest pending task of a warm model" by
    peeking O(warm models) deque heads — the old implementation scanned
    the whole deque per pop and paid an O(n) `del` on a match.  An entry
    taken through one view is tombstoned (`alive=False`) and dropped
    lazily when the other view reaches it.  Worker iteration (anonymous
    drains, steal-victim ties) is by ascending wid, never dict insertion
    order, so sim/live parity cannot depend on which worker popped first
    in history.
    """

    name = "steal"

    # a global-queue entry, shared by the FIFO deque and the model index
    # ([seq, req, attempt, alive] — a list so `alive` is mutable in place)
    _SEQ, _REQ, _ATTEMPT, _ALIVE = range(4)

    def __init__(self, predictor=None):
        super().__init__(predictor)
        self._local: Dict[int, Deque[QueueItem]] = {}
        self._global: Deque[list] = deque()    # FIFO view (seq ascending)
        self._by_model: Dict[str, Deque[list]] = {}    # per-model view
        self._n_global = 0                     # live entries in _global
        self._n_dead = 0                       # tombstones not yet dropped
        self._seq_back = itertools.count()     # arrival order keys
        self._seq_front = -1                   # reflowed-to-front keys
        self._affinity: Dict[str, int] = {}    # model name -> worker id

    def _push_global(self, req, attempt, *, front: bool = False) -> None:
        if front:
            seq, self._seq_front = self._seq_front, self._seq_front - 1
        else:
            seq = next(self._seq_back)
        entry = [seq, req, attempt, True]
        index = self._by_model.setdefault(req.model_name, deque())
        if front:
            self._global.appendleft(entry)
            index.appendleft(entry)
        else:
            self._global.append(entry)
            index.append(entry)
        self._n_global += 1

    def _take(self, entry) -> QueueItem:
        """Claim a live global entry: tombstone it for the view that did
        not hand it out (lazily skipped there later).  The payload is
        cleared immediately — a tombstone must never keep a served
        request's parameters alive — and once tombstones outnumber live
        entries both views are compacted, so memory tracks the LIVE
        queue, not every task ever pushed."""
        item = (entry[self._REQ], entry[self._ATTEMPT])
        entry[self._ALIVE] = False
        entry[self._REQ] = entry[self._ATTEMPT] = None
        self._n_global -= 1
        self._n_dead += 1
        if self._n_dead > 64 and self._n_dead > self._n_global:
            self._compact_global()
        return item

    def _compact_global(self) -> None:
        """Drop every tombstone from both global views (amortised O(1)
        per pop: runs only when dead entries dominate)."""
        self._global = deque(e for e in self._global if e[self._ALIVE])
        for model in list(self._by_model):
            q = deque(e for e in self._by_model[model] if e[self._ALIVE])
            if q:
                self._by_model[model] = q
            else:
                del self._by_model[model]
        self._n_dead = 0

    def _pop_global_fifo(self) -> Optional[QueueItem]:
        while self._global:
            entry = self._global.popleft()
            if entry[self._ALIVE]:
                return self._take(entry)
        return None

    def _pop_global_warm(self, worker: WorkerView) -> Optional[QueueItem]:
        """Earliest pending global task of any model the worker has warm
        — O(|warm_models|) head peeks on the per-model index."""
        best = None
        best_q = None
        for model in worker.warm_models:
            q = self._by_model.get(model)
            if not q:
                continue
            while q and not q[0][self._ALIVE]:     # lazy tombstone drop
                q.popleft()
            if q and (best is None or q[0][self._SEQ] < best[self._SEQ]):
                best, best_q = q[0], q
        if best is None:
            return None
        best_q.popleft()
        return self._take(best)

    def push(self, req, attempt):
        wid = self._affinity.get(req.model_name)
        if wid is not None and wid in self._local:
            self._local[wid].append((req, attempt))
        else:
            self._push_global(req, attempt)

    def pop(self, worker=None):
        if worker is None:                     # anonymous consumer
            item = self._pop_global_fifo()
            if item is not None:
                return item
            for wid in sorted(self._local):    # wid order, not dict order
                if self._local[wid]:
                    return self._local[wid].popleft()
            return None
        mine = self._local.setdefault(worker.wid, deque())
        if mine:
            return mine.popleft()
        if self._n_global:                     # prefer a warm-model task
            item = self._pop_global_warm(worker)
            if item is None:
                item = self._pop_global_fifo()
            self._affinity[item[0].model_name] = worker.wid
            return item
        victim = None
        for wid in sorted(self._local):        # largest backlog, lowest
            q = self._local[wid]               # wid among ties
            if wid != worker.wid and q and \
                    (victim is None or len(q) > len(victim)):
                victim = q
        if victim:
            req, attempt = victim.pop()        # steal from the back
            self._affinity[req.model_name] = worker.wid
            return req, attempt
        return None

    def pending(self):
        out = [(e[self._REQ], e[self._ATTEMPT]) for e in self._global
               if e[self._ALIVE]]
        for wid in sorted(self._local):
            out.extend(self._local[wid])
        return out

    def __len__(self):
        return self._n_global + sum(len(q) for q in self._local.values())

    def remove_worker(self, wid):
        """Reflow a gone worker's local tasks to the FRONT of the global
        queue (they arrived earliest) and drop its affinities, so nothing
        starves waiting for a worker that will never pop again."""
        q = self._local.pop(wid, None)
        if q:
            for req, attempt in reversed(q):   # appendleft keeps q's order
                self._push_global(req, attempt, front=True)
        self._affinity = {m: w for m, w in self._affinity.items()
                          if w != wid}


@register_policy("fairshare")
class FairSharePolicy(SchedulingPolicy):
    """Weighted fair sharing across tenants (deficit round robin).

    Composes one inner `SchedulingPolicy` per tenant — any registered
    name or zero-arg factory, sharing this policy's predictor — and
    serves pops by weighted deficit round robin over estimated
    cost-seconds: whenever no backlogged tenant holds credit, every
    backlogged tenant is credited ``quantum_s * weight`` per round
    (rounds batched in closed form, so one huge task can't make the
    replenish loop O(cost/quantum)); a pop serves the first
    credit-holding tenant after the last-served one in sorted tenant
    order, and charges the task's PUSH-TIME cost estimate against its
    deficit.  Consequences, both pinned by tests:

      * over any saturated stretch each tenant's served cost-seconds
        converge to its weight share (weighted max-min fairness);
      * a backlogged tenant is served at least ``quantum_s * weight``
        cost-seconds per round — bounded-delay, so bursty competitors
        can't starve anyone.

    Costs are cached at push (keyed ``(tenant, task_id, attempt)`` with
    duplicate counting for speculative re-pushes) so the pop hot path
    never touches the predictor — the same discipline the Broker's
    backlog ledger established.  Unknown/zero estimates charge
    ``default_cost`` so free-looking tasks still consume bandwidth.
    Classic DRR rule: a tenant whose queue empties forfeits banked
    credit (no saving up while idle).

    If every credit-holding tenant declines the asking worker (e.g. a
    budget-fit inner ``pack`` pop finds nothing that fits), the scan
    repeats ignoring credit — progress beats idling, and the charge
    still lands on the served tenant.

    Determinism: tenant ring order is sorted, the cursor is part of the
    state, and charges derive from push-time caches — identical
    push/pop sequences (the parity harness's guarantee) produce
    identical pop orders in sim and live.

    ``quotas`` (max queued tasks per tenant) are carried for admission
    layers: the policy itself never rejects work (queue contract), but
    `quota_headroom` is what `repro.service.ServiceBroker` turns into
    per-tenant backpressure.
    """

    name = "fairshare"

    def __init__(self, predictor=None, policy="fcfs",
                 weights: Optional[Dict[str, float]] = None,
                 quotas: Optional[Dict[str, int]] = None,
                 quantum_s: float = 1.0, default_cost: float = 1.0):
        super().__init__(predictor)
        if isinstance(policy, SchedulingPolicy):
            raise TypeError(
                "FairSharePolicy builds one inner queue PER tenant: pass "
                "a registered policy name or a zero-arg factory, not an "
                "instance")
        if policy == "fairshare":
            raise TypeError("fairshare inside fairshare is not supported")
        self._sub_spec = policy
        self.weights = {str(t): float(w)
                        for t, w in (weights or {}).items()}
        for t, w in self.weights.items():
            if w <= 0:
                raise ValueError(f"tenant weight must be > 0: {t}={w}")
        self.quotas = {str(t): int(q) for t, q in (quotas or {}).items()}
        self.quantum_s = float(quantum_s)
        self.default_cost = float(default_cost)
        self._tenants: Dict[str, SchedulingPolicy] = {}
        self._ring: List[str] = []             # sorted tenant names
        self._cursor: Optional[str] = None     # last-served tenant
        self._deficit: Dict[str, float] = {}
        self._served: Dict[str, float] = {}    # cumulative charged cost
        self._backlog: Dict[str, float] = {}   # queued cost (push-time est)
        # (tenant, task_id, attempt) -> (cost, multiplicity)
        self._push_cost: Dict[Tuple[str, str, int], Tuple[float, int]] = {}

    @staticmethod
    def tenant_of(req) -> str:
        return getattr(req, "tenant", "") or "default"

    def bind(self, predictor):
        super().bind(predictor)
        for q in self._tenants.values():
            q.bind(self.predictor)
        return self

    def _weight(self, tenant: str) -> float:
        return self.weights.get(tenant, 1.0)

    def _inner(self, tenant: str) -> SchedulingPolicy:
        q = self._tenants.get(tenant)
        if q is None:
            if callable(self._sub_spec) and \
                    not isinstance(self._sub_spec, str):
                q = self._sub_spec()
                q.bind(self.predictor)
            else:
                q = make_policy(self._sub_spec, self.predictor)
            self._tenants[tenant] = q
            bisect.insort(self._ring, tenant)
            self._deficit.setdefault(tenant, 0.0)
            self._served.setdefault(tenant, 0.0)
            self._backlog.setdefault(tenant, 0.0)
        return q

    # -- queue protocol -------------------------------------------------
    def push(self, req, attempt):
        tenant = self.tenant_of(req)
        inner = self._inner(tenant)
        key = (tenant, req.task_id, attempt)
        entry = self._push_cost.get(key)
        if entry is not None:                  # speculative duplicate:
            cost, n = entry                    # same charge both times
            self._push_cost[key] = (cost, n + 1)
        else:
            cost = self.cost(req)
            if cost <= 0.0:
                cost = self.default_cost
            self._push_cost[key] = (cost, 1)
        self._backlog[tenant] += cost
        inner.push(req, attempt)

    def _charge_of(self, tenant: str, req, attempt: int) -> float:
        key = (tenant, req.task_id, attempt)
        entry = self._push_cost.get(key)
        if entry is None:                      # never pushed here (migrated
            return self.default_cost           # in?): nominal charge
        cost, n = entry
        if n <= 1:
            del self._push_cost[key]
        else:
            self._push_cost[key] = (cost, n - 1)
        return cost

    def _replenish(self, active: List[str]) -> None:
        """Credit every backlogged tenant until at least one is positive
        — the number of quantum rounds computed in closed form, so a
        single task far larger than the quantum costs O(active), not
        O(cost / quantum)."""
        if any(self._deficit[t] > 0.0 for t in active):
            return
        rounds = min(
            math.floor(-self._deficit[t] / (self.quantum_s *
                                            self._weight(t))) + 1
            for t in active)
        for t in active:
            self._deficit[t] += rounds * self.quantum_s * self._weight(t)

    def _scan(self, active: List[str], worker,
              need_credit: bool) -> Optional[QueueItem]:
        if self._cursor is not None:           # resume after last served
            i = bisect.bisect_right(active, self._cursor)
            order = active[i:] + active[:i]
        else:
            order = active
        for tenant in order:
            if need_credit and self._deficit[tenant] <= 0.0:
                continue
            item = self._tenants[tenant].pop(worker)
            if item is None:
                continue
            req, attempt = item
            cost = self._charge_of(tenant, req, attempt)
            self._deficit[tenant] -= cost
            self._served[tenant] += cost
            self._backlog[tenant] = max(self._backlog[tenant] - cost, 0.0)
            self._cursor = tenant
            if not len(self._tenants[tenant]):
                self._deficit[tenant] = 0.0    # DRR: emptied -> no banking
            return item
        return None

    def pop(self, worker=None):
        active = [t for t in self._ring if len(self._tenants[t])]
        if not active:
            return None
        self._replenish(active)
        item = self._scan(active, worker, need_credit=True)
        if item is None:                       # every credit holder declined
            item = self._scan(active, worker, need_credit=False)
        return item

    def pending(self):
        out: List[QueueItem] = []
        for tenant in self._ring:
            out.extend(self._tenants[tenant].pending())
        return out

    def __len__(self):
        return sum(len(q) for q in self._tenants.values())

    def remove_worker(self, wid):
        for tenant in self._ring:
            self._tenants[tenant].remove_worker(wid)

    # -- tenant introspection (SLO accounting / admission) --------------
    def tenant_pending_all(self) -> Dict[str, int]:
        """Queued tasks per tenant (only tenants with backlog)."""
        return {t: len(q) for t, q in self._tenants.items() if len(q)}

    def tenant_backlog_cost(self) -> Dict[str, float]:
        """Queued cost-seconds per tenant, at push-time estimates (an
        SLO-accounting probe; the Broker's version-cached ledger remains
        the autoalloc signal)."""
        return {t: c for t, c in self._backlog.items()
                if len(self._tenants[t])}

    def served_cost(self) -> Dict[str, float]:
        """Cumulative charged cost-seconds per tenant — the quantity the
        fairness tests measure shares on."""
        return dict(self._served)

    def quota_headroom(self, tenant: str) -> Optional[int]:
        """How many more tasks `tenant` may queue under its quota (None
        = unlimited).  Advisory: enforced by admission layers, not by
        `push`."""
        quota = self.quotas.get(tenant)
        if quota is None:
            return None
        queued = len(self._tenants[tenant]) if tenant in self._tenants \
            else 0
        return max(quota - queued, 0)
