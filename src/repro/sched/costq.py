"""A blocked sorted store for cost-ordered scheduling queues.

The paper's premise is queues of "thousands or even millions of similar
tasks"; the cost-ordered policies (`sjf`/`lpt`/`pack`) previously kept a
binary heap, which is O(log n) for pop-min but gave `PackingPolicy` no
way to answer its budget-fit query ("the longest task that still fits
this worker's remaining allocation") without sorting the whole heap on
EVERY pop — O(n log n) per decision, O(n^2 log n) to drain a queue.

`SortedCostQueue` keeps entries `(key, tick, item)` fully sorted at all
times in bisect-indexed blocks (the sortedcontainers layout, implemented
here because the container ships no such dependency): a flat list of
bounded sorted blocks plus a parallel list of per-block maxima.  Every
operation bisects the maxima to find the owning block, then bisects
inside it — O(log n) comparisons with memmoves bounded by the block size,
so a 1M-entry queue pays the same per-decision overhead as a 1k-entry
one:

  * ``insert``            — push one entry;
  * ``pop_first``         — global minimum (the heap-pop equivalent);
  * ``pop_last``          — global maximum (pack's nothing-fits fallback:
                            under sign=-1 keys that is the SHORTEST task,
                            latest arrival among ties — exactly what the
                            old ``sorted(heap)[-1]`` returned);
  * ``pop_first_at_least``— first entry in sort order with key >= bound
                            (pack's budget fit: keys are -cost, so the
                            bound -budget selects the LONGEST task that
                            fits, earliest arrival among ties);
  * ``rebuild``           — replace all keys at once (the predictor
                            learned something): one O(n log n) sort into
                            freshly balanced blocks, amortised across the
                            whole queue instead of paid per pop.

Entries are ordered by ``(key, tick)``; ticks come from the policies'
arrival counter and are unique, so the payload item is never compared.
Deletion is eager (a bounded ``del block[i]``, cheaper at realistic block
sizes than tombstone bookkeeping) and empty blocks are dropped so the
maxima index never goes stale.
"""
from __future__ import annotations

from bisect import bisect_left, insort
from typing import Any, Iterable, List, Optional, Tuple

Entry = Tuple[float, int, Any]                 # (key, tick, item)

# Blocks split at 2*LOAD and are rebuilt at LOAD: keeps every memmove
# bounded while the maxima index stays tiny (n / LOAD entries).
LOAD = 1024


class SortedCostQueue:
    """Sorted multiset of ``(key, tick, item)`` with O(log n) ends and
    bounded-key queries (see module docstring for the operation set)."""

    __slots__ = ("_blocks", "_maxes", "_len")

    def __init__(self, entries: Optional[Iterable[Entry]] = None):
        self._blocks: List[List[Entry]] = []
        self._maxes: List[Entry] = []          # last entry of each block
        self._len = 0
        if entries is not None:
            self.rebuild(list(entries))

    # -- bulk -----------------------------------------------------------
    def rebuild(self, entries: List[Entry]) -> None:
        """Replace the contents with `entries` (keys may have changed):
        one sort, then slice into balanced blocks."""
        entries = sorted(entries, key=lambda e: (e[0], e[1]))
        self._blocks = [entries[i:i + LOAD]
                        for i in range(0, len(entries), LOAD)]
        self._maxes = [b[-1] for b in self._blocks]
        self._len = len(entries)

    def clear(self) -> None:
        self._blocks, self._maxes, self._len = [], [], 0

    # -- inserts --------------------------------------------------------
    def insert(self, key: float, tick: int, item: Any) -> None:
        entry = (key, tick, item)
        if not self._blocks:
            self._blocks.append([entry])
            self._maxes.append(entry)
            self._len = 1
            return
        # owning block: the first whose max sorts >= entry (the last
        # block takes everything beyond the current maximum)
        b = min(bisect_left(self._maxes, entry), len(self._blocks) - 1)
        block = self._blocks[b]
        insort(block, entry)
        self._maxes[b] = block[-1]
        self._len += 1
        if len(block) > 2 * LOAD:              # split, keep both bounded
            half = len(block) // 2
            self._blocks[b:b + 1] = [block[:half], block[half:]]
            self._maxes[b:b + 1] = [self._blocks[b][-1],
                                    self._blocks[b + 1][-1]]

    # -- removals -------------------------------------------------------
    def _delete(self, b: int, i: int) -> Entry:
        block = self._blocks[b]
        entry = block[i]
        del block[i]
        if block:
            self._maxes[b] = block[-1]
        else:
            del self._blocks[b]
            del self._maxes[b]
        self._len -= 1
        return entry

    def pop_first(self) -> Optional[Entry]:
        if not self._len:
            return None
        return self._delete(0, 0)

    def pop_last(self) -> Optional[Entry]:
        if not self._len:
            return None
        return self._delete(len(self._blocks) - 1, -1)

    def pop_first_at_least(self, key_bound: float) -> Optional[Entry]:
        """Remove and return the first entry (in sort order) whose key is
        >= `key_bound`; None if every key is below the bound."""
        if not self._len:
            return None
        probe = (key_bound,)                   # sorts before any real
        b = bisect_left(self._maxes, probe)    # (key_bound, tick) entry
        if b == len(self._blocks):
            return None
        # this block's max is >= probe, so the in-block bisect always
        # lands on a valid entry
        return self._delete(b, bisect_left(self._blocks[b], probe))

    # -- views ----------------------------------------------------------
    def __len__(self) -> int:
        return self._len

    def __iter__(self):
        for block in self._blocks:
            yield from block

    def entries(self) -> List[Entry]:
        """All entries in sort order (pending-snapshot support)."""
        out: List[Entry] = []
        for block in self._blocks:
            out.extend(block)
        return out
