"""Surrogate-offload routing: variance-gated dispatch to a GP surrogate.

The paper's headline saving for long-running simulations comes from NOT
running them: when a trained GP surrogate is trustworthy at a task's
input theta, the scheduler can serve the task from the surrogate
(milliseconds) instead of the forward model (minutes to hours).  PR 1/2
built the dispatch layers that *predict* runtimes; this module makes
them *act* on the surrogate option:

  * `SurrogateOffload` — the decision engine + surrogate server.  A task
    is offloaded when BOTH gates pass:
      1. cost gate: the predicted runtime (online predictor, else the
         HQ-style `time_request` hint) exceeds `runtime_budget_s` —
         short tasks are cheaper to just run;
      2. trust gate: the STANDARDISED (latent) GP posterior sd at theta
         is at most `sd_threshold`.  The outputs share one kernel, so
         the latent sd is common to all columns; being dimensionless,
         one threshold spans growth rate and mode frequency despite
         their ~100x scale split.  (Per-output variance in original
         units — the PR's bugfix — is what `gp.predict` reports and
         what original-scale consumers like `uq.adaptive` gate on.)
    Trust scoring runs through `gp.predict_batch` — the bucket-padded
    batched predict (Pallas kernel on TPU) — so routing a large queue
    costs a few fixed-shape launches, not one fresh XLA compile per
    queue length.  Completed REAL runs are fed back via `observe`, which
    conditions the posterior so nearby thetas become offloadable.
  * `SurrogateOffloadPolicy` — a `SchedulingPolicy` (registered as
    ``policy="offload"``) wrapping any inner policy: offloaded tasks go
    to a fast FIFO served before the inner queue (they cost
    milliseconds; draining them first frees dependents sooner), the
    rest to the wrapped policy.  The cluster-level counterpart lives in
    `repro.cluster.Broker` (``surrogate=``), which models the surrogate
    as a zero-queue-wait virtual allocation.

The offload decision is re-made on every push (requeues and migrations
re-decide with fresher predictor/posterior state); the chosen path is
recorded in ``req.config["_surrogate"]`` so the executor and the
discrete-event simulator serve the same routing.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import TYPE_CHECKING, Any, Deque, List, Optional, Sequence

import numpy as np

from repro.sched.policy import QueueItem, SchedulingPolicy, WorkerView
from repro.sched.predictor import flatten_parameters, request_features
from repro.sched.registry import make_policy, register_policy

if TYPE_CHECKING:                              # hint-only: keeps repro.sched
    from repro.core.task import EvalRequest    # import-cycle-free

SURROGATE_KEY = "_surrogate"                   # config flag: serve via GP
NO_SURROGATE_KEY = "_no_surrogate"             # config flag: pin to real path


class SurrogateOffload:
    """Decision engine + surrogate evaluator shared by every dispatch
    layer (single-node policy, cluster broker, live executor, simulator).

    `posterior` is a trained `repro.uq.gp.GPPosterior` over the task
    input theta (or an already-configured `repro.uq.engine` backend);
    None (or fewer than `min_train` training points) keeps every task on
    the real path — an unarmed engine is a no-op router.  `backend`
    selects the surrogate engine a bare posterior is lifted into:
    "exact" (default, full refit per conditioning — the reference),
    "incremental" (O(n²) block Cholesky updates on the completion
    stream) or "partitioned" (cap-bounded local-GP ensemble).

    Thread-safety: decisions run under the executor's dispatch lock,
    `evaluate`/`observe` from worker threads; the internal lock guards
    the engine swap and the counters.  A push-time trust check costs
    one bucketed (pre-compiled) predict launch; the compile itself is
    warmed at construction and after each conditioning, OFF the dispatch
    lock, so the pool never stalls on XLA.
    """

    def __init__(self, posterior=None, *, model_name: Optional[str] = None,
                 runtime_budget_s: float = 60.0,
                 sd_threshold: float = 0.1, min_train: int = 8,
                 latency_s: float = 0.05, n_virtual_workers: int = 1,
                 condition_every: int = 8, max_points: int = 256,
                 sd_window: int = 4096, backend: str = "exact",
                 drift_disable_s: float = 300.0,
                 **backend_kw):
        from repro.uq import engine as uq_engine
        self.backend = backend
        # backend-specific knobs (e.g. partitioned's expert_cap,
        # incremental's refactor_every) ride through to the engine —
        # both here and on every posterior re-arm
        self._backend_kw = backend_kw
        self._engine = uq_engine.as_engine(posterior, backend,
                                           max_points=max_points,
                                           **backend_kw)
        # which model this surrogate stands in for; None means "any" —
        # only safe when every model shares the posterior's theta space.
        # With several models whose payloads happen to flatten to the
        # same dimension, an unscoped engine would serve model B from a
        # surrogate of model A (and condition it on B's values), so
        # multi-model executors should always scope the engine.
        self.model_name = model_name
        self.runtime_budget_s = runtime_budget_s
        self.sd_threshold = sd_threshold
        self.min_train = min_train
        # what one surrogate evaluation costs (the simulator's virtual
        # runtime; the live path measures the real predict instead)
        self.latency_s = latency_s
        self.n_virtual_workers = n_virtual_workers
        self.condition_every = condition_every
        # optional repro.obs.Tracer: decide() emits an `offload.decide`
        # instant per decision (set by Broker.set_tracer / the executor)
        self.tracer = None
        # degraded state: while set, every decision is "real path" —
        # armed by a surrogate outage fault or a calib.drift alarm
        # (`note_drift_alarm`), re-armed by `tick_degraded` once the
        # cool-down passes (the stepper ticks it each step)
        self.degraded_until: Optional[float] = None
        self.degraded_reason: Optional[str] = None
        self.drift_disable_s = float(drift_disable_s)
        # recency cap on the conditioned training set (mirrors
        # GPRuntimePredictor.max_points): without it every batch of
        # completions grows N forever — O(N^3) Cholesky rebuilds and a
        # fresh predict compile per size, on the _complete path
        self.max_points = max_points
        self._lock = threading.Lock()
        self.n_considered = 0
        self.n_offloaded = 0
        self.n_evals = 0
        self.cpu_seconds_avoided = 0.0
        # most recent trust-check sds only: bounded memory, and stats()
        # (called under the engine lock) stays O(window), not O(run)
        self._sds: Deque[float] = deque(maxlen=sd_window)
        self._pend_x: List[List[float]] = []   # buffered conditioning batch
        self._pend_y: List[List[float]] = []
        # trust checks run at push time under the executor's dispatch
        # lock; pre-compiling the single-theta bucket shape here keeps
        # the first decide() from stalling the whole pool on an XLA
        # compile (each conditioning re-warms its new training size)
        self._warm(self._engine)

    @property
    def posterior(self):
        """The underlying `GPPosterior` (exact/incremental engines), the
        engine itself (partitioned — there is no single factor), or None
        when unarmed.  Assignment re-arms the router: a bare posterior is
        lifted into this engine's configured backend."""
        eng = self._engine
        return getattr(eng, "post", eng)

    @posterior.setter
    def posterior(self, post) -> None:
        from repro.uq import engine as uq_engine
        self._engine = uq_engine.as_engine(post, self.backend,
                                           max_points=self.max_points,
                                           **self._backend_kw)

    @staticmethod
    def _warm(eng) -> None:
        if eng is not None:
            eng.warm()

    # -- trust scoring ---------------------------------------------------
    def trust_sd(self, thetas: Sequence[Sequence[float]]) -> np.ndarray:
        """Standardised (latent) posterior sd at each theta — one
        bucket-padded `predict_batch` pass through the engine for the
        whole batch.

        The outputs share one kernel, so the latent sd is the same for
        every column; dividing any column's original-scale sd by its own
        y_std recovers it.  Being dimensionless, one `sd_threshold`
        spans outputs of any physical scale (growth rate vs frequency)."""
        return self._engine.latent_sd(thetas)

    # -- routing decision ------------------------------------------------
    def decide(self, req: "EvalRequest", cost: Optional[float]) -> bool:
        """True -> serve `req` from the surrogate.  Also stamps/clears
        ``req.config["_surrogate"]`` so runners see the same routing.
        ``req.config["_no_surrogate"]`` pins a task to the real path
        (set after a surrogate failure, and by straggler speculation —
        a speculated copy must duplicate the SAME work)."""
        offload = self._decide(req, cost)
        if not offload:
            # a "no" for a task credited on an earlier attempt (requeue
            # after a crash, trust since lost) refunds that credit: the
            # task will burn real CPU after all
            self.rollback(req)
        if self.tracer is not None:
            self.tracer.instant("offload.decide",
                                args={"task": req.task_id,
                                      "offload": bool(offload)})
        return offload

    def _decide(self, req: "EvalRequest", cost: Optional[float]) -> bool:
        req.config.pop(SURROGATE_KEY, None)
        with self._lock:
            self.n_considered += 1
            eng = self._engine
        if req.config.get(NO_SURROGATE_KEY):
            return False                       # pinned to the real path
        if self.degraded_until is not None:
            return False                       # outage / drift cool-down
        if self.model_name is not None and \
                req.model_name != self.model_name:
            return False                       # not this surrogate's model
        if not cost or cost < self.runtime_budget_s:
            return False                       # cheap enough to just run
        if eng is None or eng.n_train() < self.min_train:
            return False                       # no (trained) surrogate yet
        theta = request_features(req)          # flattened once per request
        if theta is None or len(theta) != eng.dim():
            return False                       # not in the surrogate's space
        sd = float(self.trust_sd([theta])[0])
        avoided = max(float(cost) - self.latency_s, 0.0)
        with self._lock:
            self._sds.append(sd)
            if sd > self.sd_threshold:
                return False                   # not trusted at this theta
            # one credit per TASK, not per decision: a requeued attempt
            # (crash, injected failure) re-decides but must not double
            # the offload count or the avoided-CPU credit
            if req.config.get("_surrogate_credit") is None:
                self.n_offloaded += 1
                self.cpu_seconds_avoided += avoided
                req.config["_surrogate_credit"] = avoided
        req.config[SURROGATE_KEY] = True
        return True

    def rollback(self, req: "EvalRequest") -> None:
        """Un-credit an offload that will not happen after all (failed
        surrogate evaluation, trust lost on a requeue): no-op unless this
        task holds a credit."""
        credit = req.config.pop("_surrogate_credit", None)
        if credit is None:
            return
        with self._lock:
            self.n_offloaded -= 1
            self.cpu_seconds_avoided -= credit

    def note_served(self) -> None:
        """Count one served surrogate evaluation (the simulator calls
        this where the live path counts inside `evaluate`)."""
        with self._lock:
            self.n_evals += 1

    # -- degradation (outage faults, drift alarms) -----------------------
    def set_degraded(self, now: float, until: float,
                     reason: str = "outage") -> None:
        """Disable offload until ``until`` (every `_decide` answers
        "real path").  Emits one ``offload.degraded`` instant on the
        healthy->degraded edge; an extension while already degraded
        just moves the deadline."""
        with self._lock:
            was_healthy = self.degraded_until is None
            self.degraded_until = float(until)
            self.degraded_reason = str(reason)
        if was_healthy and self.tracer is not None:
            self.tracer.instant("offload.degraded", ts=now,
                                args={"degraded": True, "reason": reason})

    def tick_degraded(self, now: float) -> None:
        """Re-arm once the cool-down has passed (called from
        `LifecycleStepper.step`, so sim and live re-arm at the same
        virtual instant)."""
        with self._lock:
            until = self.degraded_until
            if until is None or now < until:
                return
            reason = self.degraded_reason
            self.degraded_until = None
            self.degraded_reason = None
        if self.tracer is not None:
            self.tracer.instant("offload.degraded", ts=now,
                                args={"degraded": False, "reason": reason})

    def note_drift_alarm(self, alarm: Any, now: float) -> None:
        """`CalibrationMonitor.on_alarm` adapter: a drifting cost model
        means the offload economics (and trust region) are suspect —
        cool off for `drift_disable_s` seconds."""
        phase = (alarm or {}).get("phase", "?")
        self.set_degraded(now, now + self.drift_disable_s,
                          reason=f"drift:{phase}")

    # -- surrogate serving ----------------------------------------------
    def evaluate(self, parameters) -> List[List[float]]:
        """Serve one offloaded task: the GP posterior mean at theta, in
        UM-Bridge output shape ([[...]])."""
        theta = flatten_parameters(parameters)
        if theta is None:
            raise ValueError(f"unflattenable parameters {parameters!r}")
        with self._lock:
            eng = self._engine
        mean, _ = eng.predict_batch(np.asarray([theta], np.float32))
        out = [[float(v) for v in np.asarray(mean)[0]]]
        self.note_served()                     # only ANSWERED evals count
        return out

    def observe(self, parameters, value,
                model_name: Optional[str] = None) -> None:
        """Feed one completed REAL run; the engine is conditioned in
        batches of `condition_every` (every conditioning costs at least a
        fresh predict shape — amortise it; what the conditioning itself
        costs is the engine backend's contract: a full O(n³) refit on
        "exact", an O(n²) block update on "incremental", an O(cap³)
        per-affected-expert refactor on "partitioned").  Scoped engines
        ignore other models' completions — conditioning the surrogate on
        a different model's values would shrink variance on garbage."""
        if self.model_name is not None and model_name is not None \
                and model_name != self.model_name:
            return
        theta = flatten_parameters(parameters)
        if theta is None:
            return
        y = flatten_parameters(value)
        if y is None:
            return
        with self._lock:
            eng = self._engine
            if eng is None or len(theta) != eng.dim():
                return
            if len(y) != eng.n_outputs():
                return
            self._pend_x.append(theta)
            self._pend_y.append(y)
            if len(self._pend_x) < self.condition_every:
                return
            xs, ys = self._pend_x, self._pend_y
            self._pend_x, self._pend_y = [], []
        # conditioning (and the recency cap, owned by the engine) runs
        # outside the lock; engines are persistent so readers racing this
        # update keep a consistent snapshot
        new_engine = eng.condition(np.asarray(xs, np.float32),
                                   np.asarray(ys, np.float32))
        self._warm(new_engine)                 # compile off the hot path
        with self._lock:
            if self._engine is eng:
                self._engine = new_engine
            else:
                # lost a conditioning race (or a re-arm): the batch is
                # real ground truth — requeue it rather than dropping it
                self._pend_x.extend(xs)
                self._pend_y.extend(ys)

    # -- introspection ---------------------------------------------------
    def stats(self):
        """Snapshot as a `repro.core.metrics.OffloadStats` (imported
        lazily: repro.core depends on repro.sched, not vice versa)."""
        from repro.core import metrics as _metrics
        with self._lock:
            return _metrics.OffloadStats(
                n_considered=self.n_considered,
                n_offloaded=self.n_offloaded,
                n_surrogate_evals=self.n_evals,
                cpu_seconds_avoided=self.cpu_seconds_avoided,
                sd_histogram=_metrics.sd_histogram(self._sds))


@register_policy("offload")
class SurrogateOffloadPolicy(SchedulingPolicy):
    """Single-node surrogate-offload routing around any inner policy.

    Offloaded tasks land in a FIFO fast lane popped before the inner
    queue; everything else flows through the wrapped policy unchanged.
    Construct with a configured `SurrogateOffload` (``surrogate=``); the
    name-registered default builds an unarmed engine, i.e. plain
    pass-through to the inner policy until a posterior is attached.
    """

    name = "offload"

    def __init__(self, predictor=None, policy: Any = "fcfs",
                 surrogate: Optional[SurrogateOffload] = None):
        super().__init__(predictor)
        if isinstance(policy, SchedulingPolicy):
            raise TypeError(
                "SurrogateOffloadPolicy wraps a fresh inner policy: pass "
                "a registered name or factory, not a shared instance")
        self.surrogate = surrogate if surrogate is not None \
            else SurrogateOffload()
        self._inner = make_policy(policy, predictor)
        self._fast: Deque[QueueItem] = deque()

    def bind(self, predictor) -> "SurrogateOffloadPolicy":
        super().bind(predictor)
        self._inner.bind(self.predictor)
        return self

    def push(self, req, attempt):
        if self.surrogate.decide(req, cost=self.cost(req)):
            self._fast.append((req, attempt))
        else:
            self._inner.push(req, attempt)

    def pop(self, worker: Optional[WorkerView] = None
            ) -> Optional[QueueItem]:
        if self._fast:
            return self._fast.popleft()
        return self._inner.pop(worker)

    def pending(self) -> List[QueueItem]:
        return list(self._fast) + self._inner.pending()

    def __len__(self) -> int:
        return len(self._fast) + len(self._inner)

    def remove_worker(self, wid: int) -> None:
        self._inner.remove_worker(wid)
