"""Pluggable scheduling: policies + online runtime predictors.

The architectural seam between "what to run next" and the three dispatch
layers that need an answer — the live `Executor`, the UM-Bridge
`LoadBalancer` facade, and the discrete-event `simulate_policy` loop.
Pick by name (`policy="pack", predictor="gp"`) or pass configured
instances; register new ones with `@register_policy` / `@register_predictor`.
"""
from repro.sched.costq import SortedCostQueue
from repro.sched.offload import SurrogateOffload, SurrogateOffloadPolicy
from repro.sched.policy import (EDFPolicy, FairSharePolicy, FCFSPolicy,
                                LPTPolicy, PackingPolicy, SchedulingPolicy,
                                SJFPolicy, WorkStealingPolicy, WorkerView)
from repro.sched.predictor import (GPRuntimePredictor, QuantileEstimator,
                                   RuntimePredictor, flatten_parameters,
                                   request_features)
from repro.sched.registry import (POLICIES, PREDICTORS, make_policy,
                                  make_predictor, register_policy,
                                  register_predictor)
