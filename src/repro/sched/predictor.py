"""Online runtime predictors for UQ task scheduling.

The paper's central scheduling difficulty is that UQ task runtimes are
"potentially unpredictable" — GS2 runs vary from minutes to hours with the
seven physics inputs.  HQ's *time request* is a static per-workload hint;
these predictors replace it with estimates that improve online as tasks
complete:

  * `QuantileEstimator` — a running per-model quantile tracker.  Its p50
    is the cost estimate; its p95 feeds the executor's straggler-mitigation
    threshold (replacing the ad-hoc scan over completed results).
  * `GPRuntimePredictor` — a Gaussian process ON THE INPUT PARAMETERS,
    reusing `repro.uq.gp`.  It learns the runtime surface t(theta) from
    completed tasks: fit once at `min_fit` observations, then condition
    incrementally (`gp.condition`, one Cholesky rebuild, no re-training)
    and re-fit hyperparameters every `refit_every` completions.  Runtimes
    are modelled in log-space (positive, heavy-tailed).

Both are thread-safe: the live `Executor` feeds completions from worker
threads while the monitor thread queries quantiles.
"""
from __future__ import annotations

import bisect
import math
import threading
from collections import deque
from typing import (TYPE_CHECKING, Any, Deque, Dict, List, Optional,
                    Protocol, Sequence, runtime_checkable)

from repro.sched.registry import register_predictor

if TYPE_CHECKING:                              # hint-only: keeps repro.sched
    from repro.core.task import EvalRequest    # import-cycle-free


@runtime_checkable
class RuntimePredictor(Protocol):
    """What a scheduling policy / executor needs from a predictor."""

    def predict(self, req: EvalRequest) -> Optional[float]:
        """Expected compute seconds for `req`; None if unknown."""
        ...

    def observe(self, req: EvalRequest, compute_t: float) -> None:
        """Feed one completed task's measured compute time."""
        ...

    def quantile(self, q: float, model_name: Optional[str] = None
                 ) -> Optional[float]:
        """Runtime quantile over completions (pooled, or one model's)."""
        ...

    # Predictors MAY additionally expose
    #     predict_many(reqs) -> List[Optional[float]]
    # — one batched pass semantically equal to [predict(r) for r in reqs].
    # `SchedulingPolicy.costs` uses it when present, so bulk re-costing
    # (heap rebuilds, backlog ledgers) runs at batch cost: the GP
    # predictor scores the whole queue through `gp.predict_batch`
    # (bounded compile shapes, fused launches) instead of issuing one
    # `gp.predict` per task.


def flatten_parameters(parameters: Any) -> Optional[List[float]]:
    """Best-effort flatten of an UM-Bridge parameter payload ([[...]] lists)
    into a fixed feature vector; None if it contains non-numeric leaves OR
    flattens to nothing.  An empty/degenerate payload must NOT read as a
    valid zero-length feature vector: the GP predictor locks its feature
    dimension on the first flattenable request, and `_dim = 0` would pin
    it to a featureless GP forever after."""
    out: List[float] = []

    def walk(v) -> bool:
        if isinstance(v, (int, float)):
            out.append(float(v))
            return True
        if isinstance(v, (list, tuple)):
            return all(walk(u) for u in v)
        try:                                   # numpy / jax scalars & arrays
            import numpy as _np
            arr = _np.asarray(v, dtype=float)
            out.extend(float(x) for x in arr.ravel())
            return True
        except Exception:                      # noqa: BLE001
            return False

    if not walk(parameters) or not out:
        return None
    return out


def request_features(req: EvalRequest) -> Optional[List[float]]:
    """`flatten_parameters(req.parameters)`, cached ON the request.

    Every cost-scoring pass over a queue re-reads each request's feature
    vector (GP predict, offload trust gate, heap rebuilds) and a request
    survives many passes (requeues, migrations, re-costings), so the
    flatten walk — a Python recursion over the whole payload — runs once
    per request instead of once per scoring.  Parameters are treated as
    immutable after submission (the UM-Bridge contract); the cache is a
    1-tuple so an unflattenable payload (None) is cached too."""
    cached = req.__dict__.get("_feature_cache")
    if cached is not None:
        return cached[0]
    feats = flatten_parameters(req.parameters)
    req.__dict__["_feature_cache"] = (feats,)
    return feats


class _RunningQuantiles:
    """Bounded sorted window of observations with linear-interp quantiles."""

    def __init__(self, window: int):
        self.window = window
        self._ordered: List[float] = []        # sorted values
        # arrival order (for eviction): a deque, because a full window
        # evicts on EVERY observation — on the executor's completion path
        # — and list.pop(0) is an O(window) memmove each time
        self._fifo: Deque[float] = deque()
        self.count = 0

    def add(self, x: float):
        self.count += 1
        self._fifo.append(x)
        bisect.insort(self._ordered, x)
        if len(self._fifo) > self.window:
            old = self._fifo.popleft()
            del self._ordered[bisect.bisect_left(self._ordered, old)]

    def quantile(self, q: float) -> Optional[float]:
        s = self._ordered
        if not s:
            return None
        i = min(max(q, 0.0), 1.0) * (len(s) - 1)
        lo, hi = int(math.floor(i)), int(math.ceil(i))
        return s[lo] + (s[hi] - s[lo]) * (i - lo)


@register_predictor("quantile")
class QuantileEstimator:
    """Per-model running quantile estimator.

    `predict` returns the model's p50 (the single best constant guess under
    absolute loss); `quantile` exposes arbitrary quantiles — the executor's
    straggler monitor asks for p95.
    """

    def __init__(self, window: int = 512, predict_quantile: float = 0.5,
                 min_observed: int = 3):
        self.window = window
        self.predict_quantile = predict_quantile
        self.min_observed = min_observed
        self._lock = threading.Lock()
        self._per_model: Dict[str, _RunningQuantiles] = {}
        self._pooled = _RunningQuantiles(window)

    def observe(self, req: EvalRequest, compute_t: float) -> None:
        with self._lock:
            rq = self._per_model.get(req.model_name)
            if rq is None:
                rq = self._per_model[req.model_name] = \
                    _RunningQuantiles(self.window)
            rq.add(compute_t)
            self._pooled.add(compute_t)

    def predict(self, req: EvalRequest) -> Optional[float]:
        with self._lock:
            rq = self._per_model.get(req.model_name)
            if rq is None or rq.count < self.min_observed:
                return None
            return rq.quantile(self.predict_quantile)

    def predict_many(self, reqs: Sequence[EvalRequest]
                     ) -> List[Optional[float]]:
        """Batched `predict`: one lock acquisition and one quantile
        evaluation per distinct model for the whole batch — a UQ queue is
        thousands of requests over a handful of models."""
        with self._lock:
            per_model: Dict[str, Optional[float]] = {}
            out: List[Optional[float]] = []
            for req in reqs:
                name = req.model_name
                if name not in per_model:
                    rq = self._per_model.get(name)
                    per_model[name] = (
                        None if rq is None or rq.count < self.min_observed
                        else rq.quantile(self.predict_quantile))
                out.append(per_model[name])
            return out

    def predict_many_with_sd(self, reqs: Sequence[EvalRequest]
                             ) -> List[tuple]:
        """Batched (mean, sd) pairs: the p50 estimate plus a spread proxy
        from the central quantiles — sd ≈ (p84 − p16) / 2, the normal
        1-sigma band read off the empirical window.  Feeds the
        uncertainty-aware packing path; (None, None) where unknown."""
        with self._lock:
            per_model: Dict[str, tuple] = {}
            out: List[tuple] = []
            for req in reqs:
                name = req.model_name
                if name not in per_model:
                    rq = self._per_model.get(name)
                    if rq is None or rq.count < self.min_observed:
                        per_model[name] = (None, None)
                    else:
                        mean = rq.quantile(self.predict_quantile)
                        sd = (rq.quantile(0.84) - rq.quantile(0.16)) / 2.0
                        per_model[name] = (mean, max(sd, 0.0))
                out.append(per_model[name])
            return out

    def quantile(self, q: float, model_name: Optional[str] = None
                 ) -> Optional[float]:
        with self._lock:
            rq = (self._per_model.get(model_name) if model_name
                  else self._pooled)
            return rq.quantile(q) if rq else None

    def n_observed(self, model_name: Optional[str] = None) -> int:
        with self._lock:
            if model_name is None:
                return self._pooled.count
            rq = self._per_model.get(model_name)
            return rq.count if rq else 0

    def version(self) -> object:
        """Changes whenever predictions may have changed (every obs)."""
        return self.n_observed()

    # -- persistence (broker journal / Executor.snapshot) ---------------
    def state_dict(self) -> Dict[str, Any]:
        """JSON-able state: each model's observation window (arrival
        order) plus lifetime counts, so `min_observed` gates and
        `version()` resume where they left off."""
        with self._lock:
            return {
                "kind": "quantile",
                "per_model": {m: list(rq._fifo)
                              for m, rq in self._per_model.items()},
                "counts": {m: rq.count
                           for m, rq in self._per_model.items()},
                "pooled_count": self._pooled.count,
            }

    def load_state(self, state: Dict[str, Any]) -> None:
        """Inverse of `state_dict`.  The pooled window is rebuilt from
        the per-model windows (original interleaving is not preserved —
        per-model predictions, the scheduling signal, round-trip
        exactly; pooled quantiles are window-equivalent)."""
        with self._lock:
            self._per_model = {}
            self._pooled = _RunningQuantiles(self.window)
            counts = state.get("counts", {})
            for model, vals in state.get("per_model", {}).items():
                rq = _RunningQuantiles(self.window)
                for v in vals:
                    rq.add(float(v))
                    self._pooled.add(float(v))
                rq.count = int(counts.get(model, rq.count))
                self._per_model[model] = rq
            self._pooled.count = int(state.get("pooled_count",
                                               self._pooled.count))


@register_predictor("gp")
class GPRuntimePredictor:
    """GP regression of log-runtime on the task's input parameters.

    This is the predictor the paper's premise calls for: GS2 runtimes vary
    *with the inputs*, so a surrogate over theta (the same trick the paper
    plays for the physics QoI with its GP surrogate) recovers per-task cost
    estimates no static time request can express.

    Falls back to the per-model quantile estimate until `min_fit`
    observations with a consistent feature dimension have arrived, and for
    requests whose parameters cannot be flattened.

    `backend` selects the surrogate engine (`repro.uq.engine`) carrying
    the posterior: "exact" (default — every conditioning is a full
    Cholesky refit, the reference behaviour), "incremental" (rank-k
    block updates, O(n²) per conditioning batch — the always-on-service
    choice once completions stream in faster than refits amortise), or
    "partitioned" (cap-bounded local-GP ensemble for training sets one
    Cholesky can't hold).
    """

    def __init__(self, min_fit: int = 8, refit_every: int = 32,
                 condition_every: int = 8, max_points: int = 256,
                 kind: str = "rbf", fit_steps: int = 100, window: int = 512,
                 backend: str = "exact"):
        self.min_fit = min_fit
        self.refit_every = refit_every
        # batch size for incremental conditioning: every posterior size is
        # a fresh XLA compile of gp.predict, so absorbing completions in
        # batches (not one-by-one) keeps compile churn ~1/condition_every
        self.condition_every = condition_every
        self.max_points = max_points
        self.kind = kind
        self.fit_steps = fit_steps
        self.backend = backend
        self._lock = threading.Lock()
        self._fallback = QuantileEstimator(window=window)
        self._xs: List[List[float]] = []       # feature rows (fixed dim)
        self._ys: List[float] = []             # log(compute_t + eps)
        self._dim: Optional[int] = None
        self._engine = None                    # repro.uq.engine backend
        self._in_post = 0                      # rows of _xs in the posterior
        self._since_refit = 0
        self._post_version = 0                 # bumped on posterior installs
        self.n_fits = 0

    @property
    def _post(self):
        """The underlying `GPPosterior` (None before the first fit) —
        read-only introspection; the engine owns the conditioning."""
        eng = self._engine
        return getattr(eng, "post", eng)

    # -- RuntimePredictor -----------------------------------------------
    def observe(self, req: EvalRequest, compute_t: float) -> None:
        self._fallback.observe(req, compute_t)
        feats = request_features(req)
        if feats is None:
            return
        from repro.uq import engine as uq_engine
        import numpy as np
        fit_data = cond_args = None
        with self._lock:
            if self._dim is None:
                self._dim = len(feats)
            if len(feats) != self._dim:
                return                         # heterogeneous payload: skip
            self._xs.append(feats)
            self._ys.append(math.log(max(compute_t, 1e-6)))
            self._since_refit += 1
            if len(self._xs) < self.min_fit:
                return
            if self._engine is None or self._since_refit >= self.refit_every:
                if len(self._xs) > self.max_points:    # keep the most recent
                    del self._xs[:-self.max_points]
                    del self._ys[:-self.max_points]
                fit_data = (np.asarray(self._xs, dtype=float),
                            np.asarray(self._ys, dtype=float))
                self._since_refit = 0          # claim the refit
            elif len(self._xs) - self._in_post >= self.condition_every:
                cond_args = (self._engine, self._xs[self._in_post:],
                             self._ys[self._in_post:])
                self._in_post = len(self._xs)
        # the expensive JAX work runs OUTSIDE the lock so concurrent
        # predict()/observe() calls are never stalled behind a refit;
        # a stale-by-one posterior install is harmless (best-effort)
        if fit_data is not None:
            new_engine = uq_engine.fit_engine(
                fit_data[0], fit_data[1], self.backend, kind=self.kind,
                steps=self.fit_steps)
            with self._lock:
                self._engine = new_engine
                self._in_post = len(fit_data[0])
                self._post_version += 1
                self.n_fits += 1
        elif cond_args is not None:
            new_engine = cond_args[0].condition(cond_args[1], cond_args[2])
            with self._lock:
                if self._engine is cond_args[0]:  # drop if a refit raced
                    self._engine = new_engine
                    self._post_version += 1

    def predict(self, req: EvalRequest) -> Optional[float]:
        feats = request_features(req)
        with self._lock:
            eng = self._engine
            dim_ok = feats is not None and self._dim == len(feats or [])
        if eng is None or not dim_ok:
            return self._fallback.predict(req)
        mean, _ = eng.predict([feats])
        return float(math.exp(float(mean[0, 0])))

    def predict_many(self, reqs: Sequence[EvalRequest]
                     ) -> List[Optional[float]]:
        """Batched `predict`: every GP-eligible request in the batch is
        scored by ONE `gp.predict_batch` pass (bucket-padded, at most
        `len(gp.PREDICT_BUCKETS)` compile shapes per training-set size,
        one fused launch per chunk) instead of one `gp.predict` — and one
        XLA dispatch — per task.  Feature vectors come from the
        per-request cache, so `flatten_parameters` never re-walks a
        payload on re-costing.  Ineligible requests (no posterior yet,
        unflattenable or wrong-dimension payloads) take the per-model
        quantile fallback in one batch as well."""
        with self._lock:
            eng = self._engine
            dim = self._dim
        feats = [request_features(r) for r in reqs]
        out: List[Optional[float]] = [None] * len(reqs)
        gp_idx: List[int] = []
        fb_idx: List[int] = []
        for i, f in enumerate(feats):
            if eng is not None and f is not None and dim == len(f):
                gp_idx.append(i)
            else:
                fb_idx.append(i)
        if gp_idx:
            import numpy as np
            x = np.asarray([feats[i] for i in gp_idx], dtype=np.float32)
            mean, _ = eng.predict_batch(x)
            secs = np.exp(np.asarray(mean)[:, 0].astype(np.float64))
            for j, i in enumerate(gp_idx):
                out[i] = float(secs[j])
        if fb_idx:
            fb = self._fallback.predict_many([reqs[i] for i in fb_idx])
            for j, i in enumerate(fb_idx):
                out[i] = fb[j]
        return out

    def predict_many_with_sd(self, reqs: Sequence[EvalRequest]
                             ) -> List[tuple]:
        """Batched (mean seconds, sd seconds) pairs for the
        uncertainty-aware packing path — same eligibility split as
        `predict_many`, same ONE `predict_batch` pass, but the posterior
        variance rides along.  Runtimes are modelled log-normally
        (mu, sigma² in log-space), so the predictive sd in seconds is
        mean_s·sqrt(expm1(sigma²)); sigma² is clamped before `expm1` —
        an untrained posterior's prior variance must saturate the risk
        term, not overflow it.  (None, None) where no estimate exists."""
        with self._lock:
            eng = self._engine
            dim = self._dim
        feats = [request_features(r) for r in reqs]
        out: List[tuple] = [(None, None)] * len(reqs)
        gp_idx: List[int] = []
        fb_idx: List[int] = []
        for i, f in enumerate(feats):
            if eng is not None and f is not None and dim == len(f):
                gp_idx.append(i)
            else:
                fb_idx.append(i)
        if gp_idx:
            import numpy as np
            x = np.asarray([feats[i] for i in gp_idx], dtype=np.float32)
            mean, var = eng.predict_batch(x)
            mu = np.asarray(mean)[:, 0].astype(np.float64)
            s2 = np.minimum(np.asarray(var)[:, 0].astype(np.float64), 20.0)
            secs = np.exp(mu)
            sds = secs * np.sqrt(np.expm1(np.maximum(s2, 0.0)))
            for j, i in enumerate(gp_idx):
                out[i] = (float(secs[j]), float(sds[j]))
        if fb_idx:
            fb = self._fallback.predict_many_with_sd(
                [reqs[i] for i in fb_idx])
            for j, i in enumerate(fb_idx):
                out[i] = fb[j]
        return out

    def version(self) -> object:
        """Changes only when predictions may have changed: per posterior
        install once fitted, per observation while on the fallback."""
        with self._lock:
            if self._engine is None:
                return ("fallback", self._fallback.n_observed())
            return ("post", self._post_version)

    def quantile(self, q: float, model_name: Optional[str] = None
                 ) -> Optional[float]:
        return self._fallback.quantile(q, model_name)

    def n_observed(self, model_name: Optional[str] = None) -> int:
        return self._fallback.n_observed(model_name)

    # -- persistence (broker journal / Executor.snapshot) ---------------
    def state_dict(self) -> Dict[str, Any]:
        """JSON-able state: the engine BACKEND NAME and the conditioning
        set (feature rows + log-runtimes), plus the quantile fallback.
        The fitted posterior itself is not serialised — `load_state`
        refits the same backend from the same data, which is cheaper
        than it sounds (one `fit_engine` call) and keeps the journal
        free of jax arrays."""
        with self._lock:
            return {
                "kind": "gp",
                "backend": self.backend,
                "gp_kind": self.kind,
                "dim": self._dim,
                "xs": [list(row) for row in self._xs],
                "ys": [float(y) for y in self._ys],
                "n_fits": self.n_fits,
                "fallback": self._fallback.state_dict(),
            }

    def load_state(self, state: Dict[str, Any]) -> None:
        """Inverse of `state_dict`: restores the conditioning set AND
        the engine backend recorded in the state — a broker restored
        from a journal re-costs with the surrogate it was running, not
        whatever backend the fresh constructor defaulted to."""
        self._fallback.load_state(state.get("fallback", {}))
        xs = [[float(v) for v in row] for row in state.get("xs", [])]
        ys = [float(y) for y in state.get("ys", [])]
        backend = str(state.get("backend", self.backend))
        kind = str(state.get("gp_kind", self.kind))
        new_engine = None
        if len(xs) >= self.min_fit:
            from repro.uq import engine as uq_engine
            import numpy as np
            new_engine = uq_engine.fit_engine(
                np.asarray(xs, dtype=float), np.asarray(ys, dtype=float),
                backend, kind=kind, steps=self.fit_steps)
        with self._lock:
            self.backend = backend
            self.kind = kind
            self._xs = xs
            self._ys = ys
            dim = state.get("dim")
            self._dim = (int(dim) if dim is not None
                         else (len(xs[0]) if xs else None))
            self._engine = new_engine
            self._in_post = len(xs) if new_engine is not None else 0
            self._since_refit = 0
            self._post_version += 1
            self.n_fits = int(state.get("n_fits", self.n_fits))
            if new_engine is not None:
                self.n_fits += 1


# Backend variants by name (the registry resolves names via a no-arg
# call, so these are factories, not subclasses): `predictor="gp"` keeps
# the exact reference path; the variants opt a deployment into O(n²)
# conditioning or the cap-bounded ensemble without touching call sites.
@register_predictor("gp-incremental")
def _gp_incremental() -> GPRuntimePredictor:
    return GPRuntimePredictor(backend="incremental")


@register_predictor("gp-partitioned")
def _gp_partitioned() -> GPRuntimePredictor:
    return GPRuntimePredictor(backend="partitioned")
