"""String-keyed policy / predictor registration.

Mirrors the `BackendSpec` idiom in `repro.core.backends` (the `BACKENDS`
dict + `get`): call sites name a policy ("fcfs", "sjf", "lpt", "pack",
"steal", "edf", the multi-tenant "fairshare", or the cluster-level
"broker") or predictor ("quantile", "gp", "none") by string, or pass a
configured instance straight through.
Downstream work (surrogate-offload routing, SLO-aware admission) plugs
in with `@register_policy("my-policy")` — no core-module edits.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Union

POLICIES: Dict[str, Callable[..., Any]] = {}
PREDICTORS: Dict[str, Optional[Callable[..., Any]]] = {"none": None}


def register_policy(name: str):
    def deco(cls):
        POLICIES[name] = cls
        return cls
    return deco


def register_predictor(name: str):
    def deco(cls):
        PREDICTORS[name] = cls
        return cls
    return deco


def make_policy(spec: Union[str, Any], predictor: Any = None):
    """Resolve a policy name or pass an instance through.  A predictor
    given here is bound onto the policy unless it already has one."""
    if isinstance(spec, str):
        try:
            cls = POLICIES[spec]
        except KeyError:
            raise KeyError(f"unknown policy {spec!r}; "
                           f"registered: {sorted(POLICIES)}") from None
        return cls(predictor=predictor)
    if spec is None:
        return POLICIES["fcfs"](predictor=predictor)
    return spec.bind(predictor)


def make_predictor(spec: Union[str, Any, None]):
    """Resolve a predictor name ('none' and None both mean no predictor)
    or pass an instance through."""
    if spec is None:
        return None
    if isinstance(spec, str):
        try:
            cls = PREDICTORS[spec]
        except KeyError:
            raise KeyError(f"unknown predictor {spec!r}; "
                           f"registered: {sorted(PREDICTORS)}") from None
        return cls() if cls is not None else None
    return spec
