"""Dispatching wrappers around the Pallas kernels.

Every op has three implementations selected by `impl` (or the module default
set via `set_default_impl`):
  * "pallas"    — the TPU kernel (compiled; requires a TPU backend),
  * "interpret" — the same Pallas kernel run in interpret mode (CPU-correct,
                  used by the test suite to validate the kernel body),
  * "xla"       — the pure-jnp chunked fallback from `ref.py` (used on CPU and
                  for the 512-device dry-run lowering).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

_DEFAULT_IMPL = "auto"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("auto", "pallas", "interpret", "xla")
    _DEFAULT_IMPL = impl


def _resolve(impl: Optional[str]) -> str:
    impl = impl or _DEFAULT_IMPL
    if impl == "auto":
        try:
            on_tpu = jax.default_backend() == "tpu"
        except Exception:
            on_tpu = False
        return "pallas" if on_tpu else "xla"
    return impl


# --------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool = True,
                    impl: Optional[str] = None):
    """q: [B,S,H,D]; k/v: [B,Skv,Hkv,D] (GQA expanded inside)."""
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal,
                                  interpret=(mode == "interpret"))
    if q.shape[1] <= 1024 and k.shape[1] <= 1024:
        return ref.attention(q, k, v, causal=causal)
    return ref.attention_chunked(q, k, v, causal=causal)


def rwkv6_wkv(r, k, v, w, u, state=None, *, impl: Optional[str] = None,
              chunk: int = 64):
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import rwkv6_scan
        return rwkv6_scan.rwkv6_wkv(r, k, v, w, u, state, chunk=chunk,
                                    interpret=(mode == "interpret"))
    return ref.rwkv6_wkv_chunked(r, k, v, w, u, state, chunk=chunk)


def mamba2_ssd(x, dt, a, b, c, d, state=None, *, impl: Optional[str] = None,
               chunk: int = 128):
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import mamba2_ssd as ssd
        return ssd.mamba2_ssd(x, dt, a, b, c, d, state, chunk=chunk,
                              interpret=(mode == "interpret"))
    return ref.mamba2_ssd_chunked(x, dt, a, b, c, d, state, chunk=chunk)


def gp_kernel_matrix(x1, x2, lengthscale, variance, kind: str = "rbf", *,
                     impl: Optional[str] = None):
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import gp_kernel
        return gp_kernel.gp_kernel_matrix(x1, x2, lengthscale, variance, kind,
                                          interpret=(mode == "interpret"))
    return ref.gp_kernel_matrix(x1, x2, lengthscale, variance, kind)


def gp_predict(x_train, x_star, lengthscale, variance, alpha, linv,
               kind: str = "rbf", *, impl: Optional[str] = None):
    """Batched GP posterior predict: (normalised mean [S, M], quadratic
    form ||L^-1 ks||^2 [S]) in one launch — covariance assembly, alpha
    product and the variance quadratic form fused so queue scoring never
    materialises Ks in HBM per task."""
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import gp_kernel
        return gp_kernel.gp_predict(x_train, x_star, lengthscale, variance,
                                    alpha, linv, kind,
                                    interpret=(mode == "interpret"))
    return ref.gp_predict(x_train, x_star, lengthscale, variance, alpha,
                          linv, kind)


def gp_predict_experts(x_train, x_star, lengthscale, variance, alpha, linv,
                       kind: str = "rbf", *, impl: Optional[str] = None):
    """Stacked local-GP ensemble predict: every expert answers its routed
    query tile in ONE launch (grid over experts × query tiles on TPU,
    vmapped XLA elsewhere).  x_train: [E, N, D]; x_star: [E, S, D];
    alpha: [E, N, M]; linv: [E, N, N] -> (mean [E, S, M], qf [E, S])."""
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        from repro.kernels import gp_kernel
        return gp_kernel.gp_predict_experts(
            x_train, x_star, lengthscale, variance, alpha, linv, kind,
            interpret=(mode == "interpret"))
    return ref.gp_predict_experts(x_train, x_star, lengthscale, variance,
                                  alpha, linv, kind)
