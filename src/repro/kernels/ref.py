"""Pure-jnp oracles for every kernel, plus XLA-efficient chunked fallbacks.

The *simple* functions are the correctness oracles (O(S^2) memory where
applicable — test-sized inputs only).  The *chunked* functions are the
XLA fallbacks actually used by the model code off-TPU: same math, online
softmax / chunked-scan structure, bounded memory.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ==========================================================================
# Attention
# ==========================================================================
def _expand_kv(q, k, v):
    h, hkv = q.shape[2], k.shape[2]
    if h != hkv:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return k, v


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True) -> jax.Array:
    """Oracle. q:[B,S,H,D] k/v:[B,S,Hkv,D] -> [B,S,H,Dv]."""
    k, v = _expand_kv(q, k, v)
    sq, sk = q.shape[1], k.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, q_block: int = 512,
                      kv_block: int = 512) -> jax.Array:
    """Flash attention in pure JAX: online-softmax blocked forward and a
    custom blockwise-recompute VJP (memory O(S*block) in both directions —
    differentiating a naive scan would otherwise save O(S^2) residuals)."""
    k, v = _expand_kv(q, k, v)
    q_block = min(q_block, q.shape[1])
    kv_block = min(kv_block, k.shape[1])
    return _flash(q, k, v, causal, q_block, kv_block)


def _pad_blocks(x, blk):
    s = x.shape[1]
    pad = (-s) % blk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = x.shape[1] // blk
    b, _, h, d = x.shape
    # [B, n, blk, H, D] -> f32 blocks
    return x.reshape(b, n, blk, h, d).astype(jnp.float32), n


def _block_mask(qi, ki, q_block, kv_block, sq, sk, causal, q_off):
    qpos = qi * q_block + jnp.arange(q_block) + q_off
    kpos = ki * kv_block + jnp.arange(kv_block)
    valid = (kpos[None, :] < sk) & (qpos[:, None] < sq + q_off)
    if causal:
        valid = valid & (kpos[None, :] <= qpos[:, None])
    return valid


def attention_chunked_fwd(q, k, v, *, causal: bool = True,
                          q_offset=None, q_block: int = 512,
                          kv_block: int = 512):
    """Forward-only chunked attention with an explicit (traceable) global
    row offset for the Q block — the building block for context-parallel
    prefill, where each model-rank owns rows [off, off + sq) of a longer
    sequence."""
    k2, v2 = _expand_kv(q, k, v)
    q_block = min(q_block, q.shape[1])
    kv_block = min(kv_block, k2.shape[1])
    out, _ = _flash_fwd_impl(q, k2, v2, causal, q_block, kv_block,
                             q_off=q_offset)
    return out


def _flash_fwd_impl(q, k, v, causal, q_block, kv_block, q_off=None):
    b, sq, h, d = q.shape
    sk, dv = k.shape[1], v.shape[-1]
    qb, nq = _pad_blocks(q, q_block)
    kb, nk = _pad_blocks(k, kv_block)
    vb, _ = _pad_blocks(v, kv_block)
    scale = 1.0 / math.sqrt(d)
    if q_off is None:
        q_off = sk - sq

    def per_qblock(_, qi):
        qblk = qb[:, qi]

        def per_kvblock(state, ki):
            m, l, acc = state
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kb[:, ki]) * scale
            valid = _block_mask(qi, ki, q_block, kv_block, sq, sk, causal, q_off)
            s = jnp.where(valid[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            e = jnp.exp(s - m_new[..., None]) * valid[None, None]
            l_new = l * corr + jnp.sum(e, axis=-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("bhqk,bkhd->bhqd", e, vb[:, ki]))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, h, q_block), -1e30, jnp.float32),
                jnp.zeros((b, h, q_block), jnp.float32),
                jnp.zeros((b, h, q_block, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(per_kvblock, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]            # [B,H,Q,Dv]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))                # [B,H,Q]
        return None, (out.transpose(0, 2, 1, 3), lse)

    _, (blocks, lses) = jax.lax.scan(per_qblock, None, jnp.arange(nq))
    out = blocks.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_block, h, dv)
    lse = lses.transpose(1, 2, 0, 3).reshape(b, h, nq * q_block)
    return out[:, :sq].astype(q.dtype), lse[..., :sq]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, q_block, kv_block):
    return _flash_fwd_impl(q, k, v, causal, q_block, kv_block)[0]


def _flash_fwd(q, k, v, causal, q_block, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_block, kv_block)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_block, kv_block, res, dout):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    sk, dvd = k.shape[1], v.shape[-1]
    scale = 1.0 / math.sqrt(d)
    q_off = sk - sq
    qb, nq = _pad_blocks(q, q_block)
    kb, nk = _pad_blocks(k, kv_block)
    vb, _ = _pad_blocks(v, kv_block)
    dob, _ = _pad_blocks(dout.astype(jnp.float32), q_block)
    pad_q = nq * q_block - sq
    lse_p = jnp.pad(lse, ((0, 0), (0, 0), (0, pad_q)))
    lse_b = lse_p.reshape(b, h, nq, q_block)                    # [B,H,nq,Q]
    # D_i = rowsum(dO * O)
    dd = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    dd_b = jnp.pad(dd, ((0, 0), (0, pad_q), (0, 0))
                   ).reshape(b, nq, q_block, h)                 # [B,nq,Q,H]

    def _p_and_ds(qi, ki):
        s = jnp.einsum("bqhd,bkhd->bhqk", qb[:, qi], kb[:, ki]) * scale
        valid = _block_mask(qi, ki, q_block, kv_block, sq, sk, causal, q_off)
        s = jnp.where(valid[None, None], s, -1e30)
        p = jnp.exp(s - lse_b[:, :, qi][..., None]) * valid[None, None]
        dp = jnp.einsum("bqhd,bkhd->bhqk", dob[:, qi], vb[:, ki])
        ds = p * (dp - dd_b[:, qi].transpose(0, 2, 1)[..., None])
        return p, ds

    # pass 1: dq (scan q blocks; inner kv)
    def dq_block(_, qi):
        def inner(acc, ki):
            _, ds = _p_and_ds(qi, ki)
            return acc + jnp.einsum("bhqk,bkhd->bqhd", ds, kb[:, ki]) * scale, None
        acc0 = jnp.zeros((b, q_block, h, d), jnp.float32)
        dq, _ = jax.lax.scan(inner, acc0, jnp.arange(nk))
        return None, dq

    _, dqb = jax.lax.scan(dq_block, None, jnp.arange(nq))
    dq = dqb.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_block, h, d)[:, :sq]

    # pass 2: dk, dv (scan kv blocks; inner q)
    def dkv_block(_, ki):
        def inner(carry, qi):
            dk_acc, dv_acc = carry
            p, ds = _p_and_ds(qi, ki)
            dk_acc += jnp.einsum("bhqk,bqhd->bkhd", ds, qb[:, qi]) * scale
            dv_acc += jnp.einsum("bhqk,bqhd->bkhd", p, dob[:, qi])
            return (dk_acc, dv_acc), None
        init = (jnp.zeros((b, kv_block, h, d), jnp.float32),
                jnp.zeros((b, kv_block, h, dvd), jnp.float32))
        (dk_b, dv_b), _ = jax.lax.scan(inner, init, jnp.arange(nq))
        return None, (dk_b, dv_b)

    _, (dkb, dvb) = jax.lax.scan(dkv_block, None, jnp.arange(nk))
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(b, nk * kv_block, h, d)[:, :sk]
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(b, nk * kv_block, h, dvd)[:, :sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


# ==========================================================================
# RWKV6 (Finch) WKV recurrence — data-dependent per-channel decay.
#   state_t = diag(w_t) state_{t-1} + k_t v_t^T
#   out_t   = r_t^T (state_{t-1} + diag(u * k_t) v_t^T)
# ==========================================================================
def rwkv6_wkv(r, k, v, w, u, state: Optional[jax.Array] = None):
    """r,k,w: [B,S,H,K]; v: [B,S,H,V]; u: [H,K]; state: [B,H,K,V].
    Returns (out [B,S,H,V], final_state)."""
    b, s, h, kd = r.shape
    vd = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, kd, vd), jnp.float32)
    state = state.astype(jnp.float32)
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(st, t):
        rt, kt, vt, wt = rf[:, t], kf[:, t], vf[:, t], wf[:, t]
        kv = kt[..., :, None] * vt[..., None, :]               # [B,H,K,V]
        out = jnp.einsum("bhk,bhkv->bhv", rt, st + uf[..., :, None] * kv)
        st = wt[..., :, None] * st + kv
        return st, out

    state, outs = jax.lax.scan(step, state, jnp.arange(s))
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), state


def rwkv6_wkv_chunked(r, k, v, w, u, state: Optional[jax.Array] = None,
                      chunk: int = 64):
    """Chunked gated-linear-attention form of the WKV6 recurrence."""
    b, s, h, kd = r.shape
    vd = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, kd, vd), jnp.float32)
    state = state.astype(jnp.float32)
    pad = (-s) % chunk
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    n = (s + pad) // chunk
    rf = r.reshape(b, n, chunk, h, kd).astype(jnp.float32)
    kf = k.reshape(b, n, chunk, h, kd).astype(jnp.float32)
    vf = v.reshape(b, n, chunk, h, vd).astype(jnp.float32)
    wf = w.reshape(b, n, chunk, h, kd).astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def per_chunk(st, ci):
        rc, kc, vc, wc = rf[:, ci], kf[:, ci], vf[:, ci], wf[:, ci]
        logw = jnp.log(jnp.maximum(wc, 1e-30))                 # [B,C,H,K]
        cum = jnp.cumsum(logw, axis=1)                          # prod w_1..w_t
        # inter-chunk: r_t . (prod_{j<=t-1} w_j) state   (decays up to t-1)
        dec_in = jnp.exp(cum - logw)                            # prod w_1..w_{t-1}
        out_inter = jnp.einsum("bthk,bhkv->bthv", rc * dec_in, st)
        # intra-chunk: pairs j < t:  r_t (prod_{j<u<t} w ... ) using ratios
        # A[t,j] = sum_k r_t[k] k_j[k] * exp(cum[t-1,k] - cum[j,k])
        r_dec = rc * dec_in                                     # r_t * prod_{<=t-1}
        k_dec = kc * jnp.exp(-cum)                              # k_j / prod_{<=j}
        a = jnp.einsum("bthk,bjhk->bhtj", r_dec, k_dec)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
        a = a * tri[None, None]
        out_intra = jnp.einsum("bhtj,bjhv->bthv", a, vc)
        # diagonal bonus term: r_t . (u * k_t) v_t
        diag = jnp.einsum("bthk,bthk->bth", rc, uf[None, None] * kc)
        out_diag = diag[..., None] * vc
        # state update: st' = diag(prod_all w) st + sum_j (prod_{j<u<=C} w) k_j v_j
        dec_all = jnp.exp(cum[:, -1])                           # [B,H,K]
        k_out = kc * jnp.exp(cum[:, -1][:, None] - cum)         # prod_{j<u<=C}
        st = dec_all[..., None] * st + jnp.einsum("bjhk,bjhv->bhkv", k_out, vc)
        return st, out_inter + out_intra + out_diag

    state, outs = jax.lax.scan(per_chunk, state, jnp.arange(n))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n * chunk, h, vd)
    return out[:, :s].astype(r.dtype), state


# ==========================================================================
# Mamba2 SSD — scalar per-head decay.
#   state_t = exp(dt_t * A_h) state_{t-1} + dt_t * B_t x_t^T
#   y_t     = C_t . state_t + D_h * x_t
# ==========================================================================
def mamba2_ssd(x, dt, a, b_in, c_in, d, state: Optional[jax.Array] = None):
    """x: [B,S,H,P]; dt: [B,S,H]; a: [H] (negative); b,c: [B,S,N]; d: [H];
    state: [B,H,P,N].  Returns (y [B,S,H,P], final_state)."""
    bb, s, h, p = x.shape
    n = b_in.shape[-1]
    if state is None:
        state = jnp.zeros((bb, h, p, n), jnp.float32)
    state = state.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af, bf, cf, df = (t.astype(jnp.float32) for t in (a, b_in, c_in, d))

    def step(st, t):
        dtt = dtf[:, t]                                        # [B,H]
        dec = jnp.exp(dtt * af[None])                          # [B,H]
        dbx = jnp.einsum("bh,bhp,bn->bhpn", dtt, xf[:, t], bf[:, t])
        st = dec[..., None, None] * st + dbx
        y = jnp.einsum("bhpn,bn->bhp", st, cf[:, t]) + df[None, :, None] * xf[:, t]
        return st, y

    state, ys = jax.lax.scan(step, state, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state


def mamba2_ssd_chunked(x, dt, a, b_in, c_in, d,
                       state: Optional[jax.Array] = None, chunk: int = 128):
    """Chunked SSD (the Mamba2 'state-space dual' algorithm)."""
    bb, s, h, p = x.shape
    n = b_in.shape[-1]
    if state is None:
        state = jnp.zeros((bb, h, p, n), jnp.float32)
    state = state.astype(jnp.float32)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    xf = x.reshape(bb, nc, chunk, h, p).astype(jnp.float32)
    dtf = dt.reshape(bb, nc, chunk, h).astype(jnp.float32)
    bf = b_in.reshape(bb, nc, chunk, n).astype(jnp.float32)
    cf = c_in.reshape(bb, nc, chunk, n).astype(jnp.float32)
    af = a.astype(jnp.float32)
    df = d.astype(jnp.float32)

    def per_chunk(st, ci):
        xc, dtc, bc, cc = xf[:, ci], dtf[:, ci], bf[:, ci], cf[:, ci]
        la = dtc * af[None, None]                              # [B,C,H] log-decay
        cum = jnp.cumsum(la, axis=1)                           # sum_{u<=t}
        # inter: y_t += exp(cum_t) * (C_t . st)
        dec_t = jnp.exp(cum)                                   # [B,C,H]
        y_in = jnp.einsum("btn,bhpn->bthp", cc, st) * dec_t[..., None]
        # intra: L[t,j] = exp(cum_t - cum_j) for j<=t ; y_t += sum_j L C_t.B_j dt_j x_j
        g = jnp.einsum("btn,bjn->btj", cc, bc)                 # [B,C,C]
        ratio = cum[:, :, None, :] - cum[:, None, :, :]        # [B,C,C,H]
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
        l_mat = jnp.exp(ratio) * tri[None, :, :, None]
        y_intra = jnp.einsum("btj,btjh,bjh,bjhp->bthp", g, l_mat, dtc, xc)
        # state update
        dec_all = jnp.exp(cum[:, -1])                          # [B,H]
        k_dec = jnp.exp(cum[:, -1][:, None] - cum)             # [B,C,H]
        st = (dec_all[..., None, None] * st
              + jnp.einsum("bjh,bjh,bjhp,bjn->bhpn", k_dec, dtc, xc, bc))
        y = y_in + y_intra + df[None, None, :, None] * xc
        return st, y

    state, ys = jax.lax.scan(per_chunk, state, jnp.arange(nc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bb, nc * chunk, h, p)
    return y[:, :s].astype(x.dtype), state


# ==========================================================================
# GP kernel matrix (RBF / Matern-5/2)
# ==========================================================================
def gp_kernel_matrix(x1: jax.Array, x2: jax.Array, lengthscale: jax.Array,
                     variance: jax.Array, kind: str = "rbf") -> jax.Array:
    """x1: [N,D]; x2: [M,D]; ARD lengthscale: [D] -> [N,M] (f32)."""
    x1s = x1.astype(jnp.float32) / lengthscale.astype(jnp.float32)
    x2s = x2.astype(jnp.float32) / lengthscale.astype(jnp.float32)
    d2 = (jnp.sum(x1s ** 2, -1)[:, None] + jnp.sum(x2s ** 2, -1)[None, :]
          - 2.0 * x1s @ x2s.T)
    d2 = jnp.maximum(d2, 0.0)
    if kind == "rbf":
        k = jnp.exp(-0.5 * d2)
    elif kind == "matern52":
        r = jnp.sqrt(d2 + 1e-12)
        k = (1.0 + math.sqrt(5.0) * r + 5.0 / 3.0 * d2) * jnp.exp(-math.sqrt(5.0) * r)
    else:
        raise ValueError(kind)
    return variance.astype(jnp.float32) * k


def gp_predict(x_train: jax.Array, x_star: jax.Array, lengthscale: jax.Array,
               variance: jax.Array, alpha: jax.Array, linv: jax.Array,
               kind: str = "rbf") -> "tuple[jax.Array, jax.Array]":
    """Batched GP posterior predict (XLA fallback for the Pallas kernel).

    Returns (normalised mean [S, M], quadratic form [S]) where
    mean = Ks^T alpha and qf[s] = ||L^-1 ks||^2 (nonnegative by
    construction — the same conditioning as a triangular solve against
    the Cholesky factor); the caller maps both back to the original
    output scale.
    """
    ks = gp_kernel_matrix(x_train, x_star, lengthscale, variance, kind)
    mean = ks.T @ alpha
    v = linv @ ks
    qf = jnp.sum(v * v, axis=0)
    return mean, qf


def gp_predict_experts(x_train: jax.Array, x_star: jax.Array,
                       lengthscale: jax.Array, variance: jax.Array,
                       alpha: jax.Array, linv: jax.Array,
                       kind: str = "rbf") -> "tuple[jax.Array, jax.Array]":
    """Stacked local-GP ensemble predict (XLA fallback): vmap of
    `gp_predict` over the expert axis.

    x_train: [E, N, D]; x_star: [E, S, D]; alpha: [E, N, M];
    linv: [E, N, N]; shared hyperparameters
    -> (normalised mean [E, S, M], quadratic form [E, S]).  Zero-padded
    training rows contribute nothing (alpha/linv zero there), matching
    the Pallas kernel exactly.
    """
    return jax.vmap(
        lambda xt, xs, al, li: gp_predict(xt, xs, lengthscale, variance,
                                          al, li, kind)
    )(x_train, x_star, alpha, linv)
