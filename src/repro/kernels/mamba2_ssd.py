"""Mamba2 state-space-dual (SSD) scan as a chunked Pallas TPU kernel.

Same structure as rwkv6_scan: grid (B*H, n_chunks), sequential TPU grid
carrying the [P,N] state through an input/output-aliased ref, three MXU
matmuls per chunk.  dt is folded into x (xdt = dt*x) and into the
per-step log-decay (la = dt*A_h) by the wrapper; the D-skip term is
stateless and applied outside.

B/C are head-shared in Mamba2 — the BlockSpec index map points every head
of one batch row at the same [C,N] tile, so the shared tensors are staged
into VMEM once per (batch, chunk) instead of being materialised per-head
in HBM ([B,S,N] stays [B,S,N], never [B,S,H,N]).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(xdt_ref, la_ref, b_ref, c_ref, s_in_ref, y_ref, s_out_ref,
                *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_out_ref[...] = s_in_ref[...]

    st = s_out_ref[...][0].astype(jnp.float32)                 # [P,N]
    xc = xdt_ref[...][0].astype(jnp.float32)                   # [C,P] (dt folded)
    la = la_ref[...][0].astype(jnp.float32)                    # [C] log decay
    bc = b_ref[...][0].astype(jnp.float32)                     # [C,N]
    cc = c_ref[...][0].astype(jnp.float32)                     # [C,N]

    cum = jnp.cumsum(la)                                       # [C]
    # inter-chunk: y_t += exp(cum_t) * C_t . st
    y_inter = jax.lax.dot_general(cc, st, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[:, None]                  # [C,P]
    # intra-chunk: y_t += sum_{j<=t} (C_t.B_j) exp(cum_t-cum_j) xdt_j
    g = jax.lax.dot_general(cc, bc, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [C,C]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mat = jnp.exp(cum[:, None] - cum[None, :])
    g = jnp.where(tj <= ti, g * l_mat, 0.0)
    y_intra = jax.lax.dot_general(g, xc, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[...] = (y_inter + y_intra)[None].astype(y_ref.dtype)
    # state: st' = exp(cum_C) st + sum_j exp(cum_C - cum_j) xdt_j B_j^T
    k_dec = jnp.exp(cum[-1] - cum)                             # [C]
    new_st = (jnp.exp(cum[-1]) * st
              + jax.lax.dot_general(xc * k_dec[:, None], bc,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32))
    s_out_ref[...] = new_st[None]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(x, dt, a, b_in, c_in, d, state: Optional[jax.Array] = None, *,
               chunk: int = 128, interpret: bool = False
               ) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,H,P]; dt: [B,S,H]; a: [H] (negative); b,c: [B,S,N]; d: [H];
    state: [B,H,P,N] f32.  Returns (y [B,S,H,P], final_state)."""
    bb, s, h, p = x.shape
    n = b_in.shape[-1]
    if state is None:
        state = jnp.zeros((bb, h, p, n), jnp.float32)
    state = state.astype(jnp.float32)

    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    dtf = dt.astype(jnp.float32)
    xdt = (x.astype(jnp.float32) * dtf[..., None])             # [B,S,H,P]
    xdt = xdt.transpose(0, 2, 1, 3).reshape(bb * h, sp, p)
    la = (dtf * a.astype(jnp.float32)[None, None, :])          # [B,S,H]
    la = la.transpose(0, 2, 1).reshape(bb * h, sp)
    st = state.reshape(bb * h, p, n)

    x_spec = pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0))
    la_spec = pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci))
    bc_spec = pl.BlockSpec((1, chunk, n), lambda bh, ci: (bh // h, ci, 0))
    state_spec = pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0))

    y, final_state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bb * h, nc),
        in_specs=[x_spec, la_spec, bc_spec, bc_spec, state_spec],
        out_specs=(x_spec, state_spec),
        out_shape=(jax.ShapeDtypeStruct((bb * h, sp, p), x.dtype),
                   jax.ShapeDtypeStruct((bb * h, p, n), jnp.float32)),
        input_output_aliases={4: 1},
        interpret=interpret,
    )(xdt, la, b_in, c_in, st)

    y = y.reshape(bb, h, sp, p).transpose(0, 2, 1, 3)[:, :s]
    y = y + (d.astype(jnp.float32)[None, None, :, None]
             * x.astype(jnp.float32)[:, :s]).astype(y.dtype)
    return y, final_state.reshape(bb, h, p, n)
