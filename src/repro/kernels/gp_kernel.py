"""Tiled GP covariance-matrix assembly (RBF / Matérn-5/2) in Pallas.

The paper's GP surrogate spends its dense-algebra time in K(X,X) assembly
(O(N^2 d)) and the Cholesky solve; the assembly is the tileable part.  The
kernel computes one [bn, bm] output tile per grid step from [bn, d] /
[bm, d] input tiles: squared distances via the MXU cross-term
(-2 x1 x2^T) plus VPU row norms, then the covariance nonlinearity — all
in VMEM, one HBM write per tile.  ARD lengthscale scaling is folded into
the inputs by the wrapper.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256


def _gp_kernel(x1_ref, x2_ref, o_ref, *, kind, n, m, block_n, block_m):
    i = pl.program_id(0)
    j = pl.program_id(1)
    x1 = x1_ref[...].astype(jnp.float32)                       # [bn, d]
    x2 = x2_ref[...].astype(jnp.float32)                       # [bm, d]
    cross = jax.lax.dot_general(x1, x2, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    n1 = jnp.sum(x1 * x1, axis=-1)
    n2 = jnp.sum(x2 * x2, axis=-1)
    d2 = jnp.maximum(n1[:, None] + n2[None, :] - 2.0 * cross, 0.0)
    if kind == "rbf":
        k = jnp.exp(-0.5 * d2)
    else:  # matern52
        r = jnp.sqrt(d2 + 1e-12)
        k = (1.0 + math.sqrt(5.0) * r + 5.0 / 3.0 * d2) * jnp.exp(
            -math.sqrt(5.0) * r)
    # zero padded rows/cols so downstream reductions stay exact
    rows = i * block_n + jax.lax.iota(jnp.int32, block_n)
    cols = j * block_m + jax.lax.iota(jnp.int32, block_m)
    valid = (rows < n)[:, None] & (cols < m)[None, :]
    o_ref[...] = jnp.where(valid, k, 0.0)


@functools.partial(jax.jit, static_argnames=("kind", "block_n", "block_m",
                                             "interpret"))
def gp_kernel_matrix(x1, x2, lengthscale, variance, kind: str = "rbf", *,
                     block_n: int = DEFAULT_BLOCK, block_m: int = DEFAULT_BLOCK,
                     interpret: bool = False) -> jax.Array:
    """x1: [N,D]; x2: [M,D]; ARD lengthscale: [D] -> K [N,M] f32."""
    assert kind in ("rbf", "matern52"), kind
    n, d = x1.shape
    m = x2.shape[0]
    x1s = x1.astype(jnp.float32) / lengthscale.astype(jnp.float32)
    x2s = x2.astype(jnp.float32) / lengthscale.astype(jnp.float32)

    bn = min(block_n, max(n, 8))
    bm = min(block_m, max(m, 8))
    pn, pm = (-n) % bn, (-m) % bm
    if pn:
        x1s = jnp.pad(x1s, ((0, pn), (0, 0)))
    if pm:
        x2s = jnp.pad(x2s, ((0, pm), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_gp_kernel, kind=kind, n=n, m=m,
                          block_n=bn, block_m=bm),
        grid=((n + pn) // bn, (m + pm) // bm),
        in_specs=[pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((bm, d), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n + pn, m + pm), jnp.float32),
        interpret=interpret,
    )(x1s, x2s)
    return variance.astype(jnp.float32) * out[:n, :m]


def _gp_predict_kernel(x1_ref, x2_ref, alpha_ref, linv_ref, mean_ref,
                       qf_ref, *, kind):
    """One [bs]-query tile of the batched posterior predict: assemble the
    cross-covariance column block, then the MXU products against alpha
    (mean) and L^-1 (posterior-variance quadratic form) — the whole
    predict for this tile in one VMEM round-trip."""
    x1 = x1_ref[...].astype(jnp.float32)                       # [n, d]
    x2 = x2_ref[...].astype(jnp.float32)                       # [bs, d]
    cross = jax.lax.dot_general(x1, x2, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    n1 = jnp.sum(x1 * x1, axis=-1)
    n2 = jnp.sum(x2 * x2, axis=-1)
    d2 = jnp.maximum(n1[:, None] + n2[None, :] - 2.0 * cross, 0.0)
    if kind == "rbf":
        k = jnp.exp(-0.5 * d2)                                 # [n, bs]
    else:  # matern52
        r = jnp.sqrt(d2 + 1e-12)
        k = (1.0 + math.sqrt(5.0) * r + 5.0 / 3.0 * d2) * jnp.exp(
            -math.sqrt(5.0) * r)
    alpha = alpha_ref[...].astype(jnp.float32)                 # [n, m]
    mean_ref[...] = jax.lax.dot_general(
        k, alpha, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # [bs, m]
    linv = linv_ref[...].astype(jnp.float32)                   # [n, n]
    w = jax.lax.dot_general(linv, k, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    qf_ref[...] = jnp.sum(w * w, axis=0)[:, None]              # [bs, 1]


@functools.partial(jax.jit, static_argnames=("kind", "block_s", "interpret"))
def gp_predict(x_train, x_star, lengthscale, variance, alpha, linv,
               kind: str = "rbf", *, block_s: int = DEFAULT_BLOCK,
               interpret: bool = False):
    """Batched GP posterior predict in ONE kernel launch.

    x_train: [N, D]; x_star: [S, D]; alpha: [N, M]; linv: [N, N] (inverse
    Cholesky factor of K + s2 I)
    -> (normalised mean [S, M], quadratic form ||L^-1 ks||^2 [S]).

    The covariance nonlinearity commutes with the signal variance, so the
    kernel works on the unscaled correlation k0 and the wrapper applies
    `variance` (mean) and `variance^2` (quadratic form) afterwards —
    keeping the traced scalar out of the kernel body.  Padded query rows
    produce garbage that is sliced off; padded TRAINING rows are exact
    because alpha and linv are zero there.
    """
    assert kind in ("rbf", "matern52"), kind
    n, d = x_train.shape
    s = x_star.shape[0]
    m_out = alpha.shape[1]
    x1s = x_train.astype(jnp.float32) / lengthscale.astype(jnp.float32)
    x2s = x_star.astype(jnp.float32) / lengthscale.astype(jnp.float32)

    pn = (-n) % 8                                  # sublane-align the train dim
    if pn:
        x1s = jnp.pad(x1s, ((0, pn), (0, 0)))
        alpha = jnp.pad(alpha, ((0, pn), (0, 0)))
        linv = jnp.pad(linv, ((0, pn), (0, pn)))
    bs = min(block_s, max(s, 8))
    ps = (-s) % bs
    if ps:
        x2s = jnp.pad(x2s, ((0, ps), (0, 0)))

    mean0, qf0 = pl.pallas_call(
        functools.partial(_gp_predict_kernel, kind=kind),
        grid=((s + ps) // bs,),
        in_specs=[pl.BlockSpec((n + pn, d), lambda j: (0, 0)),
                  pl.BlockSpec((bs, d), lambda j: (j, 0)),
                  pl.BlockSpec((n + pn, m_out), lambda j: (0, 0)),
                  pl.BlockSpec((n + pn, n + pn), lambda j: (0, 0))],
        out_specs=(pl.BlockSpec((bs, m_out), lambda j: (j, 0)),
                   pl.BlockSpec((bs, 1), lambda j: (j, 0))),
        out_shape=(jax.ShapeDtypeStruct((s + ps, m_out), jnp.float32),
                   jax.ShapeDtypeStruct((s + ps, 1), jnp.float32)),
        interpret=interpret,
    )(x1s, x2s, alpha.astype(jnp.float32), linv.astype(jnp.float32))
    var_f = variance.astype(jnp.float32)
    return var_f * mean0[:s], (var_f * var_f) * qf0[:s, 0]


def _gp_predict_experts_kernel(x1_ref, x2_ref, alpha_ref, linv_ref,
                               mean_ref, qf_ref, *, kind):
    """One (expert, query-tile) grid step of the ensemble predict: the
    same fused cross-covariance + alpha + ||L^-1 ks||^2 body as
    `_gp_predict_kernel`, with every operand carrying a size-1 leading
    expert block — E experts answer their routed queries in ONE launch
    instead of E."""
    x1 = x1_ref[0].astype(jnp.float32)                         # [n, d]
    x2 = x2_ref[0].astype(jnp.float32)                         # [bs, d]
    cross = jax.lax.dot_general(x1, x2, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    n1 = jnp.sum(x1 * x1, axis=-1)
    n2 = jnp.sum(x2 * x2, axis=-1)
    d2 = jnp.maximum(n1[:, None] + n2[None, :] - 2.0 * cross, 0.0)
    if kind == "rbf":
        k = jnp.exp(-0.5 * d2)                                 # [n, bs]
    else:  # matern52
        r = jnp.sqrt(d2 + 1e-12)
        k = (1.0 + math.sqrt(5.0) * r + 5.0 / 3.0 * d2) * jnp.exp(
            -math.sqrt(5.0) * r)
    alpha = alpha_ref[0].astype(jnp.float32)                   # [n, m]
    mean_ref[0] = jax.lax.dot_general(
        k, alpha, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # [bs, m]
    linv = linv_ref[0].astype(jnp.float32)                     # [n, n]
    w = jax.lax.dot_general(linv, k, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    qf_ref[0] = jnp.sum(w * w, axis=0)[:, None]                # [bs, 1]


@functools.partial(jax.jit, static_argnames=("kind", "block_s", "interpret"))
def gp_predict_experts(x_train, x_star, lengthscale, variance, alpha, linv,
                       kind: str = "rbf", *, block_s: int = DEFAULT_BLOCK,
                       interpret: bool = False):
    """Stacked local-GP ensemble predict in ONE kernel launch.

    x_train: [E, N, D]; x_star: [E, S, D] (each expert's routed queries,
    zero-padded to a common width); alpha: [E, N, M]; linv: [E, N, N];
    shared hyperparameters -> (mean [E, S, M], quadratic form [E, S]).

    Grid is (E, S // bs): expert e never reads expert e2's operands, so
    the launch shards trivially over the expert axis on a multi-device
    mesh.  Padded TRAINING rows are exact (alpha and linv zero there,
    identical to `gp_predict`); padded query rows produce garbage the
    caller scatters away.
    """
    assert kind in ("rbf", "matern52"), kind
    e, n, d = x_train.shape
    s = x_star.shape[1]
    m_out = alpha.shape[2]
    ls = lengthscale.astype(jnp.float32)
    x1s = x_train.astype(jnp.float32) / ls
    x2s = x_star.astype(jnp.float32) / ls

    pn = (-n) % 8                                  # sublane-align the train dim
    if pn:
        x1s = jnp.pad(x1s, ((0, 0), (0, pn), (0, 0)))
        alpha = jnp.pad(alpha, ((0, 0), (0, pn), (0, 0)))
        linv = jnp.pad(linv, ((0, 0), (0, pn), (0, pn)))
    bs = min(block_s, max(s, 8))
    ps = (-s) % bs
    if ps:
        x2s = jnp.pad(x2s, ((0, 0), (0, ps), (0, 0)))

    mean0, qf0 = pl.pallas_call(
        functools.partial(_gp_predict_experts_kernel, kind=kind),
        grid=(e, (s + ps) // bs),
        in_specs=[pl.BlockSpec((1, n + pn, d), lambda ei, j: (ei, 0, 0)),
                  pl.BlockSpec((1, bs, d), lambda ei, j: (ei, j, 0)),
                  pl.BlockSpec((1, n + pn, m_out), lambda ei, j: (ei, 0, 0)),
                  pl.BlockSpec((1, n + pn, n + pn),
                               lambda ei, j: (ei, 0, 0))],
        out_specs=(pl.BlockSpec((1, bs, m_out), lambda ei, j: (ei, j, 0)),
                   pl.BlockSpec((1, bs, 1), lambda ei, j: (ei, j, 0))),
        out_shape=(jax.ShapeDtypeStruct((e, s + ps, m_out), jnp.float32),
                   jax.ShapeDtypeStruct((e, s + ps, 1), jnp.float32)),
        interpret=interpret,
    )(x1s, x2s, alpha.astype(jnp.float32), linv.astype(jnp.float32))
    var_f = variance.astype(jnp.float32)
    return var_f * mean0[:, :s], (var_f * var_f) * qf0[:, :s, 0]
