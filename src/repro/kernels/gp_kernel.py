"""Tiled GP covariance-matrix assembly (RBF / Matérn-5/2) in Pallas.

The paper's GP surrogate spends its dense-algebra time in K(X,X) assembly
(O(N^2 d)) and the Cholesky solve; the assembly is the tileable part.  The
kernel computes one [bn, bm] output tile per grid step from [bn, d] /
[bm, d] input tiles: squared distances via the MXU cross-term
(-2 x1 x2^T) plus VPU row norms, then the covariance nonlinearity — all
in VMEM, one HBM write per tile.  ARD lengthscale scaling is folded into
the inputs by the wrapper.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256


def _gp_kernel(x1_ref, x2_ref, o_ref, *, kind, n, m, block_n, block_m):
    i = pl.program_id(0)
    j = pl.program_id(1)
    x1 = x1_ref[...].astype(jnp.float32)                       # [bn, d]
    x2 = x2_ref[...].astype(jnp.float32)                       # [bm, d]
    cross = jax.lax.dot_general(x1, x2, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    n1 = jnp.sum(x1 * x1, axis=-1)
    n2 = jnp.sum(x2 * x2, axis=-1)
    d2 = jnp.maximum(n1[:, None] + n2[None, :] - 2.0 * cross, 0.0)
    if kind == "rbf":
        k = jnp.exp(-0.5 * d2)
    else:  # matern52
        r = jnp.sqrt(d2 + 1e-12)
        k = (1.0 + math.sqrt(5.0) * r + 5.0 / 3.0 * d2) * jnp.exp(
            -math.sqrt(5.0) * r)
    # zero padded rows/cols so downstream reductions stay exact
    rows = i * block_n + jax.lax.iota(jnp.int32, block_n)
    cols = j * block_m + jax.lax.iota(jnp.int32, block_m)
    valid = (rows < n)[:, None] & (cols < m)[None, :]
    o_ref[...] = jnp.where(valid, k, 0.0)


@functools.partial(jax.jit, static_argnames=("kind", "block_n", "block_m",
                                             "interpret"))
def gp_kernel_matrix(x1, x2, lengthscale, variance, kind: str = "rbf", *,
                     block_n: int = DEFAULT_BLOCK, block_m: int = DEFAULT_BLOCK,
                     interpret: bool = False) -> jax.Array:
    """x1: [N,D]; x2: [M,D]; ARD lengthscale: [D] -> K [N,M] f32."""
    assert kind in ("rbf", "matern52"), kind
    n, d = x1.shape
    m = x2.shape[0]
    x1s = x1.astype(jnp.float32) / lengthscale.astype(jnp.float32)
    x2s = x2.astype(jnp.float32) / lengthscale.astype(jnp.float32)

    bn = min(block_n, max(n, 8))
    bm = min(block_m, max(m, 8))
    pn, pm = (-n) % bn, (-m) % bm
    if pn:
        x1s = jnp.pad(x1s, ((0, pn), (0, 0)))
    if pm:
        x2s = jnp.pad(x2s, ((0, pm), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_gp_kernel, kind=kind, n=n, m=m,
                          block_n=bn, block_m=bm),
        grid=((n + pn) // bn, (m + pm) // bm),
        in_specs=[pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((bm, d), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n + pn, m + pm), jnp.float32),
        interpret=interpret,
    )(x1s, x2s)
    return variance.astype(jnp.float32) * out[:n, :m]
