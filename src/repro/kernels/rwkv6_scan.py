"""RWKV6 (Finch) WKV recurrence as a chunked Pallas TPU kernel.

TPU adaptation: the token-recurrent WKV update is reformulated as chunked
gated linear attention (the same math as ref.rwkv6_wkv_chunked) so each
grid step does three MXU matmuls ([C,K]@[K,V], [C,K]@[K,C], [C,C]@[C,V])
instead of S sequential rank-1 updates.  The grid is (B*H, n_chunks) with
TPU's sequential grid traversal carrying the [K,V] state in an
input/output-aliased ref: chunk ci reads the state left by chunk ci-1 —
no HBM round-trip between chunks beyond the aliased buffer.

The diagonal "bonus" term (u) has no state dependence and is added by the
wrapper outside the kernel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, s_in_ref, out_ref, s_out_ref,
                *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_out_ref[...] = s_in_ref[...]

    st = s_out_ref[...][0].astype(jnp.float32)                 # [K,V]
    rc = r_ref[...][0].astype(jnp.float32)                     # [C,K]
    kc = k_ref[...][0].astype(jnp.float32)
    vc = v_ref[...][0].astype(jnp.float32)                     # [C,V]
    wc = w_ref[...][0].astype(jnp.float32)

    logw = jnp.log(jnp.maximum(wc, 1e-30))
    cum = jnp.cumsum(logw, axis=0)                             # [C,K]
    dec_in = jnp.exp(cum - logw)                               # prod w_1..w_{t-1}
    r_dec = rc * dec_in
    out_inter = jax.lax.dot_general(r_dec, st, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    k_dec = kc * jnp.exp(-cum)
    a = jax.lax.dot_general(r_dec, k_dec, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [C,C]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(tj < ti, a, 0.0)                             # strict lower tri
    out_intra = jax.lax.dot_general(a, vc, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    out_ref[...] = (out_inter + out_intra)[None].astype(out_ref.dtype)

    dec_all = jnp.exp(cum[-1])                                 # [K]
    k_out = kc * jnp.exp(cum[-1][None] - cum)                  # [C,K]
    new_st = (dec_all[:, None] * st
              + jax.lax.dot_general(k_out, vc, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32))
    s_out_ref[...] = new_st[None]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r, k, v, w, u, state: Optional[jax.Array] = None, *,
              chunk: int = 64, interpret: bool = False
              ) -> Tuple[jax.Array, jax.Array]:
    """r,k,w: [B,S,H,K]; v: [B,S,H,V]; u: [H,K]; state: [B,H,K,V] f32.
    Returns (out [B,S,H,V], final_state [B,H,K,V])."""
    b, s, h, kd = r.shape
    vd = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, kd, vd), jnp.float32)
    state = state.astype(jnp.float32)

    pad = (-s) % chunk
    padw = ((0, 0), (0, pad), (0, 0), (0, 0))
    if pad:
        r = jnp.pad(r, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        w = jnp.pad(w, padw, constant_values=1.0)
    sp = s + pad
    n = sp // chunk

    def fold(x):                                               # [B,S,H,E]->[BH,S,E]
        return x.transpose(0, 2, 1, 3).reshape(b * h, sp, x.shape[-1])

    rt, kt, vt, wt = fold(r), fold(k), fold(v), fold(w)
    st = state.reshape(b * h, kd, vd)

    seq_spec = lambda e: pl.BlockSpec((1, chunk, e), lambda bh, ci: (bh, ci, 0))
    state_spec = pl.BlockSpec((1, kd, vd), lambda bh, ci: (bh, 0, 0))

    out, final_state = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(b * h, n),
        in_specs=[seq_spec(kd), seq_spec(kd), seq_spec(vd), seq_spec(kd),
                  state_spec],
        out_specs=(seq_spec(vd), state_spec),
        out_shape=(jax.ShapeDtypeStruct((b * h, sp, vd), r.dtype),
                   jax.ShapeDtypeStruct((b * h, kd, vd), jnp.float32)),
        input_output_aliases={4: 1},
        interpret=interpret,
    )(rt, kt, vt, wt, st)

    out = out.reshape(b, h, sp, vd).transpose(0, 2, 1, 3)[:, :s]
    # diagonal bonus: r_t . (u * k_t) v_t  (stateless; done outside the kernel)
    diag = jnp.einsum("bshk,hk,bshk->bsh", r.astype(jnp.float32)[:, :s],
                      u.astype(jnp.float32), k.astype(jnp.float32)[:, :s])
    out = out + (diag[..., None] * v.astype(jnp.float32)[:, :s]).astype(out.dtype)
    return out, final_state.reshape(b, h, kd, vd)
