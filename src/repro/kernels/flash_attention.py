"""Causal GQA flash attention as a Pallas TPU kernel.

TPU-native adaptation (not a CUDA port): the online-softmax blocking is
expressed as a 2-D grid over (batch*heads, q_blocks) with an inner
fori_loop over KV blocks; BlockSpecs stage q/k/v tiles HBM->VMEM sized to
MXU-aligned (block_q x head_dim) / (block_kv x head_dim) tiles, so the
working set is O(block^2) VMEM and matmul dims are multiples of 128 for
head_dim>=128 (dh 64 still maps onto half-lane tiles).  GQA is handled by
indexing the kv head map in the BlockSpec index fn — no jnp.repeat
materialisation of K/V.

Causal skipping: KV blocks strictly above the diagonal are never read
(the fori_loop upper bound is derived from the q block index), which
halves both FLOPs and HBM traffic for causal prefill.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_KV = 256
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, sq, skv, block_q, block_kv,
                 causal, scale):
    qi = pl.program_id(1)
    q = q_ref[...][0].astype(jnp.float32) * scale            # [bq, dh]
    bq, dh = q.shape
    dv = v_ref.shape[-1]

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, bq)        # global q rows
    nkv = pl.cdiv(skv, block_kv)
    if causal:
        # highest kv block this q block can see (diag offset skv - sq)
        q_off = skv - sq
        last = (qi * block_q + block_q - 1 + q_off) // block_kv
        nkv_used = jnp.minimum(nkv, last + 1)
    else:
        nkv_used = nkv

    def body(ki, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(ki * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (0, pl.dslice(ki * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = ki * block_kv + jax.lax.iota(jnp.int32, block_kv)
        valid = (k_pos < skv)[None, :]
        if causal:
            valid = valid & (k_pos[None, :] <= q_pos[:, None] + (skv - sq))
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None]) * valid
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    init = (jnp.full((bq,), NEG_INF, jnp.float32),
            jnp.zeros((bq,), jnp.float32),
            jnp.zeros((bq, dv), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, nkv_used, body, init)
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out[None].astype(o_ref.dtype)


def _pad_to(x, axis, mult):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_kv: int = DEFAULT_BLOCK_KV,
                    interpret: bool = False):
    """q: [B,Sq,H,Dh]; k/v: [B,Skv,Hkv,Dh(v)] -> [B,Sq,H,Dv].

    Forward-only kernel (decode/prefill serving path); the training path
    uses the custom-VJP chunked fallback in ref.py.
    """
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    scale = 1.0 / math.sqrt(dh)

    block_q = min(block_q, max(sq, 16))
    block_kv = min(block_kv, max(skv, 16))
    qp = _pad_to(q, 1, block_q)
    kp = _pad_to(k, 1, block_kv)
    vp = _pad_to(v, 1, block_kv)
    sq_p, skv_p = qp.shape[1], kp.shape[1]

    # layout: fold heads into the grid; kernel sees [1, S, Dh] tiles
    qt = qp.transpose(0, 2, 1, 3).reshape(b * h, sq_p, dh)
    kt = kp.transpose(0, 2, 1, 3).reshape(b * hkv, skv_p, dh)
    vt = vp.transpose(0, 2, 1, 3).reshape(b * hkv, skv_p, dv)

    grid = (b * h, sq_p // block_q)

    def q_index(bh, qi):
        return (bh, qi, 0)

    def kv_index(bh, qi):
        return (bh // group, 0, 0)

    out = pl.pallas_call(
        functools.partial(_attn_kernel, sq=sq, skv=skv, block_q=block_q,
                          block_kv=block_kv, causal=causal, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), q_index),
            pl.BlockSpec((1, skv_p, dh), kv_index),
            pl.BlockSpec((1, skv_p, dv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), q_index),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, dv), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)

    out = out.reshape(b, h, sq_p, dv).transpose(0, 2, 1, 3)
    return out[:, :sq]
