"""Substrate tests: checkpointing, data pipeline, optimizer, sharding rules,
gradient compression, end-to-end training behaviour."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import MemmapCorpus, SyntheticLM, host_shard
from repro.models import sharding
from repro.optim import (AdamWConfig, adamw_update, compress_with_feedback,
                         cosine_schedule, init_compression_state,
                         init_opt_state)


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------
def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32),
                  "d": jnp.full((2, 2), 0.5, jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_pytree(tmp_path / "x.npz", t, step=7)
    got, meta = load_pytree(tmp_path / "x.npz", t)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_manager_retention_and_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=True)
    t = _tree()
    for s in (1, 2, 3, 4):
        t = jax.tree.map(lambda x: x + 1, t)
        mgr.save(s, t)
    mgr.wait()
    files = sorted(p.name for p in tmp_path.iterdir())
    assert files == ["step_00000003.npz", "step_00000004.npz"]
    got, meta = mgr.restore_latest(t)
    assert meta["step"] == 4
    np.testing.assert_allclose(np.asarray(got["a"], np.float32),
                               np.asarray(t["a"], np.float32))


def test_checkpoint_resharding_restore(tmp_path):
    """Restore must accept a different sharding layout than was saved
    (elastic restart across mesh shapes)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(8.0).reshape(2, 4)}
    save_pytree(tmp_path / "x.npz", t, step=0)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = load_pytree(tmp_path / "x.npz", t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
    assert got["w"].sharding == sh["w"]


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------
def test_synthetic_deterministic_and_structured():
    pipe = SyntheticLM(vocab_size=97, seq_len=32, global_batch=4, seed=1)
    a, b = pipe.batch(5), pipe.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(pipe.batch(6)["tokens"], a["tokens"])
    # structure: most transitions follow the affine rule
    t = a["tokens"].astype(np.int64)
    follows = (t[:, 1:] == (t[:, :-1] * (6364136223846793005 % 97) + 7) % 97)
    assert follows.mean() > 0.8


def test_host_shard_partition():
    slices = [host_shard(64, i, 4) for i in range(4)]
    assert [s[1] for s in slices] == [16] * 4
    assert sorted(o for o, _ in slices) == [0, 16, 32, 48]


def test_memmap_corpus(tmp_path):
    p = tmp_path / "corpus.bin"
    MemmapCorpus.build_demo(p, vocab_size=50, n_tokens=4096, seed=0)
    pipe = MemmapCorpus(p, vocab_size=50, seq_len=16, global_batch=2)
    b = pipe.batch(0)
    assert b["tokens"].shape == (2, 16)
    assert b["tokens"].max() < 50
    np.testing.assert_array_equal(b["tokens"], pipe.batch(0)["tokens"])


def test_embeddings_mode():
    pipe = SyntheticLM(vocab_size=97, seq_len=8, global_batch=2, seed=0,
                       embeddings_dim=16)
    b = pipe.batch(0)
    assert b["embeddings"].shape == (2, 8, 16)
    assert b["labels"].shape == (2, 8)


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------
def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 0.05


def test_cosine_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10,
                      total_steps=100)
    lrs = [float(cosine_schedule(jnp.asarray(s), cfg)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6
    assert abs(lrs[10] - 1.0) < 0.01
    assert lrs[-1] == pytest.approx(0.1, abs=0.01)


def test_bf16_moments_dtype():
    cfg = AdamWConfig(moments_dtype="bfloat16")
    params = {"w": jnp.ones((4,))}
    opt = init_opt_state(params, cfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4,))}
    _, opt2, _ = adamw_update(params, g, opt, cfg)
    assert opt2["v"]["w"].dtype == jnp.bfloat16


# --------------------------------------------------------------------------
# gradient compression (error feedback)
# --------------------------------------------------------------------------
def test_compression_error_feedback_invariant():
    """decompressed + error == original + previous error (exactly, in f32)."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(1000), jnp.float32)}
    err0 = init_compression_state(g)
    deq, err = compress_with_feedback(g, err0)
    np.testing.assert_allclose(np.asarray(deq["w"]) + np.asarray(err["w"]),
                               np.asarray(g["w"]), atol=1e-6)
    # error is bounded by one quant step per block
    scale = np.abs(np.asarray(g["w"])).reshape(-1, 250).max()  # loose bound
    assert np.abs(np.asarray(err["w"])).max() <= scale / 127 + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(10, 600))
def test_compression_roundtrip_accumulates_correctly(seed, n):
    """Error feedback: sum of decompressed grads converges to sum of true
    grads (bias cancels across steps)."""
    rng = np.random.default_rng(seed)
    true = jnp.asarray(rng.standard_normal(n), jnp.float32)
    g = {"w": true}
    err = init_compression_state(g)
    total = np.zeros(n)
    for _ in range(20):
        deq, err = compress_with_feedback(g, err)
        total += np.asarray(deq["w"])
    np.testing.assert_allclose(total / 20, np.asarray(true),
                               atol=np.abs(true).max() / 127 + 1e-5)


# --------------------------------------------------------------------------
# sharding rules
# --------------------------------------------------------------------------
def _mesh16():
    import os
    devs = jax.devices()
    if len(devs) >= 4:
        return jax.make_mesh((2, 2), ("data", "model"))
    return None


def test_spec_for_divisibility_fallback():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    # head dim 56 not divisible by ... (size 1 always divides; use rules
    # logic directly with a fake mesh shape via spec_for arguments)
    spec = sharding.spec_for((128, 1024), ("embed", "mlp"), mesh,
                             fsdp_axes=("data",))
    assert isinstance(spec, P)


def test_spec_for_never_reuses_axis():
    mesh = jax.make_mesh((1,), ("model",))
    spec = sharding.spec_for((64, 64), ("mlp", "mlp"), mesh)
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat += list(s) if isinstance(s, tuple) else [s]
    assert len(flat) == len(set(flat))


def test_train_loss_decreases_end_to_end(tmp_path):
    """(b) end-to-end driver sanity: a reduced model trains and improves."""
    from repro.launch.train import train
    out = train("starcoder2-3b", reduced=True, steps=40, batch=8, seq=64,
                ckpt_dir=str(tmp_path / "ck"), ckpt_every=20, log_every=100)
    first5 = np.mean(out["losses"][:5])
    last5 = np.mean(out["losses"][-5:])
    assert last5 < first5 - 0.02, (first5, last5)


def test_accumulation_matches_single_batch():
    """accum_steps=2 over the same data must match accum_steps=1 closely."""
    from repro import configs
    from repro.launch.steps import make_train_step
    from repro.models import model as M
    from repro.optim import AdamWConfig, init_opt_state
    cfg1 = configs.get_reduced("qwen3-14b").replace(accum_steps=1)
    cfg2 = cfg1.replace(accum_steps=2)
    opt_cfg = AdamWConfig()
    params = M.init_params(cfg1, jax.random.PRNGKey(0))
    opt = init_opt_state(params, opt_cfg)
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg1.vocab_size, (4, 16)),
                                   jnp.int32)}
    p1, _, m1 = jax.jit(make_train_step(cfg1, opt_cfg))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg2, opt_cfg))(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(diff)) < 5e-3
