"""`repro.obs` unit + integration suite.

Unit coverage for the ring buffer, tracer span protocol, Chrome-trace
export/validation, histogram/registry mechanics, and the overhead
attribution math; integration coverage for the claim the module exists
to make: traced sim runs decompose `TaskRecord.overhead` EXACTLY into
queue-wait + alloc-wait + dispatch + retry, and the registry samples a
coherent per-tick timeseries.  (The sim/live span-sequence parity test
lives with the rest of the differential suite in `tests/test_parity.py`.)
"""
import json
import math

import pytest

from repro.cluster import (AutoAllocConfig, bursty_trace, simulate_cluster)
from repro.core import backends
from repro.obs import (DEFAULT_EDGES, Histogram, MetricsRegistry,
                       RingBuffer, Tracer, attribute_overhead,
                       capacity_intervals, format_breakdown,
                       span_sequence, validate_chrome_trace)


# --------------------------------------------------------------------------
# RingBuffer
# --------------------------------------------------------------------------
def test_ringbuffer_bounds_and_drop_accounting():
    rb = RingBuffer(capacity=4)
    for i in range(10):
        rb.append(i)
    assert len(rb) == 4
    assert list(rb) == [6, 7, 8, 9]           # oldest dropped first
    assert rb.n_seen == 10
    assert rb.n_dropped == 6
    assert rb[0] == 6 and rb[-1] == 9
    rb.clear()
    assert len(rb) == 0 and rb.n_dropped == 0


# --------------------------------------------------------------------------
# Tracer span protocol
# --------------------------------------------------------------------------
def test_tracer_task_attempt_spans():
    tr = Tracer()
    tr.task_queued("t0", 1, ts=0.0)
    tr.task_attempt("t0", alloc_id=2, wid=5, mark_t=3.0, start_t=3.5,
                    init_t=2.0, end_t=10.0, attempt=1, status="ok")
    by_name = {}
    for ev in tr.events():
        by_name.setdefault(ev[2], []).append(ev)
    q = by_name["task.queued"]
    # the instant at enqueue plus the closed X span
    assert [e[1] for e in q] == ["i", "X"]
    assert q[1][0] == 0.0 and q[1][5] == 3.0         # [0, mark]
    d = by_name["task.dispatch"][0]
    assert d[0] == 3.0 and d[5] == pytest.approx(0.5)
    init = by_name["task.init"][0]
    assert init[0] == 3.5 and init[5] == 2.0
    assert init[3] == 3 and init[4] == 5             # pid=alloc+1, tid=wid
    run = by_name["task.run"][0]
    assert run[0] == 5.5 and run[5] == pytest.approx(4.5)
    assert by_name["task.ok"][0][0] == 10.0


def test_tracer_requeue_closes_queued_span_at_dispatch_mark():
    tr = Tracer()
    tr.task_queued("t0", 1, ts=0.0)
    tr.task_requeue("t0", 1, now=50.0, since=10.0)
    spans = [e for e in tr.events() if e[1] == "X" and e[2] == "task.queued"]
    assert len(spans) == 1
    assert spans[0][0] == 0.0 and spans[0][5] == 10.0   # closed at `since`
    inst = [e for e in tr.events() if e[2] == "task.requeue"][0]
    assert inst[0] == 50.0 and inst[6]["since"] == 10.0


def test_tracer_lost_closes_all_pending_queue_entries():
    tr = Tracer()
    tr.task_queued("t0", 1, ts=0.0)
    tr.task_queued("t0", 2, ts=5.0)
    tr.task_lost("t0", now=20.0)
    spans = [e for e in tr.events() if e[1] == "X"]
    assert sorted((s[0], s[0] + s[5]) for s in spans) == \
        [(0.0, 20.0), (5.0, 20.0)]
    assert any(e[2] == "task.lost" for e in tr.events())


def test_tracer_ring_buffer_drops_oldest_events():
    tr = Tracer(capacity=8)
    for i in range(20):
        tr.instant("tick", ts=float(i))
    assert len(tr.events()) == 8
    assert tr.n_dropped == 12
    assert tr.events()[0][0] == 12.0


class _FakeAlloc:
    def __init__(self, aid, submit_t, ready_t, end_t, state,
                 virtual=False):
        self.alloc_id = aid
        self.submit_t = submit_t
        self.ready_t = ready_t
        self.end_t = end_t
        self.state = state
        self.virtual = virtual


def test_alloc_state_backfills_history_and_dedups():
    tr = Tracer()
    a = _FakeAlloc(3, submit_t=1.0, ready_t=4.0, end_t=None,
                   state="running")
    tr.alloc_state(a)            # backfills queued -> running
    tr.alloc_state(a)            # same state: no-op
    evs = tr.events()
    names = [(e[1], e[2]) for e in evs]
    assert names == [("B", "alloc.queued"), ("E", "alloc.queued"),
                     ("B", "alloc.running")]
    assert evs[0][0] == 1.0 and evs[1][0] == 4.0 and evs[2][0] == 4.0
    a.state, a.end_t = "expired", 9.0
    tr.alloc_state(a, ts=9.0)
    tail = tr.events()[-2:]
    # direct RUNNING -> EXPIRED: no synthetic draining span in between
    assert [(e[1], e[2]) for e in tail] == [("E", "alloc.running"),
                                            ("i", "alloc.expired")]


# --------------------------------------------------------------------------
# Chrome export + validator
# --------------------------------------------------------------------------
def test_chrome_export_schema_and_validator(tmp_path):
    tr = Tracer()
    a = _FakeAlloc(0, submit_t=0.0, ready_t=0.0, end_t=None,
                   state="running")
    tr.alloc_state(a)
    tr.task_queued("t0", 1, ts=0.0)
    tr.task_attempt("t0", 0, 0, 1.0, 1.1, 0.5, 4.0, 1, "ok")
    obj = tr.to_chrome()
    assert validate_chrome_trace(obj) == []
    # zero-length B/E pair at ts=0 must stay correctly nested
    assert obj["traceEvents"][0]["ph"] == "M"
    path = tmp_path / "trace.json"
    tr.write_chrome(str(path))
    assert validate_chrome_trace(json.loads(path.read_text())) == []
    jl = tmp_path / "trace.jsonl"
    tr.write_jsonl(str(jl))
    rows = [json.loads(line) for line in jl.read_text().splitlines()]
    assert len(rows) == len(tr.events())
    assert all("ts" in r and "ph" in r and "name" in r for r in rows)


def test_validator_flags_malformed_traces():
    bad = {"traceEvents": [
        {"name": "x", "ph": "Q", "ts": 0, "pid": 0, "tid": 0},
        {"name": "y", "ph": "X", "ts": float("nan"), "pid": 0, "tid": 0},
        {"name": "z", "ph": "X", "ts": 5.0, "dur": -1.0, "pid": 0,
         "tid": 0},
        {"name": "w", "ph": "i", "ts": 1.0, "pid": 0, "tid": 0},
        {"name": "v", "ph": "E", "ts": 6.0, "pid": 0, "tid": 0},
    ]}
    probs = validate_chrome_trace(bad)
    assert any("unknown phase" in p for p in probs)
    assert any("bad ts" in p for p in probs)
    assert any("bad X dur" in p for p in probs)
    assert any("non-monotone" in p for p in probs)
    assert any("E without open B" in p for p in probs)
    assert validate_chrome_trace({"nope": 1}) == ["no traceEvents list"]


def test_span_sequence_is_order_insensitive():
    t1, t2 = Tracer(), Tracer()
    t1.instant("a", ts=1.0)
    t1.instant("b", ts=1.0, args={"k": 2})
    t2.instant("b", ts=1.0, args={"k": 2})
    t2.instant("a", ts=1.0)
    assert span_sequence(t1) == span_sequence(t2)


# --------------------------------------------------------------------------
# Histogram + MetricsRegistry
# --------------------------------------------------------------------------
def test_histogram_bucketing_and_clamping():
    h = Histogram(edges=(0.0, 1.0, 2.0))
    for v in (-5.0, 0.5, 1.5, 99.0):
        h.observe(v)
    assert h.counts == [2, 2]     # underflow clamps low, overflow high
    assert h.n == 4
    assert h.mean == pytest.approx((-5.0 + 0.5 + 1.5 + 99.0) / 4)
    with pytest.raises(ValueError):
        Histogram(edges=(1.0,))


def test_registry_timeseries_alignment_and_nan_fill():
    reg = MetricsRegistry(max_samples=8)
    reg.set_gauge("depth", 3.0)
    reg.sample(0.0)
    reg.inc("pops")
    reg.observe("wait", 0.2)
    reg.set_gauge("depth", 1.0)
    reg.sample(1.0)
    ts = reg.timeseries()
    assert ts["t"] == [0.0, 1.0]
    assert ts["depth"] == [3.0, 1.0]
    assert math.isnan(ts["pops"][0]) and ts["pops"][1] == 1.0
    assert math.isnan(ts["wait_mean"][0])
    assert ts["wait_mean"][1] == pytest.approx(0.2)
    snap = reg.snapshot()
    assert snap["counters"] == {"pops": 1.0}
    assert snap["histograms"]["wait"]["n"] == 1
    assert snap["n_samples"] == 2


def test_registry_sample_buffer_is_bounded():
    reg = MetricsRegistry(max_samples=4)
    for i in range(10):
        reg.sample(float(i))
    assert reg.n_samples == 4
    assert reg.timeseries()["t"] == [6.0, 7.0, 8.0, 9.0]


# --------------------------------------------------------------------------
# overhead attribution
# --------------------------------------------------------------------------
def test_capacity_intervals_merge_and_ignore_virtual():
    events = [
        (0.0, "B", "alloc.running", 1, 0, 0.0, {"alloc": 0,
                                                "virtual": False}),
        (5.0, "E", "alloc.running", 1, 0, 0.0, None),
        (3.0, "B", "alloc.running", 2, 0, 0.0, {"alloc": 1,
                                                "virtual": False}),
        (8.0, "E", "alloc.running", 2, 0, 0.0, None),
        (0.0, "B", "alloc.running", 9, 0, 0.0, {"alloc": 8,
                                                "virtual": True}),
        (20.0, "B", "alloc.running", 3, 0, 0.0, {"alloc": 2,
                                                 "virtual": False}),
        (25.0, "i", "task.ok", 0, 0, 0.0, {"task": "t9"}),
    ]
    # [0,5] u [3,8] merge; virtual ignored; unclosed B runs to trace end
    assert capacity_intervals(events) == [(0.0, 8.0), (20.0, 25.0)]


def test_attribution_splits_queue_wait_by_capacity():
    events = [
        (0.0, "B", "alloc.running", 1, 0, 0.0, {"alloc": 0,
                                                "virtual": False}),
        (4.0, "E", "alloc.running", 1, 0, 0.0, None),
        # queued [2, 10]: capacity existed over [2, 4] only
        (2.0, "X", "task.queued", 0, 0, 8.0, {"task": "a", "attempt": 1}),
        (10.0, "X", "task.dispatch", 0, 0, 0.5, {"task": "a",
                                                 "attempt": 1}),
        (10.5, "X", "task.init", 2, 0, 1.5, {"task": "a", "attempt": 1}),
        (30.0, "i", "task.requeue", 0, 0, 0.0, {"task": "a",
                                                "attempt": 1,
                                                "since": 25.0}),
        (40.0, "i", "task.ok", 0, 0, 0.0, {"task": "a"}),
    ]
    out = attribute_overhead(events)
    bd = out["per_task"]["a"]
    assert bd.queue_wait_s == pytest.approx(2.0)
    assert bd.alloc_wait_s == pytest.approx(6.0)
    assert bd.dispatch_s == pytest.approx(0.5)
    assert bd.retry_s == pytest.approx(5.0)
    assert bd.init_s == pytest.approx(1.5)
    assert bd.status == "ok"
    # init is informational, not part of the overhead sum
    assert bd.overhead_s == pytest.approx(2.0 + 6.0 + 0.5 + 5.0)
    assert out["totals"]["overhead_s"] == pytest.approx(bd.overhead_s)
    text = format_breakdown(out)
    assert "queue_wait_s" in text and "not overhead" in text


def _kill_cfg(**kw):
    base = dict(workers_per_alloc=2, walltime_s=60.0, backlog_high_s=30.0,
                backlog_low_s=5.0, max_pending=2, max_allocations=4,
                min_allocations=0, idle_drain_s=20.0, hysteresis_s=5.0)
    base.update(kw)
    return AutoAllocConfig(**base)


def test_attribution_matches_task_record_overhead_exactly():
    """The headline contract: on a traced sim run (with retries from
    walltime kills), each per-task breakdown sums EXACTLY to the
    §IV-A `TaskRecord.overhead` scalar it decomposes."""
    spec = backends.get("hq")
    tr = Tracer()
    res = simulate_cluster(spec, bursty_trace(n_bursts=2, burst_size=10,
                                              seed=3),
                           autoalloc=_kill_cfg(), max_attempts=6, seed=3,
                           tracer=tr)
    att = res.overhead_attribution
    assert att is not None and att["n_tasks"] == len(res.records)
    rec_by = {r.task_id: r for r in res.records}
    assert any(r.attempts > 1 for r in res.records)   # retries exercised
    for tid, bd in att["per_task"].items():
        assert bd.overhead_s == pytest.approx(rec_by[tid].overhead,
                                              abs=1e-9), tid
    assert validate_chrome_trace(tr.to_chrome()) == []


def test_untraced_sim_has_no_attribution():
    spec = backends.get("hq")
    res = simulate_cluster(spec, bursty_trace(n_bursts=1, burst_size=4,
                                              seed=0))
    assert res.overhead_attribution is None
