"""repro.sched tests: registry resolution, policy ordering/invariants,
predictor convergence, and the deterministic simulator-vs-executor seam."""
import time

import numpy as np
import pytest

from repro.core import (EvalRequest, Executor, LambdaModel, LoadBalancer,
                        backends, metrics, simulate_policy)
from repro.core.simulator import Workload
from repro.sched import (FCFSPolicy, GPRuntimePredictor, PackingPolicy,
                         QuantileEstimator, SJFPolicy, WorkStealingPolicy,
                         WorkerView, make_policy, make_predictor)


def _req(cost=None, model="m", params=None, task_id=""):
    return EvalRequest(model, params if params is not None else [[0.0]],
                       time_request=cost, task_id=task_id)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
def test_registry_resolves_names():
    for name in ("fcfs", "sjf", "lpt", "pack", "steal"):
        assert make_policy(name).name == name
    assert isinstance(make_predictor("quantile"), QuantileEstimator)
    assert isinstance(make_predictor("gp"), GPRuntimePredictor)
    assert make_predictor("none") is None and make_predictor(None) is None


def test_registry_unknown_raises():
    with pytest.raises(KeyError):
        make_policy("nope")
    with pytest.raises(KeyError):
        make_predictor("nope")


def test_registry_instance_passthrough_binds_predictor():
    pol = SJFPolicy()
    pred = QuantileEstimator()
    assert make_policy(pol, pred) is pol
    assert pol.predictor is pred
    other = QuantileEstimator()
    make_policy(pol, other)                    # existing binding wins
    assert pol.predictor is pred


# --------------------------------------------------------------------------
# policy ordering
# --------------------------------------------------------------------------
def test_fcfs_preserves_arrival_order():
    p = FCFSPolicy()
    reqs = [_req(task_id=f"t{i}") for i in range(5)]
    for r in reqs:
        p.push(r, 1)
    assert [p.pop()[0].task_id for _ in range(5)] == [r.task_id for r in reqs]


def test_sjf_and_lpt_order_by_cost():
    for name, expected in (("sjf", [1.0, 3.0, 5.0]), ("lpt", [5.0, 3.0, 1.0])):
        p = make_policy(name)
        for c in (5.0, 1.0, 3.0):
            p.push(_req(cost=c), 1)
        assert [p.pop()[0].time_request for _ in range(3)] == expected


def test_cost_fallback_chain():
    p = SJFPolicy()
    assert p.cost(_req(cost=7.0)) == 7.0       # time_request hint
    assert p.cost(_req()) == 0.0               # nothing known
    pred = QuantileEstimator(min_observed=1)
    pred.observe(_req(), 2.0)
    p2 = SJFPolicy(predictor=pred)
    assert p2.cost(_req(cost=99.0)) == 2.0     # predictor beats the hint


def test_pack_respects_worker_budget():
    p = PackingPolicy(init_margin=0.0)
    for c in (50.0, 10.0, 30.0):
        p.push(_req(cost=c), 1)
    view = WorkerView(wid=0, budget_left=35.0)
    assert p.pop(view)[0].time_request == 30.0     # longest that fits
    assert p.pop(view)[0].time_request == 10.0
    # nothing fits a tiny budget: hand out the shortest anyway (progress)
    assert p.pop(WorkerView(wid=0, budget_left=1.0))[0].time_request == 50.0
    assert len(p) == 0


def test_pack_without_budget_is_lpt():
    p = PackingPolicy()
    for c in (10.0, 50.0, 30.0):
        p.push(_req(cost=c), 1)
    assert [p.pop()[0].time_request for _ in range(3)] == [50.0, 30.0, 10.0]


def test_steal_warm_model_preferred_from_global():
    p = WorkStealingPolicy()
    p.push(_req(model="b", task_id="b0"), 1)
    p.push(_req(model="a", task_id="a0"), 1)
    warm_a = WorkerView(wid=0, warm_models=frozenset({"a"}))
    # the warm model jumps the FIFO global queue for this worker
    assert p.pop(warm_a)[0].task_id == "a0"
    assert p.pop(warm_a)[0].task_id == "b0"


def test_steal_locality_and_stealing():
    p = WorkStealingPolicy()
    w0 = WorkerView(wid=0)
    w1 = WorkerView(wid=1)
    p.push(_req(model="a", task_id="a0"), 1)
    assert p.pop(w0)[0].task_id == "a0"        # affinity a -> w0
    p.push(_req(model="a", task_id="a-local"), 1)   # routed to w0's deque
    assert len(p) == 1
    # global is empty, so w1 STEALS w0's local task
    assert p.pop(w1)[0].task_id == "a-local"
    assert p.pop(w0) is None and p.pop(w1) is None
    # affinity followed the thief: next "a" task routes to w1's deque
    p.push(_req(model="a", task_id="a2"), 1)
    assert p.pop(w1)[0].task_id == "a2"


def test_steal_remove_worker_reflows_local_queue():
    p = WorkStealingPolicy()
    w0, w1 = WorkerView(wid=0), WorkerView(wid=1)
    p.push(_req(model="a", task_id="a0"), 1)
    assert p.pop(w0)[0].task_id == "a0"        # affinity a -> w0
    p.push(_req(model="a", task_id="a1"), 1)   # lands in w0's deque
    p.push(_req(model="b", task_id="b0"), 1)   # global
    p.remove_worker(0)                         # w0 died
    # a1 reflowed to the FRONT of global (it arrived first), affinity gone
    assert p.pop(w1)[0].task_id == "a1"
    p.push(_req(model="a", task_id="a2"), 1)
    assert "a2" in {p.pending()[i][0].task_id for i in range(len(p))}
    assert len(p) == 2                         # b0 + a2, nothing stranded


def test_cost_policies_reorder_on_new_observations():
    """A queue pushed up front is re-costed once the predictor learns."""
    pred = QuantileEstimator(min_observed=1)
    p = SJFPolicy(predictor=pred)
    p.push(_req(model="slow", task_id="s"), 1)
    p.push(_req(model="fast", task_id="f"), 1)
    # at push time nothing is known -> FIFO would pop "s" first
    pred.observe(_req(model="slow"), 50.0)
    pred.observe(_req(model="fast"), 1.0)
    assert p.pop()[0].task_id == "f"           # learned: fast first
    assert p.pop()[0].task_id == "s"


# --------------------------------------------------------------------------
# predictors
# --------------------------------------------------------------------------
def test_quantile_estimator_convergence():
    rng = np.random.default_rng(7)
    samples = rng.lognormal(mean=1.0, sigma=0.5, size=400)
    est = QuantileEstimator(window=512)
    for s in samples:
        est.observe(_req(model="m"), float(s))
    p50, p95 = est.predict(_req(model="m")), est.quantile(0.95, "m")
    assert p50 == pytest.approx(float(np.quantile(samples, 0.5)), rel=0.05)
    assert p95 == pytest.approx(float(np.quantile(samples, 0.95)), rel=0.05)
    assert est.quantile(0.95) == pytest.approx(p95)    # pooled == only model
    assert est.predict(_req(model="unseen")) is None


def test_quantile_estimator_per_model():
    est = QuantileEstimator(min_observed=3)
    for _ in range(5):
        est.observe(_req(model="short"), 1.0)
        est.observe(_req(model="long"), 40.0)
    assert est.predict(_req(model="short")) == pytest.approx(1.0)
    assert est.predict(_req(model="long")) == pytest.approx(40.0)


def test_gp_predictor_learns_runtime_surface():
    rng = np.random.default_rng(0)

    def true_t(x):
        return 0.5 + 2.0 * x[0] ** 2 + 0.5 * x[1]

    gp = GPRuntimePredictor(min_fit=8, refit_every=16, fit_steps=60)
    for x in rng.uniform(0, 1, size=(40, 2)):
        gp.observe(_req(params=[list(map(float, x))]), true_t(x))
    assert gp.n_fits >= 1
    errs = []
    for x in rng.uniform(0.1, 0.9, size=(8, 2)):
        pred = gp.predict(_req(params=[list(map(float, x))]))
        errs.append(abs(pred - true_t(x)) / true_t(x))
    assert float(np.mean(errs)) < 0.10         # within 10 % on average


def test_gp_predictor_falls_back_gracefully():
    gp = GPRuntimePredictor(min_fit=8)
    assert gp.predict(_req()) is None          # nothing observed
    for _ in range(4):
        gp.observe(_req(params=[[1.0]]), 3.0)
    assert gp.predict(_req(params=[[1.0]])) == pytest.approx(3.0)  # quantile
    assert gp.predict(_req(params="not-numeric")) == pytest.approx(3.0)


# --------------------------------------------------------------------------
# deterministic simulator: the acceptance-criterion assertions
# --------------------------------------------------------------------------
def _bimodal_workload(seed=3, n=40):
    rng = np.random.default_rng(seed)
    rts = np.array([40.0] * 8 + [2.0] * (n - 8))
    rng.shuffle(rts)
    return Workload("bimodal", runtimes=tuple(float(r) for r in rts),
                    slurm_alloc=120.0, hq_alloc=900.0,
                    time_request=60.0, time_limit=300.0)


def test_sim_pack_beats_fcfs_on_bimodal():
    w = _bimodal_workload()
    spec = backends.get("hq")
    mk = {}
    for pol in ("fcfs", "pack"):
        recs = simulate_policy(spec, w, n_workers=4, policy=pol, seed=3,
                               hints="oracle")
        assert len(recs) == w.n_tasks
        mk[pol] = metrics.makespan(recs)
    assert mk["pack"] < mk["fcfs"], mk


def test_sim_repeated_seeded_runs_identical():
    w = _bimodal_workload()
    spec = backends.get("hq")
    for pol in ("fcfs", "sjf", "pack", "steal"):
        a = simulate_policy(spec, w, n_workers=3, policy=pol, seed=11,
                            hints="oracle")
        b = simulate_policy(spec, w, n_workers=3, policy=pol, seed=11,
                            hints="oracle")
        assert a == b


def test_sim_no_task_lost_or_duplicated():
    w = _bimodal_workload()
    for backend in ("hq", "slurm"):
        for pol in ("fcfs", "sjf", "lpt", "pack", "steal"):
            recs = simulate_policy(backends.get(backend), w, n_workers=4,
                                   policy=pol, seed=5, hints="oracle")
            ids = [r.task_id for r in recs]
            assert len(ids) == w.n_tasks and len(set(ids)) == w.n_tasks


def test_sim_online_predictor_improves_over_fcfs():
    """pack+quantile: no hints at all, costs learned from completions of a
    two-model campaign — still beats FCFS makespan on bimodal."""
    rng = np.random.default_rng(3)
    n, n_long = 40, 8
    rts = np.array([40.0] * n_long + [2.0] * (n - n_long))
    names = np.array(["long"] * n_long + ["short"] * (n - n_long))
    order = rng.permutation(n)
    rts, names = rts[order], list(names[order])
    w = Workload("bimodal2", runtimes=tuple(float(r) for r in rts),
                 slurm_alloc=120.0, hq_alloc=900.0,
                 time_request=60.0, time_limit=300.0)
    spec = backends.get("hq")
    fcfs = simulate_policy(spec, w, n_workers=4, policy="fcfs", seed=3,
                           hints=None, model_names=names)
    pack = simulate_policy(spec, w, n_workers=4, policy="pack",
                           predictor="quantile", seed=3, hints=None,
                           model_names=names)
    assert metrics.makespan(pack) < metrics.makespan(fcfs)


def test_sim_and_executor_share_policy_classes():
    """The acceptance criterion: the SAME policy objects drive both the
    simulator and the live executor — no forked policy logic."""
    pol_cls = type(make_policy("pack"))
    assert pol_cls is PackingPolicy
    with Executor({"toy": _toy_factory}, n_workers=1, policy="pack") as ex:
        assert type(ex.policy) is pol_cls
    sim_pol = make_policy("pack")
    recs = simulate_policy(backends.get("hq"), _bimodal_workload(),
                           n_workers=2, policy=sim_pol, seed=0,
                           hints="oracle")
    assert recs and len(sim_pol) == 0          # the instance was the queue


# --------------------------------------------------------------------------
# live executor under non-FCFS policies
# --------------------------------------------------------------------------
def _toy_factory():
    time.sleep(0.01)
    return LambdaModel("toy", lambda p, c: [[float(p[0][0]) * 2]], 1, 1)


@pytest.mark.parametrize("policy", ["sjf", "lpt", "pack", "steal"])
def test_executor_no_task_lost_under_requeue(policy):
    """Injected failures + retries under non-FCFS orderings: every task
    completes exactly once with the right value."""
    with Executor({"toy": _toy_factory}, n_workers=3, policy=policy,
                  predictor="quantile", max_attempts=3) as ex:
        reqs = [EvalRequest("toy", [[i]], time_request=float(i % 5),
                            config={"fail_attempts": 1} if i % 4 == 0 else {})
                for i in range(24)]
        res = ex.run_all(reqs, timeout=60)
        assert [r.value[0][0] for r in res] == [2.0 * i for i in range(24)]
        assert all(r.status == "ok" for r in res)
        assert len({r.task_id for r in res}) == 24


def test_executor_worker_death_under_steal_policy():
    """Crash recovery with per-worker queues: the dead worker's local
    tasks reflow and every task still completes."""
    def slow():
        return LambdaModel("s", lambda p, c: (time.sleep(0.1),
                                              [[float(p[0][0])]])[1], 1, 1)
    with Executor({"s": slow}, n_workers=2, policy="steal") as ex:
        ids = [ex.submit(EvalRequest("s", [[i]])) for i in range(8)]
        time.sleep(0.05)
        ex.kill_worker(0)
        res = [ex.result(t, timeout=30) for t in ids]
        assert all(r.status == "ok" for r in res)
        assert ex.n_workers() == 1


def test_executor_policy_instance_predictor_wins():
    """A policy instance arriving with its own predictor: completions
    feed THAT predictor, not a second one built from the kwarg."""
    own = QuantileEstimator()
    pol = SJFPolicy(predictor=own)
    with Executor({"toy": _toy_factory}, n_workers=2, policy=pol,
                  predictor="gp") as ex:
        assert ex.predictor is own
        ex.run_all([EvalRequest("toy", [[i]]) for i in range(6)])
        assert own.n_observed("toy") >= 6


def test_executor_pack_with_allocation_budget():
    with Executor({"toy": _toy_factory}, n_workers=2, policy="pack",
                  allocation_s=120.0) as ex:
        res = ex.run_all([EvalRequest("toy", [[i]], time_request=5.0)
                          for i in range(6)])
        assert all(r.status == "ok" for r in res)
        assert ex.workers[0].view().budget_left is not None


def test_sim_allocation_renewal_reselects_worker():
    """A short allocation forces renewals; tasks must not be parked on a
    renewing worker while another is free, and determinism must hold."""
    w = Workload("renew", runtimes=tuple([30.0] * 8),
                 slurm_alloc=60.0, hq_alloc=70.0,   # fits ~2 tasks per alloc
                 time_request=30.0, time_limit=60.0)
    spec = backends.get("hq")
    a = simulate_policy(spec, w, n_workers=2, policy="fcfs", seed=5)
    b = simulate_policy(spec, w, n_workers=2, policy="fcfs", seed=5)
    assert a == b and len(a) == 8
    # workers renew in parallel: total makespan far below serial worst case
    per_worker = sorted(r.worker for r in a)
    assert len(set(per_worker)) == 2           # both workers kept busy


def test_executor_no_duplicate_under_speculation():
    def var():
        return LambdaModel(
            "v", lambda p, c: (time.sleep(p[0][0]), [[p[0][0]]])[1], 1, 1)
    with Executor({"v": var}, n_workers=3, policy="sjf",
                  predictor="quantile", straggler_factor=3.0,
                  straggler_min_completed=5) as ex:
        reqs = [EvalRequest("v", [[0.02]]) for _ in range(15)]
        reqs.append(EvalRequest("v", [[0.6]]))
        res = ex.run_all(reqs, timeout=60)
        assert all(r.status == "ok" for r in res)
        assert len({r.task_id for r in res}) == len(reqs)


def test_executor_dependencies_respected_under_lpt():
    order = []

    def dep():
        return LambdaModel(
            "d", lambda p, c: (order.append(p[0][0]), [[p[0][0]]])[1], 1, 1)
    with Executor({"d": dep}, n_workers=2, policy="lpt") as ex:
        # LPT would run the "biggest" first; dependencies must still gate
        a = EvalRequest("d", [[1]], time_request=1.0)
        b = EvalRequest("d", [[2]], time_request=50.0,
                        depends_on=(a.task_id,))
        c = EvalRequest("d", [[3]], time_request=99.0,
                        depends_on=(b.task_id,))
        for r in (c, b, a):
            ex.submit(r)
        ex.result(c.task_id, 10)
    assert order == [1, 2, 3]


def test_executor_snapshot_restore_with_policy():
    with Executor({"toy": _toy_factory}, n_workers=1, policy="sjf") as ex:
        ids = [ex.submit(EvalRequest("toy", [[i]], time_request=float(i)))
               for i in range(8)]
        ex.result(ids[0], 10)
        snap = ex.snapshot()
    ex2 = Executor.restore(snap, {"toy": _toy_factory}, n_workers=2,
                           policy="sjf")
    try:
        res = [ex2.result(t, 30) for t in ids]
        assert all(r.status == "ok" for r in res)
    finally:
        ex2.shutdown()


def test_executor_predictor_feedback_loop():
    with Executor({"toy": _toy_factory}, n_workers=2, policy="sjf",
                  predictor="quantile") as ex:
        ex.run_all([EvalRequest("toy", [[i]]) for i in range(10)])
        assert ex.predictor.n_observed("toy") >= 10
        assert ex.predictor.predict(EvalRequest("toy", [[0]])) is not None


# --------------------------------------------------------------------------
# server-init accounting (the satellite fix)
# --------------------------------------------------------------------------
def test_server_init_not_clobbered_on_reuse():
    with Executor({"toy": _toy_factory}, n_workers=1) as ex:
        res = ex.run_all([EvalRequest("toy", [[i]]) for i in range(5)])
        inits = sorted((r.init_t for r in res), reverse=True)
        assert inits[0] > 0.0                  # first dispatch paid warmup
        assert all(i == 0.0 for i in inits[1:])    # reuses report 0
        server = next(iter(ex.workers[0].servers.values()))
        assert server.init_t > 0.0             # stored first-init survives
        m = ex.metrics()
        assert m["server_inits"] == 1
        assert m["server_init_total_t"] == pytest.approx(server.init_t)


def test_metrics_cumulative_init_fresh_servers():
    with Executor({"toy": _toy_factory}, n_workers=2,
                  persistent_servers=False) as ex:
        res = ex.run_all([EvalRequest("toy", [[i]]) for i in range(8)])
        m = ex.metrics()
        assert m["server_inits"] == 8
        assert m["server_init_total_t"] == pytest.approx(
            sum(r.init_t for r in res))
        assert m["results_by_status"] == {"ok": 8}


# --------------------------------------------------------------------------
# balancer facade passthrough
# --------------------------------------------------------------------------
def test_balancer_exposes_policy_and_predictor():
    with LoadBalancer("hq", n_workers=2, policy="pack",
                      predictor="quantile") as lb:
        lb.register_model("toy", _toy_factory)
        assert lb.policy is not None and lb.policy.name == "pack"
        assert isinstance(lb.predictor, QuantileEstimator)
        assert lb.evaluate("toy", [[4]])[0][0] == 8.0
        assert lb.predictor.n_observed("toy") >= 1
