"""Tests for trace-driven calibration and replay (`repro.obs.calib` /
`repro.obs.replay`): the BackendSpec overhead draws themselves (seeded
moment checks), lognormal fit recovery with the KS gate and ECDF
fallback, calibration from recorded traces, the bitwise round-trip
replay contract, online drift detection, and the JSONL read path."""
import json
import math

import numpy as np
import pytest

from repro.cluster.autoalloc import AutoAllocConfig
from repro.cluster.sim import simulate_cluster
from repro.cluster.traces import TraceTask, bursty_trace
from repro.core import backends
from repro.core.backends import QUEUE_WAIT_SATURATION_S, lognormal
from repro.obs import (CalibratedBackendSpec, CalibrationMonitor,
                       MetricsRegistry, ReplayBackendSpec, TraceReplay,
                       Tracer, calibrate, extract_phase_samples,
                       fit_lognormal, fit_phase, hlo_runtime_prior,
                       prior_fit, read_jsonl, replay_cluster,
                       validate_jsonl_row)


# ---------------------------------------------------------------------------
# the spec's own overhead draws: seeded moment checks
# ---------------------------------------------------------------------------
def test_lognormal_draw_moments():
    rng = np.random.default_rng(0)
    xs = np.array([lognormal(rng, 2.0, 0.6) for _ in range(4000)])
    # median of the draw IS the parameter (log-symmetric around it)
    assert np.median(xs) == pytest.approx(2.0, rel=0.1)
    # sigma is the std of the logs
    assert np.log(xs).std() == pytest.approx(0.6, rel=0.1)


def test_lognormal_degenerate_cases():
    rng = np.random.default_rng(1)
    # sigma=0 collapses to the median exactly (deterministic specs)
    assert lognormal(rng, 3.5, 0.0) == 3.5
    # non-positive median is a zero draw, not an error
    assert lognormal(rng, 0.0, 0.6) == 0.0
    assert lognormal(rng, -1.0, 0.6) == 0.0


def test_draw_queue_wait_matches_model():
    spec = backends.get("hq")
    # the median model: floor + coef * min(walltime, sat)^power
    expect = (spec.queue_wait_floor + spec.queue_wait_coef
              * min(7200.0, QUEUE_WAIT_SATURATION_S)
              ** spec.queue_wait_power)
    assert spec.queue_wait_median(7200.0) == pytest.approx(expect)
    # saturation: a 600 h request waits like the partition max
    assert spec.queue_wait_median(600 * 3600.0) \
        == spec.queue_wait_median(QUEUE_WAIT_SATURATION_S)
    # the draw's median is the model's median
    rng = np.random.default_rng(2)
    xs = [spec.draw_queue_wait(rng, 7200.0) for _ in range(4000)]
    assert np.median(xs) == pytest.approx(expect, rel=0.1)


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------
def test_fit_lognormal_recovers_known_params():
    rng = np.random.default_rng(3)
    xs = [lognormal(rng, 3.0, 0.4) for _ in range(3000)]
    median, sigma = fit_lognormal(xs)
    assert median == pytest.approx(3.0, rel=0.05)
    assert sigma == pytest.approx(0.4, rel=0.05)
    f = fit_phase("runtime", "m", xs)
    assert f.lognormal_ok and f.ks_pvalue > 0.05
    # draws from the fit reproduce the distribution
    r2 = np.random.default_rng(4)
    drawn = [f.draw(r2) for _ in range(2000)]
    assert np.median(drawn) == pytest.approx(3.0, rel=0.1)


def test_ks_rejects_bimodal_and_ecdf_takes_over():
    xs = [0.1] * 200 + [10.0] * 200
    f = fit_phase("init", None, xs)
    assert not f.lognormal_ok and f.ks_pvalue < 0.05
    # the ECDF fallback draws from the actual support, not the
    # (badly-fitting) lognormal's continuum
    r = np.random.default_rng(5)
    drawn = [f.draw(r) for _ in range(500)]
    lo = sum(1 for d in drawn if d <= 0.2)
    hi = sum(1 for d in drawn if d >= 9.0)
    assert lo + hi > 450            # almost everything lands at a mode
    assert 100 < lo < 400           # and both modes are populated
    assert f.quantile(0.0) == 0.1 and f.quantile(1.0) == 10.0


def test_fit_constant_and_zero_samples():
    f = fit_phase("init", None, [1.0, 1.0, 1.0, 1.0])
    assert f.lognormal_ok and f.median == pytest.approx(1.0) \
        and f.sigma == 0.0
    z = fit_phase("dispatch", None, [0.0, 0.0, 0.0])
    assert z.median == 0.0          # point mass at zero, not log(eps)
    rng = np.random.default_rng(6)
    assert z.draw(rng) == 0.0


# ---------------------------------------------------------------------------
# calibration from a recorded trace
# ---------------------------------------------------------------------------
def _sim_trace_events(seed=3, **kw):
    spec = backends.get("hq")
    tracer = Tracer()
    simulate_cluster(spec, bursty_trace(2, 10, seed=seed), seed=seed,
                     tracer=tracer, **kw)
    return spec, tracer.events()


def test_extract_phase_samples_keys():
    _spec, events = _sim_trace_events(n_workers=4)
    groups = extract_phase_samples(events)
    phases = {k[0] for k in groups}
    assert {"queue_wait", "init", "dispatch", "runtime"} <= phases
    assert ("runtime", "burst-model") in groups
    assert len(groups[("runtime", "burst-model")]) == 20


def test_calibrate_sim_trace_recovers_exact_constants():
    spec, events = _sim_trace_events(n_workers=4)
    cal = calibrate(events, spec)
    assert isinstance(cal, CalibratedBackendSpec)
    # every cold init in the sim is exactly spec.server_init, and the
    # exact value rides in the span args -> the fit is bit-exact
    assert cal.server_init == spec.server_init
    assert cal.server_init_for("burst-model") == spec.server_init
    # dispatch medians come from span durs (endpoint differences):
    # close, not bitwise
    assert cal.dispatch_latency == pytest.approx(spec.dispatch_latency,
                                                 rel=1e-6)
    # the fitted runtime matches the trace's ~20 s bursty runtimes
    rf = cal.runtime_fit("burst-model")
    assert rf is not None and rf.median == pytest.approx(20.0, rel=0.15)
    # drop-in: the calibrated spec runs through the simulator unchanged
    res = simulate_cluster(cal, bursty_trace(1, 4, seed=1), n_workers=2,
                           seed=1)
    assert all(r.status == "ok" for r in res.records)


def test_calibrate_queue_wait_fallback_to_base_model():
    spec, events = _sim_trace_events(n_workers=4)
    cal = calibrate(events, spec)
    # the trace has one unbounded-walltime allocation; its fitted wait
    # answers nearest-key lookups...
    fitted = cal.queue_wait_median(math.inf)
    assert fitted == cal.fit_for("queue_wait",
                                 (None, 4)).median  # type: ignore[union-attr]
    # ...while a spec with NO queue fits falls back to the base model
    bare = calibrate([e for e in events if e[2] != "alloc.queued"], spec)
    assert bare.queue_wait_median(7200.0) \
        == spec.queue_wait_median(7200.0)


def test_calibrate_priors_for_unobserved_models():
    spec, events = _sim_trace_events(n_workers=4)
    cal = calibrate(events, spec, priors={"jax-kernel": 0.42})
    rf = cal.runtime_fit("jax-kernel")
    assert rf is not None and rf.median == 0.42 and rf.source == "prior"
    # an observed model's trace fit is NOT overridden by a prior
    cal2 = calibrate(events, spec, priors={"burst-model": 999.0})
    assert cal2.runtime_fit("burst-model").median != 999.0


def test_hlo_runtime_prior_roofline():
    # compute-bound: 2e12 flops at 1e12 flop/s -> 2 s (+ floor)
    t = hlo_runtime_prior({"flops": 2e12, "bytes": 1e9},
                          peak_flops=1e12, mem_bw=1e11)
    assert t == pytest.approx(2.0, abs=1e-3)
    # memory-bound: 1e10 bytes at 1e11 B/s dominates 1e9 flops
    t = hlo_runtime_prior({"flops": 1e9, "bytes": 1e10},
                          peak_flops=1e12, mem_bw=1e11)
    assert t == pytest.approx(0.1, abs=1e-3)
    # object access path (OpCost-alikes)
    pf = prior_fit("runtime", "k", hlo_runtime_prior(
        type("C", (), {"flops": 1e12, "bytes": 0.0, "coll_bytes": 0.0})(),
        peak_flops=1e12))
    assert pf.median == pytest.approx(1.0, abs=1e-3)


# ---------------------------------------------------------------------------
# round-trip replay: THE exactness contract
# ---------------------------------------------------------------------------
_KILL_CFG = dict(workers_per_alloc=2, backlog_high_s=30, backlog_low_s=5,
                 max_pending=2, max_allocations=4, min_allocations=0,
                 idle_drain_s=20, hysteresis_s=5, walltime_s=25)


@pytest.mark.parametrize("max_attempts", [2, 6])
def test_roundtrip_identity_elastic(max_attempts):
    """Replaying a sim-recorded trace reproduces the original records,
    allocations, and makespan EXACTLY — including walltime kills,
    requeues, and (max_attempts=2) terminal kills."""
    spec = backends.get("hq")
    cfg = AutoAllocConfig(**_KILL_CFG)
    tracer = Tracer()
    orig = simulate_cluster(spec, bursty_trace(2, 10, seed=3),
                            autoalloc=cfg, seed=3,
                            max_attempts=max_attempts, tracer=tracer)
    replay = TraceReplay(tracer.events())
    # a different seed proves the rng is fully displaced by the trace
    again = simulate_cluster(replay.spec(spec), replay.trace(),
                             autoalloc=cfg, seed=4242,
                             max_attempts=max_attempts)
    assert orig.records == again.records
    assert orig.allocations == again.allocations
    assert orig.summary() == again.summary()
    if max_attempts == 2:           # the scenario must exercise kills
        assert any(r.status == "failed" for r in orig.records)


def test_roundtrip_identity_static_with_lost():
    spec = backends.get("hq")
    tracer = Tracer()
    orig = simulate_cluster(spec, bursty_trace(2, 10, seed=3),
                            n_workers=2, walltime_s=120, seed=7,
                            tracer=tracer)
    assert any(r.status == "lost" for r in orig.records)
    again = replay_cluster(spec, tracer.events(), n_workers=2,
                           walltime_s=120, seed=0)
    assert orig.records == again.records


def test_replay_spec_fifo_and_fallback():
    spec, events = _sim_trace_events(n_workers=4)
    replay = TraceReplay(events)
    assert len(replay.queue_waits) == 1
    rspec = replay.spec(spec)
    assert isinstance(rspec, ReplayBackendSpec)
    rng = np.random.default_rng(0)
    # first draw pops the recorded value verbatim...
    assert rspec.draw_queue_wait(rng, math.inf) == replay.queue_waits[0]
    # ...and a dry FIFO falls back to the base parametric draw
    rng2 = np.random.default_rng(11)
    fallback = rspec.draw_queue_wait(rng2, 7200.0)
    assert fallback == spec.draw_queue_wait(np.random.default_rng(11),
                                            7200.0)
    # fresh FIFO per spec() call: a second replay starts over
    assert replay.spec(spec).queue_fifo[0] == replay.queue_waits[0]
    # exact recorded constants from the trace.spec instant
    assert rspec.dispatch_latency == spec.dispatch_latency
    assert rspec.server_init_for("burst-model") == spec.server_init


def test_replay_untimed_task_ladder():
    # killed-terminal -> inf; lost with time_request -> the hint
    spec = backends.get("hq")
    tracer = Tracer()
    simulate_cluster(spec, bursty_trace(2, 10, seed=3), n_workers=2,
                     walltime_s=120, seed=7, tracer=tracer)
    replay = TraceReplay(tracer.events())
    tasks = replay.trace()
    lost = [t for t in tasks if not math.isfinite(t.runtime)
            or t.runtime != pytest.approx(20.0, rel=0.2)]
    # bursty_trace hints time_request=runtime_s: untimed tasks take it
    for t in lost:
        assert t.runtime == t.time_request or math.isinf(t.runtime)
    # killed-terminal tasks replay as inf
    cfg = AutoAllocConfig(**_KILL_CFG)
    t2 = Tracer()
    simulate_cluster(spec, bursty_trace(2, 10, seed=3), autoalloc=cfg,
                     seed=3, max_attempts=2, tracer=t2)
    r2 = TraceReplay(t2.events())
    assert r2.summary()["n_killed"] > 0
    killed_rts = [r2.runtime_of(t) for t in r2._killed]
    assert all(math.isinf(rt) for rt in killed_rts)


# ---------------------------------------------------------------------------
# online drift detection
# ---------------------------------------------------------------------------
def test_monitor_alarm_once_with_hysteresis():
    spec = backends.get("hq")          # dispatch_latency = 8 ms
    reg = MetricsRegistry()
    tracer = Tracer()
    mon = CalibrationMonitor(spec, registry=reg, tracer=tracer, min_n=4,
                             window=8)
    # sustained excursion: observed dispatch ~0 vs predicted 8 ms
    for i in range(10):
        mon.observe("dispatch", spec.dispatch_latency, 0.0, float(i))
    assert len(mon.alarms) == 1        # one excursion, ONE alarm
    drift_events = [e for e in tracer.buf if e[2] == "calib.drift"]
    assert len(drift_events) == 1
    assert drift_events[0][6]["phase"] == "dispatch"
    # recovery re-arms: accurate observations pull the window mean back
    for i in range(10, 30):
        mon.observe("dispatch", spec.dispatch_latency,
                    spec.dispatch_latency, float(i))
    for i in range(30, 40):
        mon.observe("dispatch", spec.dispatch_latency, 0.0, float(i))
    assert len(mon.alarms) == 2        # second excursion, second alarm


def test_monitor_consume_trace_and_calibrated_silence():
    spec, events = _sim_trace_events(n_workers=4)
    # the sim trace was GENERATED by this spec: zero residual, no alarms
    mon = CalibrationMonitor(spec, min_n=4)
    fed = mon.consume(events)
    assert fed > 0 and mon.alarms == []
    # a wildly-off spec alarms on the same trace
    wrong = backends.get("slurm")      # dispatch 0.5 s vs hq's 8 ms
    mon2 = CalibrationMonitor(wrong, min_n=4)
    mon2.consume(events)
    assert len(mon2.alarms) >= 1
    # calibrating on the trace silences the alarms again
    cal = calibrate(events, wrong)
    mon3 = CalibrationMonitor(cal, min_n=4)
    mon3.consume(events)
    assert mon3.alarms == []


def test_monitor_registry_counters():
    spec = backends.get("hq")
    reg = MetricsRegistry()
    mon = CalibrationMonitor(spec, registry=reg, min_n=4)
    for i in range(8):
        mon.observe("init", 1.0, 4.0, float(i))
    assert len(mon.alarms) == 1
    assert mon.summary()["phases"]["init"]["mean_logratio"] \
        == pytest.approx(math.log(4.0), abs=0.01)


# ---------------------------------------------------------------------------
# JSONL read path + streaming
# ---------------------------------------------------------------------------
def test_read_jsonl_roundtrip(tmp_path):
    _spec, events = _sim_trace_events(n_workers=4)
    tracer = Tracer()
    for ev in events:
        tracer.emit(ev[1], ev[2], ev[0], pid=ev[3], tid=ev[4],
                    dur=ev[5], args=ev[6])
    path = str(tmp_path / "t.jsonl")
    tracer.write_jsonl(path)
    back = read_jsonl(path)
    assert back == [(*e[:6], e[6] if e[6] else None) for e in events]


def test_read_jsonl_strict_and_lenient(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    good = {"ts": 1.0, "ph": "i", "name": "x", "pid": 0, "tid": 0}
    with open(path, "w") as fh:
        fh.write(json.dumps(good) + "\n")
        fh.write("not json\n")
        fh.write(json.dumps({"ts": 2.0, "ph": "Z", "name": "y"}) + "\n")
        fh.write(json.dumps(dict(good, ts=3.0)) + "\n")
    with pytest.raises(ValueError, match="bad.jsonl:2"):
        read_jsonl(path)
    rows = read_jsonl(path, strict=False)
    assert [r[0] for r in rows] == [1.0, 3.0]


def test_validate_jsonl_row():
    ok = {"ts": 0.0, "ph": "X", "name": "task.run", "pid": 1, "tid": 0,
          "dur": 2.0, "args": {"task": "t"}}
    assert validate_jsonl_row(ok) is None
    assert validate_jsonl_row({**ok, "ph": "Q"}) is not None
    assert validate_jsonl_row({**ok, "ts": float("nan")}) is not None
    assert validate_jsonl_row({**ok, "dur": -1.0}) is not None
    assert validate_jsonl_row({**ok, "args": 3}) is not None
    assert validate_jsonl_row([1, 2]) is not None


def test_stream_to_matches_write_jsonl(tmp_path):
    spec = backends.get("hq")
    streamed = str(tmp_path / "s.jsonl")
    tracer = Tracer().stream_to(streamed)
    simulate_cluster(spec, bursty_trace(1, 6, seed=2), n_workers=2,
                     seed=2, tracer=tracer)
    tracer.close_stream()
    batch = str(tmp_path / "b.jsonl")
    tracer.write_jsonl(batch)
    assert open(streamed).read() == open(batch).read()
    # and the streamed file calibrates end-to-end
    cal = calibrate(streamed, spec)
    assert cal.server_init == spec.server_init


def test_streamed_trace_survives_ring_buffer_drop(tmp_path):
    spec = backends.get("hq")
    path = str(tmp_path / "tiny.jsonl")
    tracer = Tracer(capacity=8).stream_to(path)   # buffer far too small
    simulate_cluster(spec, bursty_trace(1, 6, seed=2), n_workers=2,
                     seed=2, tracer=tracer)
    tracer.close_stream()
    assert tracer.n_dropped > 0
    assert len(read_jsonl(path)) == tracer.buf.n_seen


# ---------------------------------------------------------------------------
# sacct field-mapping adapter: real SLURM accounting -> trace schema
# ---------------------------------------------------------------------------
SACCT_LINES = [
    "JobID|JobName|State|Submit|Start|End|Elapsed|Timelimit|NNodes",
    "100|gs2|COMPLETED|2024-03-05T10:00:00|2024-03-05T10:05:00"
    "|2024-03-05T10:25:00|00:20:00|01:00:00|4",
    "100.batch|batch|COMPLETED|2024-03-05T10:05:00|2024-03-05T10:05:00"
    "|2024-03-05T10:25:00|00:20:00||4",
    "100.extern|extern|COMPLETED|2024-03-05T10:05:00|2024-03-05T10:05:00"
    "|2024-03-05T10:25:00|00:20:00||4",
    "101|gs2|TIMEOUT|2024-03-05T10:00:30|2024-03-05T10:10:00"
    "|2024-03-05T11:10:00|01:00:00|01:00:00|4",
    "102|gpsurrogate|COMPLETED|2024-03-05T10:01:00|2024-03-05T10:02:00"
    "|2024-03-05T10:02:05|00:00:05|00:10:00|1",
    "103|gs2|CANCELLED by 1000|2024-03-05T10:02:00|Unknown|Unknown"
    "|00:00:00|01:00:00|4",
    "104|gs2|FAILED|2024-03-05T10:02:00|2024-03-05T10:04:00"
    "|2024-03-05T10:05:00|00:01:00|01:00:00|4",
    "105|gs2|RUNNING|2024-03-05T10:03:00|2024-03-05T10:06:00|Unknown"
    "|00:30:00|01:00:00|4",
]


def test_parse_slurm_duration_forms():
    from repro.obs import parse_slurm_duration
    assert parse_slurm_duration("1-02:03:04.5") == pytest.approx(93784.5)
    assert parse_slurm_duration("00:20:00") == 1200.0
    assert parse_slurm_duration("12:34") == 754.0
    assert parse_slurm_duration("UNLIMITED") is None
    assert parse_slurm_duration("Partition_Limit") is None
    assert parse_slurm_duration("") is None
    assert parse_slurm_duration("garbage") is None


def test_read_sacct_phase_samples():
    from repro.obs import extract_phase_samples, read_sacct
    evs = read_sacct(SACCT_LINES)
    samples = extract_phase_samples(evs)
    # queue waits keyed by the (walltime_s, n_workers) request signature
    assert samples[("queue_wait", (3600.0, 4))] == [300.0, 570.0, 120.0]
    assert samples[("queue_wait", (600.0, 1))] == [60.0]
    # runtimes keyed by JobName; ok+timeout counted, FAILED excluded
    assert samples[("runtime", "gs2")] == [1200.0, 3600.0]
    assert samples[("runtime", "gpsurrogate")] == [5.0]


def test_read_sacct_skips_steps_and_incomplete():
    from repro.obs import read_sacct
    evs = read_sacct(SACCT_LINES)
    tasks = [e[6]["task"] for e in evs if e[2] == "task.run"]
    # steps (100.batch/.extern), pending-cancelled (103) and RUNNING
    # (105) never become samples
    assert sorted(tasks) == ["100", "101", "102", "104"]
    assert all("." not in t for t in tasks)
    # the FAILED job is kept in the trace but flagged, like any failure
    by_task = {e[6]["task"]: e[6] for e in evs if e[2] == "task.run"}
    assert by_task["104"]["status"] == "failed"
    assert by_task["101"]["status"] == "timeout"


def test_sacct_to_jsonl_roundtrip_and_calibrate(tmp_path):
    from repro.obs import read_sacct, sacct_to_jsonl
    path = str(tmp_path / "sacct.jsonl")
    n = sacct_to_jsonl(SACCT_LINES, path)
    evs = read_jsonl(path)                  # every row schema-valid
    assert len(evs) == n
    assert evs == read_sacct(SACCT_LINES)
    # and the converted log drops straight into calibrate()
    base = backends.get("hq")
    cal = calibrate(path, base, min_samples=1)
    assert cal.queue_wait_median(3600.0, 4) == pytest.approx(
        math.exp(np.mean(np.log([300.0, 570.0, 120.0]))), rel=1e-6)


def test_read_sacct_field_map_and_no_header():
    from repro.obs import read_sacct
    # site export keyed runtimes by Account instead of JobName
    remapped = ["JobID|Account|State|Submit|Start|End|Elapsed|Timelimit"
                "|NNodes",
                "300|proj-a|COMPLETED|1000|1060|1120|00:01:00|00:10:00|2"]
    evs = read_sacct(remapped, field_map={"JobName": "Account"})
    run = [e for e in evs if e[2] == "task.run"][0]
    assert run[6]["model"] == "proj-a"
    # headerless input assumes the default column order; epoch stamps ok
    bare = ["200|m|COMPLETED|1000|1060|1120|00:01:00|00:10:00|2"]
    (b, e, x) = read_sacct(bare)
    assert b[6]["queue_wait"] == 60.0 and b[6]["n_workers"] == 2
    assert x[5] == 60.0


def test_read_sacct_strict_flags_unknown_state():
    from repro.obs import read_sacct
    bad = ["JobID|State", "1|WEIRD"]
    with pytest.raises(ValueError, match="WEIRD"):
        read_sacct(bad)
    assert read_sacct(bad, strict=False) == []
