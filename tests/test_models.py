"""Per-architecture smoke + decode-equivalence tests (reduced configs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models.config import ModelConfig

ARCHS = list(configs.ARCH_NAMES)


def _batch(cfg: ModelConfig, b: int, s: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    if cfg.input_mode == "embeddings":
        return {"embeddings": jnp.asarray(
                    rng.standard_normal((b, s, cfg.d_model)), cfg.activation_dtype),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}


# --------------------------------------------------------------------------
# smoke: forward + one train step per arch
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = configs.get_reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 16)
    logits, _, aux = M.forward(params, batch, cfg)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    from repro.launch.steps import make_train_step
    from repro.optim import AdamWConfig, init_opt_state
    cfg = configs.get_reduced(arch)
    opt_cfg = AdamWConfig()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _batch(cfg, 2, 16)
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(o2["step"]) == 1
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, p2)
    assert max(jax.tree.leaves(moved)) > 0


# --------------------------------------------------------------------------
# decode equivalence: cached decode must match teacher-forced forward
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = configs.get_reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b, prompt, total = 2, 6, 10
    full = _batch(cfg, b, total, seed=3)
    full.pop("labels", None)
    # teacher-forced full forward
    logits_full, _, _ = M.forward(params, full, cfg)

    def slice_batch(lo, hi):
        return {k: v[:, lo:hi] for k, v in full.items()}

    cache = M.init_cache(cfg, b, total)
    _, cache, _ = M.prefill(params, slice_batch(0, prompt), cfg, cache)
    for pos in range(prompt, total):
        step_logits, cache = M.decode_step(
            params, slice_batch(pos, pos + 1), cfg, cache, jnp.int32(pos))
        want = logits_full[:, pos]
        np.testing.assert_allclose(
            np.asarray(step_logits, np.float32),
            np.asarray(want, np.float32), atol=2e-3, rtol=2e-3,
            err_msg=f"{arch} decode diverges at pos {pos}")


# --------------------------------------------------------------------------
# family-specific invariants
# --------------------------------------------------------------------------
def test_moe_capacity_drops_are_bounded():
    """With a generous capacity factor no tokens should be dropped:
    doubling capacity must not change the output."""
    from repro.models.moe import moe_apply
    cfg = configs.get_reduced("dbrx-132b")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    seg = [s for s in M.model_segments(cfg) if s.kind == "attn_moe"][0]
    lp = jax.tree.map(lambda t: t[0], params[seg.name])
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model))
    y1, _ = moe_apply(lp["moe"], x, cfg.replace(capacity_factor=8.0))
    y2, _ = moe_apply(lp["moe"], x, cfg.replace(capacity_factor=16.0))
    np.testing.assert_allclose(y1, y2, atol=1e-5)


def test_moe_aux_loss_near_one_for_uniform_router():
    """Switch aux loss == E * sum f_i P_i -> ~1.0 under uniform routing."""
    from repro.models.moe import _route
    logits = jnp.zeros((4096, 8)) + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(4), (4096, 8))
    cfg = configs.get_reduced("dbrx-132b")
    _, _, aux = _route(logits, cfg)
    assert 0.9 < float(aux) < 1.3


def test_deepseek_mtp_loss_present():
    cfg = configs.get_reduced("deepseek-v3-671b")
    assert cfg.mtp_depth == 1
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    batch = _batch(cfg, 2, 12)
    loss, metrics = M.loss_fn(params, batch, cfg)
    assert "mtp_ce" in metrics and np.isfinite(float(metrics["mtp_ce"]))


def test_zamba_shared_attention_is_shared():
    """The zamba2 shared attention block must be a single weight copy."""
    cfg = configs.get_reduced("zamba2-2.7b")
    defs = M.param_defs(cfg)
    assert "shared_attn" in defs
    # groups stack exists and the shared block is NOT per-layer stacked
    w_q = defs["shared_attn"]["attn"]["w_q"]
    assert len(w_q.shape) == 3  # no leading layer dim


def test_long_500k_runnable_flags():
    runnable = {a: configs.get(a).runnable(configs.shapes()[3])
                for a in ARCHS}
    assert runnable["rwkv6-3b"] and runnable["zamba2-2.7b"]
    assert sum(runnable.values()) == 2  # everyone else skips long_500k


def test_param_counts_match_public_specs():
    """Full-config parameter counts must land near the published sizes."""
    expected = {
        "yi-34b": 34.4e9, "qwen3-14b": 14.8e9, "dbrx-132b": 132e9,
        "deepseek-v3-671b": 671e9, "starcoder2-3b": 3.0e9,
        "minicpm3-4b": 4.0e9, "rwkv6-3b": 3.1e9, "zamba2-2.7b": 2.7e9,
        "phi-3-vision-4.2b": 4.2e9, "musicgen-large": 3.3e9,
    }
    for arch, want in expected.items():
        got = M.count_params(configs.get(arch))
        assert 0.7 * want < got < 1.35 * want, (arch, got, want)
