"""UQ substrate tests: GP vs closed form, GS2 proxy profile, QoI, samplers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.uq import gp as gp_lib
from repro.uq import gs2_proxy, qoi, sampling
from repro.uq.eigen import EigenModel


# --------------------------------------------------------------------------
# samplers
# --------------------------------------------------------------------------
def test_lhs_stratification():
    """LHS: exactly one sample per 1/n stratum in every dimension."""
    n = 50
    x = sampling.latin_hypercube(n, seed=1)
    lo = np.array([r[1] for r in sampling.GS2_PARAM_RANGES])
    hi = np.array([r[2] for r in sampling.GS2_PARAM_RANGES])
    u = (x - lo) / (hi - lo)
    for d in range(u.shape[1]):
        strata = np.floor(u[:, d] * n).astype(int)
        assert len(set(strata.tolist())) == n


def test_lhs_seeded_repeatable():
    a = sampling.latin_hypercube(20, seed=9)
    b = sampling.latin_hypercube(20, seed=9)
    np.testing.assert_array_equal(a, b)
    c = sampling.latin_hypercube(20, seed=10)
    assert not np.array_equal(a, c)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(5, 60))
def test_halton_in_bounds(n):
    x = sampling.halton(n)
    lo = np.array([r[1] for r in sampling.GS2_PARAM_RANGES])
    hi = np.array([r[2] for r in sampling.GS2_PARAM_RANGES])
    assert np.all(x >= lo - 1e-12) and np.all(x <= hi + 1e-12)


# --------------------------------------------------------------------------
# GS2 proxy
# --------------------------------------------------------------------------
def test_gs2_proxy_deterministic():
    theta = sampling.latin_hypercube(1, seed=2)[0]
    assert gs2_proxy.evaluate(theta) == gs2_proxy.evaluate(theta)


def test_gs2_proxy_runtime_spread():
    """The scheduling-relevant property: a wide, unpredictable runtime
    distribution over the LHS inputs (paper: minutes -> hours)."""
    thetas = sampling.latin_hypercube(40, seed=42)
    rts = gs2_proxy.runtime_table(thetas)
    assert rts.min() >= 60.0 and rts.max() <= 10_800.0
    assert rts.max() / rts.min() > 5.0
    its = [gs2_proxy.iteration_count(t) for t in thetas[:20]]
    assert max(its) / max(min(its), 1) > 3.0


def test_gs2_proxy_drive_increases_growth():
    """More temperature-gradient drive -> larger growth rate (physics
    sanity: eta drives micro-instability)."""
    base = np.array([4.0, 1.0, 3.0, 1.0, 0.05, 0.05, 0.4])
    hot = base.copy()
    hot[3] = 6.0
    g_lo, _ = gs2_proxy.evaluate(base)
    g_hi, _ = gs2_proxy.evaluate(hot)
    assert g_hi > g_lo


# --------------------------------------------------------------------------
# GP regression
# --------------------------------------------------------------------------
def test_gp_matches_closed_form():
    """Posterior mean/var must match a direct numpy evaluation of
    eqs. (3)/(4) with the same hyperparameters."""
    rng = np.random.default_rng(3)
    x = rng.random((12, 2))
    y = np.sin(3 * x[:, 0]) + x[:, 1]
    post = gp_lib.fit(x, y, steps=50)
    xs = rng.random((4, 2))
    mean, var = gp_lib.predict(post, xs)

    ls = np.exp(np.asarray(post.params.log_lengthscale))
    sf = np.exp(np.asarray(post.params.log_variance))
    s2 = np.exp(2 * np.asarray(post.params.log_noise))
    ystd = max(float(y.std()), 1e-8)

    def k(a, b):
        d2 = ((a[:, None] / ls - b[None] / ls) ** 2).sum(-1)
        return sf * np.exp(-0.5 * d2)

    kxx = k(x, x) + (s2 + 1e-5 * (sf + 1.0)) * np.eye(len(x))
    kxs = k(x, xs)
    yc = (y - y.mean()) / ystd
    mean_np = y.mean() + (kxs.T @ np.linalg.solve(kxx, yc)) * ystd
    var_np = (sf - np.sum(kxs * np.linalg.solve(kxx, kxs), axis=0)) * ystd ** 2
    np.testing.assert_allclose(np.asarray(mean)[:, 0], mean_np,
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(var)[:, 0], var_np,
                               atol=1e-3, rtol=2e-2)


def test_gp_interpolates_noiselessly():
    rng = np.random.default_rng(4)
    x = rng.random((25, 3))
    y = np.stack([np.cos(2 * x[:, 0]), x[:, 1] * x[:, 2]], 1)
    post = gp_lib.fit(x, y, steps=250)
    mean, var = gp_lib.predict(post, x)
    assert float(jnp.max(jnp.abs(mean - y))) < 0.05
    # posterior variance at training points << each output's prior variance
    prior = jnp.exp(post.params.log_variance) * post.y_std ** 2   # [M]
    assert bool(jnp.all(jnp.max(var, axis=0) < 0.2 * prior))


def test_gp_condition_shrinks_uncertainty():
    rng = np.random.default_rng(5)
    x = rng.random((10, 2))
    y = x[:, 0] ** 2
    post = gp_lib.fit(x, y, steps=80)
    x_new = np.array([[0.5, 0.5]])
    _, var_before = gp_lib.predict(post, x_new)
    post2 = gp_lib.condition(post, x_new, np.array([0.25]))
    _, var_after = gp_lib.predict(post2, x_new)
    assert float(var_after[0, 0]) < float(var_before[0, 0])


# --------------------------------------------------------------------------
# QoI integral
# --------------------------------------------------------------------------
def _cheap_model(x):
    """Analytic stand-in with the same (growth, freq) signature."""
    g = 0.3 * x[6] * (1.0 - x[6]) + 0.05 * np.sin(x[1])
    return float(g), float(0.1 * x[1])


def test_qoi_quadrature_converges():
    base = sampling.latin_hypercube(1, seed=6)[0]
    coarse = qoi.quadrature(_cheap_model, base, n_ky=4, n_theta0=4)
    fine = qoi.quadrature(_cheap_model, base, n_ky=16, n_theta0=16)
    finer = qoi.quadrature(_cheap_model, base, n_ky=24, n_theta0=24)
    assert abs(fine.value - finer.value) < abs(coarse.value - finer.value) + 1e-9
    assert finer.n_evals == 24 * 24


def test_qoi_bayesian_quadrature_tracks_direct():
    base = sampling.latin_hypercube(1, seed=7)[0]
    direct = qoi.quadrature(_cheap_model, base, n_ky=16, n_theta0=16)
    bq = qoi.bayesian_quadrature(_cheap_model, base, n_init=8,
                                 n_adaptive=10, seed=0)
    assert bq.n_evals == 18                    # 13x fewer than direct 256
    assert abs(bq.value - direct.value) < max(0.25 * abs(direct.value), 0.02)
    assert bq.uncertainty >= 0.0


# --------------------------------------------------------------------------
# eigen model
# --------------------------------------------------------------------------
def test_eigen_model_deterministic_and_sized():
    m = EigenModel(64)
    a = m([[0]])
    b = m([[0]])
    assert a == b
    assert m.get_output_sizes() == [2]
    assert m.cost_hint(None) > 0
