"""Scheduler tests: simulator determinism, paper-claim validation bands,
metric properties (hypothesis), live-executor behaviour."""
import time

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import workloads
from repro.core import (EvalRequest, Executor, LambdaModel, LoadBalancer,
                        backends, eval_records, metrics, simulate)
from repro.core.metrics import TaskRecord
from repro.core.simulator import Workload


def _run(bench: str, backend: str, q: int, seed: int = 7):
    w = workloads.make_workload(bench)
    recs = simulate(backends.get(backend), w, q, seed=seed)
    return metrics.summarize(bench, backend, eval_records(recs))


# --------------------------------------------------------------------------
# determinism + structural invariants
# --------------------------------------------------------------------------
def test_simulator_deterministic():
    a = _run("eigen-100", "slurm", 2, seed=3)
    b = _run("eigen-100", "slurm", 2, seed=3)
    assert a == b


def test_simulator_respects_queue_depth():
    w = workloads.make_workload("eigen-5000")
    recs = eval_records(simulate(backends.get("slurm"), w, 2, seed=1))
    # at any time at most 2 jobs in flight
    events = sorted([(r.submit_t, 1) for r in recs] +
                    [(r.end_t, -1) for r in recs])
    depth, worst = 0, 0
    for _, d in events:
        depth += d
        worst = max(worst, depth)
    assert worst <= 2


def test_timeout_mechanism():
    spec = backends.get("hq")
    w = Workload("t", runtimes=(10.0, 500.0), time_limit=60.0,
                 hq_alloc=600.0)
    recs = eval_records(simulate(spec, w, 1, seed=0))
    statuses = {r.task_id.split("-")[-1]: r.status for r in recs}
    assert statuses["0"] == "ok" and statuses["1"] == "timeout"
    assert max(r.cpu_time for r in recs) <= 60.0 + 1e-9


# --------------------------------------------------------------------------
# paper-claim validation (tolerance bands; EXPERIMENTS.md §Paper-validation)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("q", [2, 10])
def test_claim_gs2_makespan_reduction_38pct(q):
    s = _run("gs2", "slurm", q)
    h = _run("gs2", "hq", q)
    red = 1 - h.makespan / s.makespan
    assert 0.28 <= red <= 0.48, red          # paper: ~38 % both settings


def test_claim_overhead_three_orders():
    """Median per-job scheduling overhead drops by >= 3 orders of magnitude
    for the long-running workload (and >= ~500x even for eigen-100)."""
    for bench, floor in [("gs2", 1e3), ("eigen-5000", 1e3),
                         ("eigen-100", 300.0)]:
        s = _run(bench, "slurm", 2)
        h = _run(bench, "hq", 2)
        ratio = s.overhead_stats["median"] / max(h.overhead_stats["median"],
                                                 1e-9)
        assert ratio >= floor, (bench, ratio)


def test_claim_eigen100_hq_3x_quicker():
    s = _run("eigen-100", "slurm", 2)
    h = _run("eigen-100", "hq", 2)
    assert 2.0 <= s.makespan / h.makespan <= 6.0   # paper: "roughly 3x"


def test_claim_hq_loses_cpu_time_on_short_tasks():
    """The ~1 s server init makes HQ CPU time WORSE on eigen-100 (the
    paper's reported negative result) but better on GS2."""
    s100, h100 = _run("eigen-100", "slurm", 2), _run("eigen-100", "hq", 2)
    assert h100.total_cpu_time > s100.total_cpu_time
    sgs2, hgs2 = _run("gs2", "slurm", 10), _run("gs2", "hq", 10)
    assert hgs2.total_cpu_time < sgs2.total_cpu_time


def test_claim_slr_ordering():
    """HQ SLR is near the work-conserving bound; SLURM SLR is far above it
    on short tasks (Fig. 4)."""
    s = _run("eigen-100", "slurm", 2)
    h = _run("eigen-100", "hq", 2)
    assert h.slr < 2.0
    assert s.slr > 2.0 * h.slr


def test_claim_umb_slurm_no_gain():
    """Appendix A: the UM-Bridge SLURM backend is no better than naive."""
    for q in (2, 10):
        s = _run("gs2", "slurm", q)
        u = _run("gs2", "umb-slurm", q)
        assert u.makespan >= 0.95 * s.makespan


def test_hq_finishes_first_in_most_benchmarks():
    wins = 0
    cells = [(b, q) for b in workloads.BENCHMARKS for q in (2, 10)]
    for bench, q in cells:
        if _run(bench, "hq", q).makespan < _run(bench, "slurm", q).makespan:
            wins += 1
    assert wins >= 7, wins                     # paper: 'majority finished first'


# --------------------------------------------------------------------------
# metric properties (hypothesis)
# --------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 100), st.floats(0.01, 50),
                          st.floats(0, 10)), min_size=1, max_size=40))
def test_metrics_invariants(raw):
    recs = []
    for i, (submit, compute, ovh) in enumerate(raw):
        start = submit + ovh
        recs.append(TaskRecord(task_id=str(i), submit_t=submit,
                               start_t=start, end_t=start + compute,
                               cpu_time=compute, compute_t=compute))
    assert metrics.makespan(recs) >= 0
    assert metrics.scheduling_overhead(recs) >= 0
    assert all(r.overhead >= 0 for r in recs)
    s = metrics.summarize("x", "y", recs)
    assert s.total_cpu_time == pytest.approx(sum(r.cpu_time for r in recs))
    # makespan >= the longest single task
    assert s.makespan >= max(r.end_t - r.submit_t for r in recs) - 1e-9


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), q=st.sampled_from([1, 2, 5, 10]))
def test_simulator_records_are_consistent(seed, q):
    w = workloads.make_workload("eigen-100")
    recs = simulate(backends.get("hq"), w, q, seed=seed)
    for r in recs:
        assert r.end_t >= r.start_t >= r.submit_t - 1e-9
        assert r.cpu_time >= 0 and r.compute_t >= 0
        assert r.end_t - r.start_t == pytest.approx(r.cpu_time, abs=1e-6)


# --------------------------------------------------------------------------
# live executor
# --------------------------------------------------------------------------
def _toy_factory():
    time.sleep(0.02)
    return LambdaModel("toy", lambda p, c: [[float(p[0][0]) * 2]], 1, 1)


def test_executor_correct_values():
    with Executor({"toy": _toy_factory}, n_workers=4) as ex:
        res = ex.run_all([EvalRequest("toy", [[i]]) for i in range(30)])
        assert [r.value[0][0] for r in res] == [2.0 * i for i in range(30)]
        assert all(r.status == "ok" for r in res)


def test_executor_persistent_vs_fresh_init_cost():
    with Executor({"toy": _toy_factory}, n_workers=2) as ex:
        res = ex.run_all([EvalRequest("toy", [[i]]) for i in range(20)])
        hq_init = sum(r.init_t for r in res)
    with Executor({"toy": _toy_factory}, n_workers=2,
                  persistent_servers=False) as ex:
        res = ex.run_all([EvalRequest("toy", [[i]]) for i in range(20)])
        slurm_init = sum(r.init_t for r in res)
    assert slurm_init > 5 * hq_init


def test_executor_retry_and_fail():
    with Executor({"toy": _toy_factory}, n_workers=2, max_attempts=3) as ex:
        ok = ex.run_all([EvalRequest("toy", [[1]],
                                     config={"fail_attempts": 2})])[0]
        assert ok.status == "ok" and ok.attempts == 3
        bad = ex.run_all([EvalRequest("toy", [[1]],
                                      config={"fail_attempts": 99})])[0]
        assert bad.status == "failed"


def test_executor_worker_death_requeues():
    def slow():
        return LambdaModel("s", lambda p, c: (time.sleep(0.2), [[1.0]])[1],
                           1, 1)
    with Executor({"s": slow}, n_workers=2) as ex:
        ids = [ex.submit(EvalRequest("s", [[i]])) for i in range(6)]
        time.sleep(0.05)
        ex.kill_worker(0)
        res = [ex.result(t, timeout=30) for t in ids]
        assert all(r.status == "ok" for r in res)
        assert ex.n_workers() == 1


def test_executor_dependencies_order():
    order = []

    def dep():
        return LambdaModel(
            "d", lambda p, c: (order.append(p[0][0]), [[p[0][0]]])[1], 1, 1)
    with Executor({"d": dep}, n_workers=2) as ex:
        a = EvalRequest("d", [[1]])
        b = EvalRequest("d", [[2]], depends_on=(a.task_id,))
        c = EvalRequest("d", [[3]], depends_on=(b.task_id,))
        for r in (c, b, a):
            ex.submit(r)
        ex.result(c.task_id, 10)
    assert order == [1, 2, 3]


def test_executor_autoscale_and_snapshot():
    def slowcall():
        return LambdaModel(
            "toy", lambda p, c: (time.sleep(0.05), [[float(p[0][0])]])[1],
            1, 1)
    with Executor({"toy": slowcall}, n_workers=1, autoscale_backlog=3,
                  max_workers=4) as ex:
        ids = [ex.submit(EvalRequest("toy", [[i]])) for i in range(25)]
        [ex.result(t, 30) for t in ids]
        assert ex.n_workers() > 1
    with Executor({"toy": _toy_factory}, n_workers=1) as ex:
        ids = [ex.submit(EvalRequest("toy", [[i]])) for i in range(10)]
        ex.result(ids[0], 10)
        snap = ex.snapshot()
    ex2 = Executor.restore(snap, {"toy": _toy_factory}, n_workers=2)
    try:
        res = [ex2.result(t, 30) for t in ids]
        assert all(r.status == "ok" for r in res)
    finally:
        ex2.shutdown()


def test_executor_straggler_speculation():
    def var():
        return LambdaModel(
            "v", lambda p, c: (time.sleep(p[0][0]), [[1.0]])[1], 1, 1)
    with Executor({"v": var}, n_workers=3, straggler_factor=3.0,
                  straggler_min_completed=5) as ex:
        reqs = [EvalRequest("v", [[0.02]]) for _ in range(15)]
        reqs.append(EvalRequest("v", [[1.0]]))
        res = ex.run_all(reqs, timeout=60)
        assert all(r.status == "ok" for r in res)


def test_balancer_readiness_and_health():
    with LoadBalancer("hq", n_workers=2) as lb:
        info = lb.register_model("toy", _toy_factory)
        assert info.probes_run == 5
        assert lb.evaluate("toy", [[21]])[0][0] == 42.0
        assert lb.health_check("toy", [[1]])
        with pytest.raises(KeyError):
            lb.submit(EvalRequest("nope", [[1]]))
