"""Serving-path tests: bucketed prefill equivalence + scheduler wiring."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch.serve import LMServer, serve_benchmark
from repro.models import model as M


@pytest.mark.parametrize("arch", ["qwen3-14b", "minicpm3-4b", "rwkv6-3b",
                                  "zamba2-2.7b"])
def test_bucketed_generation_matches_teacher_forced(arch):
    """Right-padded bucketed prefill + cached decode must emit exactly the
    greedy tokens of repeated full forwards."""
    cfg = configs.get_reduced(arch)
    srv = LMServer(cfg, batch=1, max_len=64, seed=3)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (1, 6)).astype(np.int32)
    out = srv.generate(prompt, 4)

    toks = prompt.copy()
    ref = []
    for _ in range(4):
        logits, _, _ = M.forward(srv.params, {"tokens": jnp.asarray(toks)},
                                 cfg)
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        ref.append(nxt)
        toks = np.concatenate([toks, [[nxt]]], 1)
    assert out[0].tolist() == ref, arch


def test_bucket_sizes_are_powers_of_two():
    srv = LMServer.__new__(LMServer)
    srv.cfg = configs.get_reduced("qwen3-14b")
    srv.min_bucket, srv.max_len = 16, 256
    assert srv._bucket(5) == 16
    assert srv._bucket(16) == 16
    assert srv._bucket(17) == 32
    assert srv._bucket(300) == 256   # clamped to max_len
    # recurrent archs never pad
    srv.cfg = configs.get_reduced("rwkv6-3b")
    assert srv._bucket(5) == 5


def test_serve_benchmark_end_to_end():
    out = serve_benchmark("starcoder2-3b", n_requests=3, max_new=2,
                          n_workers=1, persistent=True, max_len=32,
                          reduced=True)
    assert out["tokens"] == 3 * 2
    assert out["summary"].n_tasks >= 3
