"""`repro.chaos`: deterministic fault injection + hardened recovery.

The contract under test: a seeded `FaultPlan` produces IDENTICAL fault
sequences — and identical recovery — in `simulate_cluster` and the live
replay driver, so `run_parity` stays exact with crashes, preemptions,
corrupted results, slow nodes and backoff-jittered requeues in play.
Plus the hardening satellites: torn-journal recovery, the conservation
`InvariantChecker`, quarantine thresholds, offload degradation wiring,
and the speculation/quarantine overhead-attribution components.
"""
from collections import Counter

import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.chaos import (ChaosInjector, FaultEvent, FaultPlan,
                         InvariantChecker, attach_chaos)
from repro.checkpoint.journal import Journal
from repro.cluster import AutoAllocConfig, TraceTask, simulate_cluster
from repro.cluster.parity import run_parity
from repro.core import backends
from repro.core.task import RetryPolicy
from repro.obs import Tracer, span_sequence
from repro.obs.calib import CalibrationMonitor
from repro.sched.offload import SurrogateOffload


def _elastic_cfg() -> AutoAllocConfig:
    return AutoAllocConfig(workers_per_alloc=2, walltime_s=300.0,
                           backlog_high_s=10.0, backlog_low_s=2.0,
                           max_pending=3, max_allocations=6,
                           min_allocations=1, idle_drain_s=30.0,
                           hysteresis_s=5.0)


def _hedge_trace():
    """14 short tasks + 2 stragglers: the queue drains, the stragglers
    run past 4x p95 and idle workers hedge them."""
    trace = [TraceTask(t=float(i) * 0.5, runtime=2.0) for i in range(14)]
    trace += [TraceTask(t=7.0, runtime=120.0),
              TraceTask(t=7.5, runtime=90.0)]
    return trace


# --------------------------------------------------------------------------
# FaultPlan / ChaosInjector mechanics
# --------------------------------------------------------------------------
def test_fault_plan_sorted_and_validated():
    plan = FaultPlan(events=(
        FaultEvent(t=20.0, kind="preempt", duration_s=30.0),
        FaultEvent(t=5.0, kind="worker_crash", target=3),
        FaultEvent(t=5.0, kind="worker_crash", target=1),
    ))
    assert [e.t for e in plan.events] == [5.0, 5.0, 20.0]
    assert [e.target for e in plan.events[:2]] == [1, 3]
    assert len(plan) == 3
    assert plan.kinds() == {"worker_crash": 2, "preempt": 1}
    with pytest.raises(ValueError):
        FaultEvent(t=0.0, kind="meteor_strike")


def test_fault_plan_roundtrip_and_seeded_generation():
    rates = {"worker_crash": 1 / 100.0, "preempt": 1 / 200.0}
    a = FaultPlan.generate(seed=11, horizon_s=500.0, rates=rates)
    b = FaultPlan.generate(seed=11, horizon_s=500.0, rates=rates)
    c = FaultPlan.generate(seed=12, horizon_s=500.0, rates=rates)
    assert a.events == b.events                 # seeded: reproducible
    assert a.events != c.events
    assert len(a) > 0
    assert FaultPlan.from_dicts(a.to_dicts()).events == a.events


def test_injector_fires_in_order_and_tracks_state():
    plan = FaultPlan(events=(
        FaultEvent(t=1.0, kind="worker_crash"),
        FaultEvent(t=2.0, kind="corrupt_result"),
        FaultEvent(t=9.0, kind="worker_crash"),
    ))
    inj = ChaosInjector(plan)
    seen = []
    inj.on("worker_crash", lambda ev, now: seen.append((ev.t, now)))
    assert inj.next_time() == 1.0
    assert inj.fire(5.0) == 2                  # crash + corrupt due
    assert seen == [(1.0, 5.0)]
    assert inj.take_corruption() is True       # pending counter consumed
    assert inj.take_corruption() is False
    assert inj.next_time() == 9.0
    inj.set_slow(wid=2, factor=3.0, until=20.0)
    assert inj.slow_factor(2, 10.0) == 3.0
    assert inj.slow_factor(2, 25.0) == 1.0     # expired, dropped
    assert inj.slow_factor(7, 10.0) == 1.0


def test_attach_chaos_arms_journal_torn_writes(tmp_path):
    class _FakeExecutor:
        workers = ()
        tracer = None
        _broker = None
        _stepper = None

    journal = Journal(tmp_path / "j")
    ex = _FakeExecutor()
    inj = attach_chaos(
        ex, FaultPlan(events=(FaultEvent(t=3.0, kind="journal_torn"),)),
        journal=journal)
    assert ex._chaos is inj
    assert journal.torn_next is False
    inj.fire(5.0)
    assert journal.torn_next is True


# --------------------------------------------------------------------------
# faulted differential parity: every recovery path, still exact
# --------------------------------------------------------------------------
def test_faulted_parity_exact_with_all_recovery_paths():
    """crash + preemption-with-migration + result corruption +
    straggler hedging in one run: sim and live agree on records, alloc
    events, billing AND span sequences, and every conservation
    invariant holds on both sides."""
    spec = backends.get("hq")
    plan = FaultPlan(events=(
        FaultEvent(t=12.0, kind="worker_crash", target=1),
        FaultEvent(t=20.0, kind="preempt", target=0, duration_s=15.0),
        FaultEvent(t=31.0, kind="corrupt_result", target=0),
    ))
    retry = RetryPolicy(base_s=1.0, factor=2.0, max_s=20.0, jitter=0.3,
                        quarantine_after=3)
    ts, tl = Tracer(), Tracer()
    rep = run_parity(spec, _hedge_trace(), autoalloc=_elastic_cfg(),
                     max_workers=12, seed=5, max_attempts=6,
                     fault_plan=plan, retry_policy=retry,
                     straggler_factor=4.0, straggler_min_completed=5,
                     tracers=(ts, tl))
    assert rep.ok, rep.divergences[:5]
    assert Counter(r.status for r in rep.sim.records) == {"ok": 16}

    counts = Counter(e[2] for e in ts.events())
    assert counts["chaos.fire"] == 3
    assert counts["task.requeue"] >= 1         # crash / corruption retry
    assert counts["task.migrate"] >= 1         # preemption grace drain
    assert counts["task.speculate"] >= 1       # straggler hedged
    assert counts["task.hedge_cancel"] >= 1    # loser cancelled
    # the observability layer inherits the no-forked-logic guarantee
    assert span_sequence(ts) == span_sequence(tl)

    checker = InvariantChecker()
    expected = [f"trace-{i}" for i in range(16)]
    for res, tr in ((rep.sim, ts), (rep.live, tl)):
        inv = checker.check(records=res.records,
                            allocations=res.allocations,
                            events=tr.events(), expected_tasks=expected)
        assert inv.ok, inv.violations[:5]


def test_backoff_jitter_requeue_timestamps_pinned():
    """The seeded differential test the issue asks for: with exponential
    backoff + jitter, both drivers emit bit-identical requeue release
    timestamps, and the poison task quarantines at the threshold."""
    spec = backends.get("hq")
    trace = [TraceTask(t=0.0, runtime=500.0)]
    plan = FaultPlan(events=tuple(
        FaultEvent(t=10.0 + 20.0 * i, kind="worker_crash", target=0)
        for i in range(4)))
    retry = RetryPolicy(base_s=1.0, factor=2.0, jitter=0.2,
                        quarantine_after=3)
    ts, tl = Tracer(), Tracer()
    rep = run_parity(spec, trace, n_workers=1, seed=2, max_attempts=10,
                     fault_plan=plan, retry_policy=retry,
                     tracers=(ts, tl))
    assert rep.ok, rep.divergences[:5]
    assert [r.status for r in rep.sim.records] == ["quarantined"]
    assert [r.status for r in rep.live.records] == ["quarantined"]

    def releases(tr):
        return [(e[6]["attempt"], e[6]["since"], e[6]["release"])
                for e in tr.events() if e[2] == "task.requeue"]

    # bit-exact, seeded: blake2b(f"{seed}:{task}:{attempt}") jitter on
    # an exponential base — pinned so refactors cannot silently change
    # the backoff schedule either driver observes
    expect = [(1, 0.0, 10.823104785525953),
              (2, 10.823104785525953, 32.146764199914315)]
    assert releases(ts) == expect
    assert releases(tl) == expect

    quarantined = [e for e in ts.events() if e[2] == "task.quarantined"]
    assert len(quarantined) == 1
    assert quarantined[0][6]["attempt"] == 3
    assert quarantined[0][6]["since"] == 32.146764199914315


def test_retry_policy_backoff_deterministic_and_bounded():
    r = RetryPolicy(base_s=2.0, factor=2.0, max_s=30.0, jitter=0.5)
    a = r.backoff_s("task-x", 3, seed=7)
    assert a == r.backoff_s("task-x", 3, seed=7)      # pure function
    assert a != r.backoff_s("task-x", 3, seed=8)      # seed matters
    assert a != r.backoff_s("task-y", 3, seed=7)      # task matters
    base = min(2.0 * 2.0 ** (3 - 1), 30.0)
    assert base * 0.5 <= a <= base * 1.5               # jitter bounded
    nojit = RetryPolicy(base_s=2.0, factor=2.0, max_s=30.0, jitter=0.0)
    assert nojit.backoff_s("t", 10, seed=0) == 30.0    # max_s cap


# --------------------------------------------------------------------------
# quarantine threshold: fires iff failures cross it
# --------------------------------------------------------------------------
def _crash_run(n_crashes: int, quarantine_after: int):
    # run_parity (not bare simulate_cluster): its static mode seeds a
    # zero-queue-wait allocation, so the crash times land inside the
    # task's run window — and every cell doubles as a parity check
    spec = backends.get("hq")
    plan = FaultPlan(events=tuple(
        FaultEvent(t=10.0 + 20.0 * i, kind="worker_crash", target=0)
        for i in range(n_crashes)))
    rep = run_parity(
        spec, [TraceTask(t=0.0, runtime=500.0)], n_workers=1, seed=2,
        max_attempts=10, fault_plan=plan,
        retry_policy=RetryPolicy(base_s=1.0, factor=2.0, jitter=0.2,
                                 quarantine_after=quarantine_after),
        walltime_s=3600.0)
    assert rep.ok, rep.divergences[:3]
    assert rep.sim.records[0].status == rep.live.records[0].status
    return rep.sim.records[0].status


def test_quarantine_fires_iff_threshold_crossed():
    """Every (crashes, threshold) cell: quarantined exactly when the
    fatal-failure count reaches the threshold, ok otherwise (the task
    always recovers when allowed to retry)."""
    for threshold in (1, 2, 3):
        for crashes in range(5):
            status = _crash_run(crashes, threshold)
            if crashes >= threshold:
                assert status == "quarantined", (crashes, threshold)
            else:
                assert status == "ok", (crashes, threshold)


@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=6))
@settings(max_examples=12, deadline=None)
def test_quarantine_threshold_property(threshold, crashes):
    status = _crash_run(crashes, threshold)
    assert status == ("quarantined" if crashes >= threshold else "ok")


# --------------------------------------------------------------------------
# journal: torn-write recovery + directory fsync
# --------------------------------------------------------------------------
def test_journal_survives_torn_writes(tmp_path):
    """Kill-mid-write loop: every other publish is torn (the chaos
    `journal_torn` fault), and `latest()` must fall back to the newest
    complete snapshot every time — zero lost state."""
    j = Journal(tmp_path / "j", keep=10)
    for i in range(6):
        j.write({"round": i})
        j.torn_next = True                     # next publish is torn
        j.write({"round": f"torn-{i}"})
        assert j.torn_next is False            # one-shot flag
        seq, state = j.latest()
        assert state == {"round": i}           # torn snapshot skipped
    # a cold restart over the littered directory recovers the same state
    j2 = Journal(tmp_path / "j", keep=10)
    _, state = j2.latest()
    assert state == {"round": 5}
    # and the next publish heals the tip
    j2.write({"round": 99})
    assert j2.latest()[1] == {"round": 99}


def test_journal_dir_fsync_is_tolerant(tmp_path):
    j = Journal(tmp_path / "j")
    path = j.write({"a": 1})
    assert path.exists()
    j._fsync_dir()                             # second sync: harmless
    assert j.latest()[1] == {"a": 1}


# --------------------------------------------------------------------------
# InvariantChecker: catches the bugs it exists for
# --------------------------------------------------------------------------
def test_invariant_checker_clean_run_passes():
    spec = backends.get("hq")
    tracer = Tracer()
    res = simulate_cluster(spec, _hedge_trace(), autoalloc=_elastic_cfg(),
                           max_workers=12, seed=5, max_attempts=6,
                           tracer=tracer)
    inv = InvariantChecker().check(
        records=res.records, allocations=res.allocations,
        events=tracer.events(),
        expected_tasks=[f"trace-{i}" for i in range(16)])
    assert inv.ok, inv.violations[:5]
    assert inv.measures["n_records"] == 16.0
    assert inv.measures["n_lost"] == 0.0
    assert inv.measures["billed_busy_s"] == \
        inv.measures["accounted_busy_s"]


def test_invariant_checker_flags_violations():
    spec = backends.get("hq")
    tracer = Tracer()
    res = simulate_cluster(spec, _hedge_trace(), autoalloc=_elastic_cfg(),
                           max_workers=12, seed=5, max_attempts=6,
                           tracer=tracer)
    checker = InvariantChecker()
    # duplicate terminal state for one task
    dup = checker.check(records=list(res.records) + [res.records[0]],
                        allocations=res.allocations,
                        events=tracer.events())
    assert not dup.ok
    # a submitted task with no terminal record = lost work
    missing = checker.check(records=res.records[:-1],
                            allocations=res.allocations,
                            events=tracer.events(),
                            expected_tasks=[f"trace-{i}"
                                            for i in range(16)])
    assert not missing.ok
    with pytest.raises(AssertionError):
        missing.assert_ok()


# --------------------------------------------------------------------------
# overhead attribution: quarantine component stays additive
# --------------------------------------------------------------------------
def test_quarantine_attribution_additive():
    spec = backends.get("hq")
    tracer = Tracer()
    rep = run_parity(
        spec, [TraceTask(t=0.0, runtime=500.0)], n_workers=1, seed=2,
        max_attempts=10, tracers=(tracer, Tracer()),
        fault_plan=FaultPlan(events=tuple(
            FaultEvent(t=10.0 + 20.0 * i, kind="worker_crash", target=0)
            for i in range(4))),
        retry_policy=RetryPolicy(base_s=1.0, factor=2.0, jitter=0.2,
                                 quarantine_after=3))
    assert rep.ok, rep.divergences[:3]
    res = rep.sim
    att = res.overhead_attribution
    bd = att["per_task"]["trace-0"]
    assert bd.status == "quarantined"
    assert bd.quarantine_s > 0                 # final burned attempt
    assert bd.retry_s > 0                      # backoff-extended burns
    assert bd.speculation_s == 0.0             # nothing hedged
    rec = res.records[0]
    assert abs(bd.overhead_s - rec.overhead) < 1e-6


# --------------------------------------------------------------------------
# offload degradation: outage faults + calibration drift alarms
# --------------------------------------------------------------------------
def test_offload_degradation_cycle_and_instants():
    tracer = Tracer()
    sur = SurrogateOffload(drift_disable_s=120.0)
    sur.tracer = tracer
    assert sur.degraded_until is None
    sur.set_degraded(10.0, 40.0, reason="outage")
    assert sur.degraded_until == 40.0
    sur.set_degraded(12.0, 50.0, reason="outage")   # extend: no new edge
    sur.tick_degraded(30.0)                         # too early: no-op
    assert sur.degraded_until == 50.0
    sur.tick_degraded(50.0)                         # re-arm
    assert sur.degraded_until is None
    edges = [e[6] for e in tracer.events()
             if e[2] == "offload.degraded"]
    assert edges == [{"degraded": True, "reason": "outage"},
                     {"degraded": False, "reason": "outage"}]


def test_calib_drift_alarm_degrades_offload():
    """Satellite 1 end-to-end: a drifting cost model raises `calib.drift`,
    the monitor's `on_alarm` hook cools the offload engine off, and the
    stepper-driven tick re-arms it after `drift_disable_s`."""
    spec = backends.get("hq")
    sur = SurrogateOffload(drift_disable_s=100.0)
    mon = CalibrationMonitor(spec, min_n=4, on_alarm=sur.note_drift_alarm)
    for i in range(6):                         # observed 4x predicted
        mon.observe("init", 1.0, 4.0, float(i))
    assert mon.alarms, "drift alarm did not fire"
    assert sur.degraded_until is not None
    assert sur.degraded_reason == "drift:init"
    t_alarm = mon.alarms[0]["t"]
    assert sur.degraded_until == t_alarm + 100.0
    sur.tick_degraded(sur.degraded_until)
    assert sur.degraded_until is None


def test_surrogate_outage_fault_degrades_and_rearms():
    """A `surrogate_outage` fault disables offload for its duration in
    the simulator; the stepper re-arms it at the same virtual instant
    on both drivers (here: sim side, via the degraded tick)."""
    calls = []

    class _FakeSurrogate:
        latency_s = 0.05
        n_virtual_workers = 1
        tracer = None
        degraded_until = None

        def decide(self, req, cost=None):
            return False

        def note_served(self):
            pass

        def observe(self, *a, **kw):
            pass

        def set_degraded(self, now, until, reason="outage"):
            calls.append(("set", now, until, reason))
            self.degraded_until = until

        def tick_degraded(self, now):
            if self.degraded_until is not None \
                    and now >= self.degraded_until:
                calls.append(("rearm", now))
                self.degraded_until = None

    from repro.cluster import Broker
    broker = Broker()
    broker.attach_surrogate(_FakeSurrogate())
    spec = backends.get("hq")
    res = simulate_cluster(
        spec, _hedge_trace(), broker=broker, autoalloc=_elastic_cfg(),
        max_workers=12, seed=5, max_attempts=6,
        fault_plan=FaultPlan(events=(
            FaultEvent(t=15.0, kind="surrogate_outage", duration_s=40.0),
        )))
    assert Counter(r.status for r in res.records)["ok"] == 16
    sets = [c for c in calls if c[0] == "set"]
    rearms = [c for c in calls if c[0] == "rearm"]
    assert sets == [("set", 15.0, 55.0, "outage")]
    assert len(rearms) == 1 and rearms[0][1] >= 55.0
