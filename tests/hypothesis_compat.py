"""Optional-hypothesis shim.

The container does not ship `hypothesis`; an unconditional import made
four test modules fail COLLECTION, taking all their non-property tests
down with them.  Importing `given`/`settings`/`st` from here instead
degrades gracefully: with hypothesis installed the real objects are
re-exported; without it, property tests become cleanly-skipped zero-arg
stubs and every other test in the module still runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Stands in for `strategies`: any attribute is a factory whose
        result can itself be composed (st.lists(st.floats(...)))."""

        def __getattr__(self, _name):
            return lambda *a, **k: _AnyStrategy()

    st = _AnyStrategy()
