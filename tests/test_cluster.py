"""repro.cluster tests: allocation lifecycle, broker routing/migration,
autoalloc hysteresis, simulate_cluster determinism + elasticity, the
live-executor seam, and the satellite fixes (snapshot round-trip, EDF)."""
import time

import pytest

from repro.cluster import (AutoAllocConfig, AutoAllocator, Allocation,
                           Broker, bursty_trace, bimodal_trace,
                           simulate_cluster)
from repro.core import (EvalRequest, Executor, LambdaModel, backends,
                        metrics)
from repro.sched import EDFPolicy, make_policy


def _req(cost=None, model="m", task_id="", deadline=None):
    return EvalRequest(model, [[0.0]], time_request=cost, task_id=task_id,
                       deadline=deadline)


# --------------------------------------------------------------------------
# allocation lifecycle
# --------------------------------------------------------------------------
def test_allocation_lifecycle_states():
    a = Allocation(0, n_workers=2, walltime_s=100.0)
    assert a.state == "pending" and a.budget_left(0.0) == 100.0
    a.submit(10.0, queue_wait=5.0)
    assert a.state == "queued" and a.grant_t == 15.0 and a.expiry_t == 115.0
    assert a.tick(12.0) == "queued"            # still in the SLURM queue
    assert a.tick(15.0) == "running" and a.ready_t == 15.0
    assert a.budget_left(65.0) == pytest.approx(50.0)
    a.drain(70.0)
    assert a.state == "draining" and not a.open
    assert a.tick(115.0) == "expired"          # walltime still enforced
    assert a.end_t == 115.0
    assert a.node_seconds() == pytest.approx(2 * 100.0)


def test_allocation_drain_while_queued_cancels():
    a = Allocation(1, 4, 300.0).submit(0.0, queue_wait=60.0)
    a.drain(10.0)                              # cancelled before grant
    assert a.state == "expired" and a.node_seconds() == 0.0


def test_allocation_terminate_early_stops_billing():
    a = Allocation(2, 2, 1000.0).submit(0.0, 0.0)
    a.tick(0.0)
    a.note_busy(30.0)
    a.terminate(50.0)                          # drained dry at t=50
    assert a.node_seconds() == pytest.approx(100.0)
    rec = a.record()
    assert rec.busy_t == pytest.approx(30.0) and rec.state == "expired"


def test_allocation_unbounded_budget_is_none():
    a = Allocation(3, 1, None).submit(0.0, 0.0)
    a.tick(0.0)
    assert a.budget_left(1e6) is None          # pack degrades to LPT


# --------------------------------------------------------------------------
# broker: routing, migration, stealing
# --------------------------------------------------------------------------
def _running_alloc(broker, n_workers=1, walltime=1000.0, t=0.0):
    a = Allocation(broker.next_alloc_id(), n_workers, walltime)
    a.submit(t, 0.0)
    a.tick(t)
    broker.add_allocation(a)
    return a


def test_broker_registered_and_rejects_shared_instance():
    assert type(make_policy("broker")) is Broker
    with pytest.raises(TypeError):
        Broker(policy=make_policy("sjf"))
    with pytest.raises(TypeError):
        Broker(policy="broker")                # no brokers-in-brokers
    with pytest.raises(TypeError):
        b = Broker(policy=Broker)              # factory sneaking one in
        _running_alloc(b)


def test_broker_backlog_cost_tracks_composition_changes():
    """Pop a cheap task, push an expensive one: the total must move even
    though the queue length is unchanged (regression: a (len, version)
    cache key missed exactly this)."""
    from repro.sched import WorkerView
    b = Broker()
    a = _running_alloc(b)
    b.push(_req(cost=1.0, task_id="cheap"), 1)
    assert b.backlog_cost() == pytest.approx(1.0)
    assert b.pop(WorkerView(wid=0, alloc_id=a.alloc_id))[0].task_id \
        == "cheap"
    b.push(_req(cost=500.0, task_id="dear"), 1)
    assert b.backlog_cost() == pytest.approx(500.0)
    # cost survives routing moves: drain to a second allocation
    a2 = _running_alloc(b)
    b.drain_allocation(a.alloc_id, now=1.0)
    assert b.queued_on(a2.alloc_id) == 1
    assert b.backlog_cost() == pytest.approx(500.0)
    assert b.pop(WorkerView(wid=1, alloc_id=a2.alloc_id)) is not None
    assert b.backlog_cost() == pytest.approx(0.0)


def test_executor_broker_policy_with_autoalloc_serves():
    """policy='broker' + autoalloc must not nest brokers (regression:
    tasks once parked in an inner unrouted buffer forever)."""
    cfg = AutoAllocConfig(workers_per_alloc=1, walltime_s=None,
                          backlog_high_s=3.0, max_allocations=2,
                          min_allocations=1, idle_drain_s=30.0,
                          hysteresis_s=0.05)
    with Executor({"toy": _toy_factory}, n_workers=1, policy="broker",
                  autoalloc=cfg) as ex:
        res = ex.run_all([EvalRequest("toy", [[i]]) for i in range(6)], 30)
        assert [r.value[0][0] for r in res] == [2.0 * i for i in range(6)]


def test_sim_cluster_honors_allocator_via_autoalloc_kwarg():
    """An AutoAllocator instance passed as autoalloc= must be used, not
    silently replaced with a default config."""
    spec = backends.get("hq")
    allocator = AutoAllocator(_elastic_cfg(workers_per_alloc=3),
                              spec=spec, seed=2)
    trace = bursty_trace(n_bursts=1, burst_size=6, runtime_s=5.0, seed=2)
    res = simulate_cluster(spec, trace, autoalloc=allocator, seed=2)
    assert all(r.status == "ok" for r in res.records)
    assert all(a.n_workers == 3 for a in res.allocations)
    with pytest.raises(TypeError):
        simulate_cluster(spec, trace, autoalloc=42, seed=2)


def test_broker_unrouted_buffer_flushes_on_capacity():
    b = Broker()
    b.push(_req(task_id="t0"), 1)              # no allocation yet
    assert len(b) == 1 and b.backlog_cost(default=2.0) == 2.0
    a = _running_alloc(b)
    assert b.queued_on(a.alloc_id) == 1        # flushed on add
    from repro.sched import WorkerView
    item = b.pop(WorkerView(wid=0, alloc_id=a.alloc_id))
    assert item[0].task_id == "t0"


def test_broker_affinity_and_least_loaded_routing():
    b = Broker()
    a0 = _running_alloc(b)
    a1 = _running_alloc(b)
    b.push(_req(cost=10.0, model="gs2", task_id="g0"), 1)
    first = a0.alloc_id if b.queued_on(a0.alloc_id) else a1.alloc_id
    b.push(_req(cost=10.0, model="gs2", task_id="g1"), 1)
    assert b.queued_on(first) == 2             # affinity: same model sticks
    b.push(_req(cost=1.0, model="eig", task_id="e0"), 1)
    other = a1.alloc_id if first == a0.alloc_id else a0.alloc_id
    assert b.queued_on(other) == 1             # new model -> least loaded


def test_broker_drain_migrates_queue():
    b = Broker()
    a0 = _running_alloc(b)
    a1 = _running_alloc(b)
    for i in range(3):
        b.push(_req(model="m", task_id=f"t{i}"), 1)
    src = a0 if b.queued_on(a0.alloc_id) else a1
    dst = a1 if src is a0 else a0
    assert b.queued_on(src.alloc_id) == 3
    b.drain_allocation(src.alloc_id, now=10.0)
    assert src.state == "draining"
    assert b.queued_on(src.alloc_id) == 0      # migrated, nothing stranded
    assert b.queued_on(dst.alloc_id) == 3
    assert len(b) == 3


def test_broker_remove_last_allocation_parks_tasks_unrouted():
    b = Broker()
    a0 = _running_alloc(b)
    b.push(_req(task_id="t0"), 1)
    b.remove_allocation(a0.alloc_id, now=5.0)
    assert b.allocation(a0.alloc_id) is None
    assert len(b) == 1                         # parked, not lost
    a1 = _running_alloc(b)
    assert b.queued_on(a1.alloc_id) == 1


def test_broker_cluster_level_stealing_moves_affinity():
    from repro.sched import WorkerView
    b = Broker()
    a0 = _running_alloc(b)
    a1 = _running_alloc(b)
    b.push(_req(cost=5.0, model="gs2", task_id="g0"), 1)
    loaded = a0 if b.queued_on(a0.alloc_id) else a1
    idle = a1 if loaded is a0 else a0
    # a worker of the idle allocation steals from the loaded one
    item = b.pop(WorkerView(wid=9, alloc_id=idle.alloc_id))
    assert item[0].task_id == "g0"
    b.push(_req(cost=5.0, model="gs2", task_id="g1"), 1)
    assert b.queued_on(idle.alloc_id) == 1     # affinity followed the thief

    # draining allocations are handed nothing
    b.drain_allocation(idle.alloc_id, now=1.0)
    assert b.pop(WorkerView(wid=9, alloc_id=idle.alloc_id)) is None


# --------------------------------------------------------------------------
# autoallocator decisions
# --------------------------------------------------------------------------
def _cfg(**kw):
    base = dict(workers_per_alloc=1, walltime_s=100.0, backlog_high_s=30.0,
                backlog_low_s=5.0, max_pending=2, max_allocations=4,
                min_allocations=0, idle_drain_s=10.0, hysteresis_s=5.0)
    base.update(kw)
    return AutoAllocConfig(**base)


def test_autoalloc_bootstraps_cold_cluster():
    b = Broker()
    aa = AutoAllocator(_cfg())
    b.push(_req(cost=1.0), 1)                  # tiny backlog, below watermark
    actions = aa.step(0.0, b, {})
    assert [a for a, _ in actions] == ["submit"]


def test_autoalloc_grows_on_backlog_cost_not_count():
    b = Broker()
    aa = AutoAllocator(_cfg())
    _running_alloc(b)
    # ONE task of 500 s is over the watermark even though the count is 1
    b.push(_req(cost=500.0), 1)
    assert [a for a, _ in aa.step(0.0, b, {0: 1})] == ["submit"]
    # many tiny tasks under the watermark trigger nothing
    b2 = Broker()
    aa2 = AutoAllocator(_cfg())
    _running_alloc(b2)
    for i in range(20):
        b2.push(_req(cost=1.0, task_id=f"s{i}"), 1)
    assert aa2.step(0.0, b2, {0: 1}) == []


def test_autoalloc_max_pending_cap():
    b = Broker()
    aa = AutoAllocator(_cfg(max_pending=1, hysteresis_s=0.0))
    # a pending (queued, not yet granted) allocation counts against the cap
    queued = Allocation(b.next_alloc_id(), 1, 100.0).submit(0.0, 50.0)
    b.add_allocation(queued)
    b.push(_req(cost=500.0), 1)
    assert aa.step(1.0, b, {}) == []           # capped
    queued.tick(60.0)                          # granted now
    assert [a for a, _ in aa.step(60.0, b, {queued.alloc_id: 1})] \
        == ["submit"]


def test_autoalloc_drains_idle_allocation():
    b = Broker()
    aa = AutoAllocator(_cfg(idle_drain_s=10.0, hysteresis_s=0.0))
    a0 = _running_alloc(b)
    aa.step(0.0, b, {a0.alloc_id: 0})          # idle starts being tracked
    assert a0.state == "running"
    aa.step(9.0, b, {a0.alloc_id: 0})          # not idle long enough
    assert a0.state == "running"
    aa.step(10.0, b, {a0.alloc_id: 0})
    assert a0.state == "draining"
    assert aa.decisions[-1]["action"] == "drain"


def test_autoalloc_busy_resets_idle_clock():
    b = Broker()
    aa = AutoAllocator(_cfg(idle_drain_s=10.0, hysteresis_s=0.0))
    a0 = _running_alloc(b)
    aa.step(0.0, b, {a0.alloc_id: 0})
    aa.step(8.0, b, {a0.alloc_id: 1})          # got busy again
    aa.step(12.0, b, {a0.alloc_id: 0})         # idle clock restarted at 12
    assert a0.state == "running"
    aa.step(22.0, b, {a0.alloc_id: 0})
    assert a0.state == "draining"


def test_autoalloc_respects_min_allocations():
    b = Broker()
    aa = AutoAllocator(_cfg(min_allocations=1, hysteresis_s=0.0))
    a0 = _running_alloc(b)
    for t in (0.0, 20.0, 40.0):
        aa.step(t, b, {a0.alloc_id: 0})
    assert a0.state == "running"               # never drained below the floor


def test_autoalloc_hysteresis_no_flapping():
    """Oscillating backlog (over the high watermark one step, empty the
    next) must not produce one decision per oscillation: the hysteresis
    window bounds the decision rate."""
    b = Broker()
    aa = AutoAllocator(_cfg(hysteresis_s=10.0, max_allocations=64,
                            max_pending=64, idle_drain_s=2.0))
    _running_alloc(b)
    from repro.sched import WorkerView
    big = 0
    for step in range(100):                    # 100 s of 1 Hz oscillation
        t = float(step)
        if step % 2 == 0:
            b.push(_req(cost=500.0, task_id=f"osc-{big}"), 1)
            big += 1
        else:
            while True:                        # drain the queue entirely
                item = b.pop(WorkerView(wid=0, alloc_id=0))
                if item is None:
                    break
        aa.step(t, b, {a.alloc_id: 0 for a in b.allocations()})
    # without hysteresis this would be ~50 submits; the window caps it
    assert len(aa.decisions) <= 100 / 10.0 + 1, len(aa.decisions)


# --------------------------------------------------------------------------
# simulate_cluster: determinism, renewal, elasticity
# --------------------------------------------------------------------------
def _elastic_cfg(**kw):
    base = dict(workers_per_alloc=2, walltime_s=300.0, backlog_high_s=30.0,
                backlog_low_s=5.0, max_pending=2, max_allocations=4,
                min_allocations=0, idle_drain_s=20.0, hysteresis_s=5.0)
    base.update(kw)
    return AutoAllocConfig(**base)


def test_sim_cluster_static_deterministic():
    spec = backends.get("hq")
    trace = bimodal_trace(n=30, seed=4)
    a = simulate_cluster(spec, trace, n_workers=3, seed=9)
    b = simulate_cluster(spec, trace, n_workers=3, seed=9)
    assert a.records == b.records and a.allocations == b.allocations
    assert len(a.records) == 30
    assert all(r.status == "ok" for r in a.records)


def test_sim_cluster_renewal_and_drain_deterministic():
    """Short walltimes force expiry + renewal while the trace continues;
    idle gaps force drains.  Same seed -> identical records, allocation
    records, and decision log."""
    spec = backends.get("hq")
    trace = bursty_trace(n_bursts=3, burst_size=10, gap_s=400.0,
                         runtime_s=15.0, seed=2)
    kw = dict(autoalloc=_elastic_cfg(), seed=7)
    a = simulate_cluster(spec, trace, **kw)
    b = simulate_cluster(spec, trace, **kw)
    assert a.records == b.records
    assert a.allocations == b.allocations
    assert a.decisions == b.decisions
    assert len(a.allocations) >= 3             # renewed across bursts
    assert {d["action"] for d in a.decisions} == {"submit", "drain"}
    ids = [r.task_id for r in a.records]
    assert len(ids) == len(set(ids)) == len(trace)


def test_sim_cluster_walltime_kill_requeues():
    """A task still running at allocation expiry is killed and restarted
    on renewed capacity (attempts > 1), not lost."""
    spec = backends.get("hq")
    trace = bursty_trace(n_bursts=1, burst_size=4, burst_span_s=1.0,
                         runtime_s=40.0, jitter=0.0, seed=0)
    cfg = _elastic_cfg(workers_per_alloc=1, walltime_s=60.0,
                       idle_drain_s=50.0)
    res = simulate_cluster(spec, trace, autoalloc=cfg, seed=3,
                           max_attempts=6)
    assert all(r.status == "ok" for r in res.records)
    assert len(res.records) == 4
    assert max(r.attempts for r in res.records) > 1
    assert len(res.allocations) > 1            # capacity was renewed


def test_sim_cluster_unservable_tasks_get_lost_records():
    """A static pool whose only allocation expires with work queued must
    surface the loss as 'lost' records, never shrink the record set."""
    spec = backends.get("hq")
    trace = bursty_trace(n_bursts=1, burst_size=6, burst_span_s=1.0,
                         runtime_s=50.0, jitter=0.0, seed=0)
    res = simulate_cluster(spec, trace, n_workers=1, walltime_s=60.0,
                           seed=0)
    assert len(res.records) == 6               # every task accounted for
    by_status = {}
    for r in res.records:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    assert by_status.get("lost", 0) >= 1
    s = res.summary()
    assert s["n_tasks"] == 6 and s["n_ok"] < 6  # loss is visible


def test_allocation_resize_bills_time_weighted():
    """scale_to-style resizes must not rewrite already-billed history:
    1 worker for 100 s then 4 workers for 10 s is 140 node-seconds, not
    4 x 110."""
    a = Allocation(0, 1, None).submit(0.0, 0.0)
    a.tick(0.0)
    a.resize(4, 100.0)
    a.terminate(110.0)
    assert a.node_seconds() == pytest.approx(1 * 100.0 + 4 * 10.0)
    assert a.record().node_s == pytest.approx(140.0)
    assert metrics.node_seconds([a.record()]) == pytest.approx(140.0)


def test_executor_max_workers_caps_autoalloc():
    """The documented pool cap binds allocator-granted groups too."""
    cfg = AutoAllocConfig(workers_per_alloc=8, walltime_s=None,
                          backlog_high_s=1.0, backlog_low_s=0.5,
                          max_pending=8, max_allocations=8,
                          min_allocations=1, idle_drain_s=30.0,
                          hysteresis_s=0.05)
    with Executor({"toy": _slow_factory}, n_workers=1, autoalloc=cfg,
                  max_workers=3) as ex:
        ids = [ex.submit(EvalRequest("toy", [[i]])) for i in range(40)]
        peak = 0
        res = []
        for t in ids:
            res.append(ex.result(t, 60))
            peak = max(peak, ex.n_workers())
        assert all(r.status == "ok" for r in res)
        assert peak <= 3, peak


def test_sim_cluster_elasticity_claim():
    """The acceptance criterion, at test size: autoalloc spends fewer
    node-seconds than the best static pool at <= 10 % makespan penalty."""
    spec = backends.get("hq")
    statics = {}
    for n in (2, 4, 8):
        trace = bursty_trace(n_bursts=3, burst_size=12, gap_s=500.0,
                             runtime_s=15.0, seed=5)
        span = max(t.t for t in trace)
        res = simulate_cluster(spec, trace, n_workers=n,
                               walltime_s=span + 1200.0, seed=5)
        assert all(r.status == "ok" for r in res.records)
        statics[n] = res.summary()
    trace = bursty_trace(n_bursts=3, burst_size=12, gap_s=500.0,
                         runtime_s=15.0, seed=5)
    auto = simulate_cluster(spec, trace, autoalloc=_elastic_cfg(),
                            seed=5).summary()
    best = min(statics.values(), key=lambda s: s["makespan"])
    assert auto["node_seconds"] < best["node_seconds"]
    assert auto["makespan"] <= 1.10 * best["makespan"]


def test_sim_cluster_same_objects_as_executor():
    """No forked decision logic: explicitly constructed Broker and
    AutoAllocator instances drive the simulator; the executor accepts
    the same types (instance-level for the allocator)."""
    spec = backends.get("hq")
    broker = Broker(policy="pack")
    allocator = AutoAllocator(_elastic_cfg(), spec=spec, seed=1)
    trace = bursty_trace(n_bursts=2, burst_size=8, gap_s=300.0,
                         runtime_s=10.0, seed=1)
    res = simulate_cluster(spec, trace, broker=broker, allocator=allocator,
                           seed=1)
    assert all(r.status == "ok" for r in res.records)
    assert res.decisions is not None and len(allocator.decisions) > 0
    assert len(broker) == 0                    # the instance WAS the queue

    allocator2 = AutoAllocator(_elastic_cfg(min_allocations=1,
                                            hysteresis_s=0.05,
                                            backlog_high_s=3.0,
                                            idle_drain_s=30.0))
    with Executor({"toy": _toy_factory}, n_workers=1, policy="pack",
                  autoalloc=allocator2) as ex:
        assert ex.autoalloc is allocator2      # same object, live clock
        assert isinstance(ex.policy, Broker)
        res = ex.run_all([EvalRequest("toy", [[i]]) for i in range(8)])
        assert all(r.status == "ok" for r in res)


# --------------------------------------------------------------------------
# live executor: allocation-backed elasticity
# --------------------------------------------------------------------------
def _toy_factory():
    time.sleep(0.01)
    return LambdaModel("toy", lambda p, c: [[float(p[0][0]) * 2]], 1, 1)


def _slow_factory():
    return LambdaModel(
        "toy", lambda p, c: (time.sleep(0.03), [[float(p[0][0]) * 2]])[1],
        1, 1)


def test_executor_autoalloc_grows_and_drains():
    cfg = AutoAllocConfig(workers_per_alloc=2, walltime_s=None,
                          backlog_high_s=3.0, backlog_low_s=1.0,
                          max_pending=4, max_allocations=4,
                          min_allocations=1, idle_drain_s=0.2,
                          hysteresis_s=0.05)
    with Executor({"toy": _slow_factory}, n_workers=1, autoalloc=cfg) as ex:
        ids = [ex.submit(EvalRequest("toy", [[i]])) for i in range(40)]
        res = [ex.result(t, 60) for t in ids]
        assert [r.value[0][0] for r in res] == [2.0 * i for i in range(40)]
        grew = ex.metrics()["allocations_total"] > 1
        assert grew                            # backlog cost forced growth
        # generous deadline: drains need several monitor passes and CI
        # machines can starve the monitor thread for whole seconds
        deadline = time.monotonic() + 30.0
        while ex.n_workers() > 1 and time.monotonic() < deadline:
            time.sleep(0.05)                   # idle drain shrinks back
        assert ex.n_workers() == 1
        assert any(d["action"] == "drain" for d in ex.autoalloc.decisions)
    recs = ex.allocation_records()
    assert metrics.node_seconds(recs) > 0
    assert 0.0 < metrics.allocation_utilization(recs) <= 1.0


def test_executor_autoscale_backlog_alias_routes_through_autoalloc():
    with Executor({"toy": _slow_factory}, n_workers=1, autoscale_backlog=3,
                  max_workers=4) as ex:
        assert ex.autoalloc is not None        # alias, not the old loop
        assert isinstance(ex.policy, Broker)
        ids = [ex.submit(EvalRequest("toy", [[i]])) for i in range(30)]
        res = [ex.result(t, 30) for t in ids]
        assert all(r.status == "ok" for r in res)
        assert ex.n_workers() > 1
        assert ex.n_workers() <= 4             # max_workers still honoured


def test_autoalloc_absolute_backlog_mode():
    """per_worker=False (the autoscale_backlog alias semantics): the
    watermark sees TOTAL queued seconds, undivided by capacity."""
    b = Broker()
    aa = AutoAllocator(_cfg(backlog_high_s=3.0, per_worker=False))
    _running_alloc(b, n_workers=4)             # plenty of capacity
    for i in range(10):
        b.push(_req(cost=1.0, task_id=f"a{i}"), 1)
    # 10 total > 3 triggers even though 10/4 = 2.5 per worker would not
    assert [a for a, _ in aa.step(0.0, b, {0: 4})] == ["submit"]


def test_executor_autoscale_alias_grows_wide_pools():
    """The legacy trigger was an ABSOLUTE count: a 4-worker pool with
    backlog 10 > 3 must still grow (per-worker division would stall)."""
    with Executor({"toy": _slow_factory}, n_workers=4, autoscale_backlog=3,
                  max_workers=6) as ex:
        ids = [ex.submit(EvalRequest("toy", [[i]])) for i in range(60)]
        res = [ex.result(t, 60) for t in ids]
        assert all(r.status == "ok" for r in res)
        assert ex.metrics()["allocations_total"] > 1
        assert ex.n_workers() <= 6


def test_executor_scale_to_after_full_drain_still_serves():
    """scale_to must never pin workers to a retired allocation: after
    autoalloc drains every group, manual scale-up brings up a fresh open
    group whose workers actually receive work."""
    cfg = AutoAllocConfig(workers_per_alloc=1, walltime_s=None,
                          backlog_high_s=3.0, backlog_low_s=1.0,
                          max_allocations=2, min_allocations=0,
                          idle_drain_s=0.1, hysteresis_s=0.05)
    with Executor({"toy": _toy_factory}, n_workers=1, autoalloc=cfg) as ex:
        res = ex.run_all([EvalRequest("toy", [[i]]) for i in range(4)], 30)
        assert all(r.status == "ok" for r in res)
        deadline = time.monotonic() + 30.0     # idle drain removes ALL groups
        while ex.n_workers() > 0 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert ex.n_workers() == 0
        ex.scale_to(2)                         # must create an OPEN group
        assert ex.n_workers() == 2
        res = ex.run_all([EvalRequest("toy", [[i]]) for i in range(6)], 30)
        assert [r.value[0][0] for r in res] == [2.0 * i for i in range(6)]


def test_executor_walltime_kill_counts_attempts_like_sim():
    """Retiring an expired allocation charges the running task an attempt
    (sim semantics): at max_attempts=1 the kill records a 'failed' result
    instead of resetting the counter and retrying forever."""
    def sleepy():
        return LambdaModel(
            "s", lambda p, c: (time.sleep(3.0), [[1.0]])[1], 1, 1)
    with Executor({"s": sleepy}, n_workers=1, policy="broker",
                  allocation_s=0.3, max_attempts=1) as ex:
        tid = ex.submit(EvalRequest("s", [[0.0]]))
        res = ex.result(tid, timeout=2.0)      # well before the 3 s sleep
        assert res.status == "failed"
        assert "allocation expired" in res.error
        assert res.attempts == 1


def test_executor_at_cap_does_not_churn_allocations():
    """An allocator at the worker cap must stop submitting, not cycle
    submit -> zero-headroom cancel every hysteresis period."""
    cfg = AutoAllocConfig(workers_per_alloc=2, walltime_s=None,
                          backlog_high_s=1.0, backlog_low_s=0.5,
                          max_pending=8, max_allocations=8,
                          min_allocations=1, idle_drain_s=30.0,
                          hysteresis_s=0.05)
    with Executor({"toy": _slow_factory}, n_workers=1, autoalloc=cfg,
                  max_workers=1) as ex:
        assert ex.autoalloc.worker_cap == 1
        ids = [ex.submit(EvalRequest("toy", [[i]])) for i in range(20)]
        res = [ex.result(t, 30) for t in ids]
        assert all(r.status == "ok" for r in res)
        assert ex.metrics()["allocations_total"] == 1   # no phantom grants
        assert not any(d["action"] == "submit"
                       for d in ex.autoalloc.decisions)


def test_executor_respects_request_max_attempts():
    """Live parity with the sim: the request's own max_attempts bounds
    retries (jointly with the executor-wide limit)."""
    with Executor({"toy": _toy_factory}, n_workers=2, max_attempts=5) as ex:
        res = ex.run_all([EvalRequest("toy", [[1]], max_attempts=2,
                                      config={"fail_attempts": 99})], 30)[0]
        assert res.status == "failed"
        assert res.attempts == 2


def test_executor_cluster_snapshot_restore():
    """Checkpoint-restart straight through the broker's multi-queue."""
    with Executor({"toy": _toy_factory}, n_workers=1, policy="broker") as ex:
        ids = [ex.submit(EvalRequest("toy", [[i]])) for i in range(8)]
        ex.result(ids[0], 10)
        snap = ex.snapshot()
    ex2 = Executor.restore(snap, {"toy": _toy_factory}, n_workers=2,
                           policy="broker")
    try:
        res = [ex2.result(t, 30) for t in ids]
        assert all(r.status == "ok" for r in res)
    finally:
        ex2.shutdown()


# --------------------------------------------------------------------------
# satellite: snapshot round-trip preserves ALL request fields + deps
# --------------------------------------------------------------------------
def test_snapshot_roundtrip_preserves_all_request_fields():
    with Executor({"toy": _toy_factory}, n_workers=1) as ex:
        blocked = EvalRequest(
            "toy", [[7.0]], config={"a": 1}, time_request=12.5,
            time_limit=99.0, n_cpus=4, max_attempts=7, deadline=123.0,
            task_id="rich", depends_on=("never-finishes",))
        ex.submit(blocked)                     # parked on unmet deps
        snap = ex.snapshot()
    payload = next(p for p in snap["pending"] if p["task_id"] == "rich")
    assert payload["n_cpus"] == 4              # the fields that were dropped
    assert payload["max_attempts"] == 7
    assert payload["deadline"] == 123.0
    assert payload["time_request"] == 12.5
    assert payload["time_limit"] == 99.0
    assert payload["depends_on"] == ["never-finishes"]
    assert payload["config"] == {"a": 1}

    ex2 = Executor.restore(snap, {"toy": _toy_factory}, n_workers=1)
    try:
        with ex2._lock:
            restored = ex2._requests["rich"]
        for field in ("n_cpus", "max_attempts", "deadline", "time_request",
                      "time_limit", "config"):
            assert getattr(restored, field) == getattr(blocked, field), field
        assert list(restored.depends_on) == list(blocked.depends_on)
        assert ex2.backlog() == 0              # still gated on the dep
    finally:
        ex2.shutdown()


def test_snapshot_roundtrip_waiting_deps_release():
    """A restored waiting task runs once its dependency completes."""
    with Executor({"toy": _toy_factory}, n_workers=1) as ex:
        a = EvalRequest("toy", [[1.0]], task_id="dep-a")
        b = EvalRequest("toy", [[2.0]], task_id="dep-b",
                        depends_on=("dep-a",))
        ex.submit(b)                           # waits: a not submitted yet
        snap = ex.snapshot()
    ex2 = Executor.restore(snap, {"toy": _toy_factory}, n_workers=1)
    try:
        ex2.submit(a)
        res = ex2.result("dep-b", 30)
        assert res.status == "ok" and res.value[0][0] == 4.0
    finally:
        ex2.shutdown()


# --------------------------------------------------------------------------
# satellite: EDF policy
# --------------------------------------------------------------------------
def test_edf_orders_by_deadline_none_last():
    p = make_policy("edf")
    assert type(p) is EDFPolicy
    p.push(_req(task_id="late", deadline=300.0), 1)
    p.push(_req(task_id="none1"), 1)
    p.push(_req(task_id="soon", deadline=10.0), 1)
    p.push(_req(task_id="none2"), 1)
    p.push(_req(task_id="mid", deadline=100.0), 1)
    order = [p.pop()[0].task_id for _ in range(5)]
    assert order == ["soon", "mid", "late", "none1", "none2"]
    assert p.pop() is None


def test_edf_pending_snapshot_and_len():
    p = EDFPolicy()
    for i, d in enumerate((50.0, None, 5.0)):
        p.push(_req(task_id=f"t{i}", deadline=d), 1)
    assert len(p) == 3
    assert [r.task_id for r, _ in p.pending()] == ["t2", "t0", "t1"]


def test_edf_in_live_executor():
    with Executor({"toy": _toy_factory}, n_workers=2, policy="edf") as ex:
        now = time.monotonic()
        reqs = [EvalRequest("toy", [[i]], deadline=now + 60.0 - i)
                for i in range(10)]
        res = ex.run_all(reqs, timeout=30)
        assert all(r.status == "ok" for r in res)


def test_edf_as_broker_sub_policy():
    """The per-allocation policy instances ride the new registry entry."""
    spec = backends.get("hq")
    trace = bimodal_trace(n=20, seed=6)
    res = simulate_cluster(spec, trace, policy="edf", n_workers=2, seed=6)
    assert all(r.status == "ok" for r in res.records)
