"""Order-equivalence suite for the O(log n) queue structures.

The PR that introduced `repro.sched.costq` rebuilt every per-decision
operation in the scheduling hot path (pack/sjf/lpt pops, the steal
queue's warm-model match, cost-heap rebuilds) to be O(log n) or batched.
The refactor claims to be behaviour-preserving, so this module keeps
MINIMAL NAIVE REFERENCES — the literal pre-refactor heap/deque
implementations — and drives both through long seeded push/pop/remove
op traces, asserting byte-identical pop sequences and `pending()`
snapshots.

One deliberate semantic change is encoded in the steal reference rather
than papered over: anonymous-consumer drains and steal-victim tie-breaks
now iterate workers by ascending wid (never dict insertion order), so
sim/live parity cannot depend on which worker happened to pop first in
history.  The reference implements exactly that rule.

Also here: the batched predictor contract (`predict_many` ==
one-at-a-time `predict`), per-request feature caching, the GP rebuild's
compile-shape discipline, `_RunningQuantiles` eviction after the deque
swap, and the broker's epoch-cached allocation views.
"""
import heapq
import math
from collections import deque

import numpy as np
import pytest

from repro.cluster import Allocation, Broker
from repro.core.task import EvalRequest
from repro.sched import (GPRuntimePredictor, QuantileEstimator,
                         SortedCostQueue, WorkerView, make_policy)
from repro.sched.policy import SchedulingPolicy
from repro.sched.predictor import _RunningQuantiles, request_features
from repro.uq import gp

MODELS = ("gs2", "proxy", "cheap")


def _req(i, rng):
    """A randomised request: some have hints, some have GP-able params,
    some have junk payloads (predictor fallback paths)."""
    kind = rng.integers(0, 4)
    params = [[float(rng.uniform(0, 1)), float(rng.uniform(0, 1))]]
    if kind == 0:
        params = "not-numeric"                 # unflattenable
    return EvalRequest(
        model_name=MODELS[int(rng.integers(0, len(MODELS)))],
        parameters=params,
        time_request=(float(rng.uniform(0.5, 60.0))
                      if rng.random() < 0.8 else None),
        deadline=(float(rng.uniform(0, 500.0))
                  if rng.random() < 0.5 else None),
        task_id=f"eq-{i}")


# --------------------------------------------------------------------------
# naive references: the pre-refactor implementations, verbatim semantics
# --------------------------------------------------------------------------
class _NaiveCostOrdered(SchedulingPolicy):
    """The old heap: push O(log n), rebuild via per-item `cost`."""

    sign = 1.0

    def __init__(self, predictor=None):
        super().__init__(predictor)
        self._heap = []
        self._built_version = None

    def _maybe_rebuild(self):
        if self.predictor is None or not self._heap:
            return
        v = self._predictor_version()
        if v != self._built_version:
            self._heap = [(self.sign * self.cost(item[0]), tick, item)
                          for _, tick, item in self._heap]
            heapq.heapify(self._heap)
            self._built_version = v

    def push(self, req, attempt):
        heapq.heappush(self._heap, (self.sign * self.cost(req),
                                    next(self._tick), (req, attempt)))

    def pop(self, worker=None):
        self._maybe_rebuild()
        return heapq.heappop(self._heap)[2] if self._heap else None

    def pending(self):
        return [item for _, _, item in sorted(self._heap)]

    def __len__(self):
        return len(self._heap)


class NaiveSJF(_NaiveCostOrdered):
    sign = 1.0


class NaiveLPT(_NaiveCostOrdered):
    sign = -1.0


class NaivePack(_NaiveCostOrdered):
    """The old O(n log n)-per-pop budget fit: sort, scan, remove, heapify."""

    sign = -1.0

    def __init__(self, predictor=None, init_margin: float = 1.0):
        super().__init__(predictor)
        self.init_margin = init_margin

    def pop(self, worker=None):
        self._maybe_rebuild()
        if not self._heap:
            return None
        if worker is None or worker.budget_left is None:
            return heapq.heappop(self._heap)[2]
        budget = worker.budget_left - self.init_margin
        order = sorted(self._heap)
        for entry in order:
            if -entry[0] <= budget:
                self._heap.remove(entry)
                heapq.heapify(self._heap)
                return entry[2]
        entry = order[-1]
        self._heap.remove(entry)
        heapq.heapify(self._heap)
        return entry[2]


class NaiveSteal(SchedulingPolicy):
    """The old deque-scan steal queue, with the ONE deliberate change of
    this PR folded in: worker iteration is by ascending wid (anonymous
    drains and steal-victim ties), never dict insertion order."""

    def __init__(self, predictor=None):
        super().__init__(predictor)
        self._local = {}
        self._global = deque()
        self._affinity = {}

    def push(self, req, attempt):
        wid = self._affinity.get(req.model_name)
        if wid is not None and wid in self._local:
            self._local[wid].append((req, attempt))
        else:
            self._global.append((req, attempt))

    def pop(self, worker=None):
        if worker is None:
            if self._global:
                return self._global.popleft()
            for wid in sorted(self._local):
                if self._local[wid]:
                    return self._local[wid].popleft()
            return None
        mine = self._local.setdefault(worker.wid, deque())
        if mine:
            return mine.popleft()
        if self._global:
            for i, (req, attempt) in enumerate(self._global):
                if req.model_name in worker.warm_models:
                    del self._global[i]
                    self._affinity[req.model_name] = worker.wid
                    return req, attempt
            req, attempt = self._global.popleft()
            self._affinity[req.model_name] = worker.wid
            return req, attempt
        victim = None
        for wid in sorted(self._local):
            q = self._local[wid]
            if wid != worker.wid and q and \
                    (victim is None or len(q) > len(victim)):
                victim = q
        if victim:
            req, attempt = victim.pop()
            self._affinity[req.model_name] = worker.wid
            return req, attempt
        return None

    def pending(self):
        out = list(self._global)
        for wid in sorted(self._local):
            out.extend(self._local[wid])
        return out

    def __len__(self):
        return len(self._global) + sum(len(q) for q in self._local.values())

    def remove_worker(self, wid):
        q = self._local.pop(wid, None)
        if q:
            self._global.extendleft(reversed(q))
        self._affinity = {m: w for m, w in self._affinity.items()
                          if w != wid}


NAIVE = {"sjf": NaiveSJF, "lpt": NaiveLPT, "pack": NaivePack,
         "steal": NaiveSteal,
         # fcfs/edf structures were already O(log n); their references
         # are the policies themselves re-instantiated (the differential
         # driver then checks determinism under the shared op trace)
         "fcfs": lambda predictor=None: make_policy("fcfs", predictor),
         "edf": lambda predictor=None: make_policy("edf", predictor)}


# --------------------------------------------------------------------------
# the differential driver
# --------------------------------------------------------------------------
def _ids(items):
    return [(r.task_id, a) for r, a in items]


def _drive(name, seed, n_ops=600, predictor_factory=None):
    """One seeded op trace through the real policy and its reference;
    every pop result and every pending snapshot must match exactly."""
    rng = np.random.default_rng(seed)
    pred_new = predictor_factory() if predictor_factory else None
    pred_ref = predictor_factory() if predictor_factory else None
    new = make_policy(name, pred_new)
    ref = NAIVE[name](predictor=pred_ref)
    wids = [0, 1, 2, 3]
    pushed = 0
    for op_i in range(n_ops):
        op = rng.random()
        if op < 0.45:                           # push
            req = _req(f"{name}-{seed}-{pushed}", rng)
            pushed += 1
            attempt = int(rng.integers(1, 3))
            new.push(req, attempt)
            ref.push(req, attempt)
        elif op < 0.85:                         # pop, assorted views
            v = rng.random()
            if v < 0.25:
                view = None
            else:
                warm = frozenset(m for m in MODELS if rng.random() < 0.4)
                budget = (float(rng.uniform(0.0, 80.0))
                          if rng.random() < 0.6 else None)
                view = WorkerView(wid=int(rng.choice(wids)),
                                  warm_models=warm, budget_left=budget)
            a, b = new.pop(view), ref.pop(view)
            assert (a is None) == (b is None), (name, seed, op_i)
            if a is not None:
                assert (a[0].task_id, a[1]) == (b[0].task_id, b[1]), \
                    (name, seed, op_i)
        elif op < 0.90 and name == "steal":     # worker death (reflow)
            wid = int(rng.choice(wids))
            new.remove_worker(wid)
            ref.remove_worker(wid)
        else:                                   # observation (re-costing)
            if pred_new is not None:
                r = _req(f"{name}-{seed}-obs-{op_i}", rng)
                t = float(rng.uniform(0.1, 50.0))
                pred_new.observe(r, t)
                pred_ref.observe(r, t)
        if op_i % 37 == 0:
            assert _ids(new.pending()) == _ids(ref.pending()), \
                (name, seed, op_i)
        assert len(new) == len(ref), (name, seed, op_i)
    # drain both dry through mixed views and compare the full tail
    view = WorkerView(wid=0, budget_left=25.0)
    while True:
        a, b = new.pop(view), ref.pop(view)
        assert (a is None) == (b is None)
        if a is None:
            break
        assert (a[0].task_id, a[1]) == (b[0].task_id, b[1])
    assert len(new) == 0 and len(ref) == 0


@pytest.mark.parametrize("name", ["fcfs", "sjf", "lpt", "pack", "steal",
                                  "edf"])
@pytest.mark.parametrize("seed", [0, 1, 7])
def test_pop_order_matches_naive_reference(name, seed):
    _drive(name, seed)


@pytest.mark.parametrize("name", ["sjf", "lpt", "pack"])
def test_pop_order_matches_with_online_predictor(name):
    """Re-costing rebuilds (predictor version bumps mid-trace) must leave
    the new batched-rebuild store in exactly the old heap's order."""
    _drive(name, seed=3, predictor_factory=lambda:
           QuantileEstimator(min_observed=1))


def test_steal_anonymous_drain_is_wid_ordered():
    """The satellite fix: anonymous pops drain local queues by ascending
    wid, regardless of which worker appeared first."""
    p = make_policy("steal")
    # build affinity so pushes land on locals: wid 5 first, then wid 1
    for wid, model in ((5, "m5"), (1, "m1")):
        p.push(EvalRequest(model, [[0.0]], task_id=f"seed-{wid}"), 1)
        assert p.pop(WorkerView(wid=wid))[0].task_id == f"seed-{wid}"
    p.push(EvalRequest("m5", [[0.0]], task_id="on-5"), 1)
    p.push(EvalRequest("m1", [[0.0]], task_id="on-1"), 1)
    assert p.pop()[0].task_id == "on-1"        # wid 1 before wid 5
    assert p.pop()[0].task_id == "on-5"


# --------------------------------------------------------------------------
# SortedCostQueue unit fuzz
# --------------------------------------------------------------------------
def test_costq_matches_flat_sorted_list():
    rng = np.random.default_rng(11)
    q = SortedCostQueue()
    ref = []
    tick = 0
    for _ in range(4000):
        op = rng.random()
        if op < 0.5 or not ref:
            key = float(rng.integers(0, 40))   # many duplicate keys
            q.insert(key, tick, f"it{tick}")
            ref.append((key, tick, f"it{tick}"))
            ref.sort(key=lambda e: (e[0], e[1]))
            tick += 1
        elif op < 0.65:
            assert q.pop_first() == ref.pop(0)
        elif op < 0.8:
            assert q.pop_last() == ref.pop()
        else:
            bound = float(rng.integers(0, 40))
            got = q.pop_first_at_least(bound)
            want = next((e for e in ref if e[0] >= bound), None)
            assert got == want
            if want is not None:
                ref.remove(want)
        assert len(q) == len(ref)
    assert q.entries() == ref


def test_costq_rebuild_rebalances():
    q = SortedCostQueue((float(k), k, k) for k in range(5000))
    q.rebuild([(float(-e[0]), e[1], e[2]) for e in q.entries()])
    keys = [e[0] for e in q.entries()]
    assert keys == sorted(keys) and len(q) == 5000
    assert q.pop_first()[2] == 4999            # biggest old key now first


# --------------------------------------------------------------------------
# batched predictors
# --------------------------------------------------------------------------
def test_quantile_predict_many_matches_predict():
    rng = np.random.default_rng(2)
    est = QuantileEstimator(min_observed=2)
    reqs = [_req(f"q-{i}", rng) for i in range(50)]
    for i, r in enumerate(reqs[:30]):
        est.observe(r, float(rng.uniform(1, 20)))
    assert est.predict_many(reqs) == [est.predict(r) for r in reqs]


def test_gp_predict_many_matches_predict():
    rng = np.random.default_rng(4)
    pred = GPRuntimePredictor(min_fit=8, refit_every=16, fit_steps=40)
    for x in rng.uniform(0, 1, size=(24, 2)):
        pred.observe(EvalRequest("m", [list(map(float, x))]),
                     0.5 + 2.0 * x[0] + x[1])
    assert pred.n_fits >= 1
    reqs = [EvalRequest("m", [list(map(float, x))])
            for x in rng.uniform(0.2, 0.8, size=(12, 2))]
    reqs.append(EvalRequest("m", "junk-params"))   # fallback row mixed in
    many = pred.predict_many(reqs)
    single = [pred.predict(r) for r in reqs]
    assert many[-1] == single[-1]              # fallback path identical
    # GP rows: batched bucket-padded path vs per-task solve — same maths,
    # different kernels, so equality is numerical not bitwise
    np.testing.assert_allclose(many[:-1], single[:-1], rtol=1e-3)


def test_request_features_flattens_once(monkeypatch):
    import repro.sched.predictor as P
    calls = {"n": 0}
    real = P.flatten_parameters

    def counting(params):
        calls["n"] += 1
        return real(params)

    monkeypatch.setattr(P, "flatten_parameters", counting)
    req = EvalRequest("m", [[1.0, 2.0]])
    assert P.request_features(req) == [1.0, 2.0]
    assert P.request_features(req) == [1.0, 2.0]
    bad = EvalRequest("m", "junk")
    assert P.request_features(bad) is None     # None is cached too
    assert P.request_features(bad) is None
    assert calls["n"] == 2


def test_gp_costed_rebuild_shape_discipline():
    """The acceptance criterion: a full cost-store rebuild over a large
    GP-costed queue issues at most len(PREDICT_BUCKETS) distinct compile
    shapes (one batched pass), never one predict per task."""
    rng = np.random.default_rng(9)
    pred = GPRuntimePredictor(min_fit=8, refit_every=1000, fit_steps=30)
    for x in rng.uniform(0, 1, size=(16, 2)):
        pred.observe(EvalRequest("m", [list(map(float, x))]),
                     1.0 + x[0] + x[1])
    assert pred.n_fits >= 1
    pol = make_policy("sjf", pred)
    n = 300
    for i, x in enumerate(rng.uniform(0, 1, size=(n, 2))):
        pol.push(EvalRequest("m", [list(map(float, x))],
                             task_id=f"sd-{i}"), 1)
    # new observations install a fresh posterior -> version bump
    for x in rng.uniform(0, 1, size=(8, 2)):
        pred.observe(EvalRequest("m", [list(map(float, x))]),
                     1.0 + x[0] + x[1])
    before = dict(gp.predict_batch_shapes)
    assert pol.pop() is not None               # triggers the rebuild
    new_shapes = {k: v - before.get(k, 0)
                  for k, v in gp.predict_batch_shapes.items()
                  if v - before.get(k, 0) > 0}
    assert 0 < len(new_shapes) <= len(gp.PREDICT_BUCKETS), new_shapes
    # and the padded launch sizes are exactly the published bucket plan
    assert sorted(s for _, s in new_shapes) == \
        sorted(set(gp.bucket_launches(n)))


def test_bucket_launches_matches_chunking():
    cap = gp.PREDICT_BUCKETS[-1]
    assert gp.bucket_launches(0) == []
    assert gp.bucket_launches(1) == [gp.PREDICT_BUCKETS[0]]
    assert gp.bucket_launches(cap) == [cap]
    assert gp.bucket_launches(cap + 1) == [cap, gp.PREDICT_BUCKETS[0]]
    assert gp.bucket_launches(5 * cap + 300) == [cap] * 5 + \
        [gp.bucket_of(300)]


# --------------------------------------------------------------------------
# satellites: quantile window eviction, broker view caches
# --------------------------------------------------------------------------
def test_running_quantiles_deque_eviction_window():
    rq = _RunningQuantiles(window=5)
    for x in [9.0, 1.0, 5.0, 3.0, 7.0, 2.0, 8.0]:
        rq.add(x)
    # the two oldest (9, 1) were evicted; the window is the last five
    assert rq.count == 7
    assert rq._ordered == sorted([5.0, 3.0, 7.0, 2.0, 8.0])
    assert rq.quantile(0.0) == 2.0 and rq.quantile(1.0) == 8.0


def test_broker_allocation_views_track_changes():
    b = Broker(policy="fcfs")
    a0 = Allocation(b.next_alloc_id(), 2, 100.0).submit(0.0, 0.0)
    a1 = Allocation(b.next_alloc_id(), 2, 100.0).submit(0.0, 0.0)
    b.add_allocation(a0)
    first = b.allocations()
    assert [a.alloc_id for a in first] == [0]
    assert b.allocations() is first            # cache hit, no resort
    b.add_allocation(a1)
    assert [a.alloc_id for a in b.allocations()] == [0, 1]
    assert b._open_ids() == [0, 1]
    b.drain_allocation(a0.alloc_id, now=1.0)   # queued -> cancelled
    assert b._open_ids() == [1]
    b.remove_allocation(a1.alloc_id, now=2.0)
    assert b._open_ids() == []
    # drain keeps the (now expired) group registered; remove forgets it
    assert [a.alloc_id for a in b.allocations()] == [a0.alloc_id]
    # out-of-band state change (the stepper's tick path) + invalidate
    a2 = Allocation(b.next_alloc_id(), 1, 10.0).submit(0.0, 0.0)
    b.add_allocation(a2)
    a2.tick(0.0)
    assert b._open_ids() == [a2.alloc_id]
    a2.tick(50.0)                              # walltime expiry
    b.invalidate_allocations()
    assert b._open_ids() == []


def test_steal_tombstones_do_not_accumulate():
    """Warm-match pops tombstone the FIFO view; the tombstones must not
    retain request payloads or grow memory with total tasks ever pushed
    (compaction once dead entries dominate)."""
    p = make_policy("steal")
    n = 1000
    for i in range(n):
        p.push(EvalRequest("a", [[float(i)]], task_id=f"c{i}"), 1)
    warm = WorkerView(wid=0, warm_models=frozenset({"a"}))
    for i in range(n):
        assert p.pop(warm)[0].task_id == f"c{i}"   # all via the warm index
    assert len(p) == 0
    # the FIFO view was never popped, yet holds no payloads and is small
    assert len(p._global) <= 128
    assert all(e[1] is None for e in p._global)


def test_bucket_of_oversize_raises():
    with pytest.raises(ValueError):
        gp.bucket_of(gp.PREDICT_BUCKETS[-1] + 1)


def test_steal_warm_match_after_tombstones():
    """Warm-model hits must survive interleaved FIFO pops that tombstone
    entries in the per-model index."""
    p = make_policy("steal")
    for i in range(6):
        p.push(EvalRequest("a" if i % 2 else "b", [[0.0]],
                           task_id=f"t{i}"), 1)
    warm_a = WorkerView(wid=0, warm_models=frozenset({"a"}))
    assert p.pop(warm_a)[0].task_id == "t1"    # earliest "a"
    assert p.pop(None)[0].task_id == "t0"      # FIFO skips nothing yet
    assert p.pop(warm_a)[0].task_id == "t3"    # next "a", over tombstone
    assert p.pop(None)[0].task_id == "t2"      # FIFO skips dead t1/t3
    assert len(p) == 2
