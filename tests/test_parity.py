"""Differential parity suite: sim and live must be the same machine.

Every test drives one seeded trace + config through BOTH adapters of the
shared `LifecycleStepper` — `simulate_cluster` (virtual event loop over
a sim worker table) and `replay_live` (the real `Executor` machinery on
a virtual clock) — and asserts an empty divergence list: identical
allocation decisions, spawn/kill/drain-dry/cancel event sequences, and
terminal task statuses/records.  Also: direct regression tests for the
three historical divergences (autoalloc step order, the missing
`max_workers` cap in the sim, the killed-task record shape) and a
hypothesis property test that the stepper's phase order is deterministic
under seed.
"""
import math

import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401

from repro.cluster import (Allocation, AutoAllocConfig, AutoAllocator,
                           Broker, LifecycleStepper, bimodal_trace,
                           bursty_trace, run_parity, simulate_cluster)
from repro.core import EvalRequest, backends
from repro.core.metrics import killed_task_record


def _elastic_cfg(**kw):
    base = dict(workers_per_alloc=2, walltime_s=300.0, backlog_high_s=30.0,
                backlog_low_s=5.0, max_pending=2, max_allocations=4,
                min_allocations=0, idle_drain_s=20.0, hysteresis_s=5.0)
    base.update(kw)
    return AutoAllocConfig(**base)


def _assert_parity(rep):
    assert rep.ok, "sim/live diverged:\n" + "\n".join(rep.divergences)


# --------------------------------------------------------------------------
# differential scenarios
# --------------------------------------------------------------------------
def test_parity_static_pool():
    spec = backends.get("hq")
    trace = bimodal_trace(n=30, seed=4)
    rep = run_parity(spec, trace, n_workers=3, seed=9)
    _assert_parity(rep)
    assert all(r.status == "ok" for r in rep.sim.records)
    assert len(rep.live.records) == 30


def test_parity_elastic_autoalloc():
    """Bursty arrivals through a cold cluster: bootstrap, growth, idle
    drains — the full decision log must match, timestamps included."""
    spec = backends.get("hq")
    trace = bursty_trace(n_bursts=2, burst_size=8, gap_s=300.0,
                         runtime_s=10.0, seed=1)
    rep = run_parity(spec, trace, autoalloc=_elastic_cfg(),
                     max_workers=16, seed=1)
    _assert_parity(rep)
    assert rep.sim.decisions            # the scenario actually scaled
    assert rep.sim.decisions == rep.live.decisions


def test_parity_drained_dry():
    """Idle allocations drain, finish their last task, and terminate
    drained-dry — the same 'drain-dry' retire events on both paths."""
    spec = backends.get("hq")
    trace = bursty_trace(n_bursts=2, burst_size=6, gap_s=400.0,
                         runtime_s=15.0, seed=2)
    rep = run_parity(spec, trace, autoalloc=_elastic_cfg(idle_drain_s=10.0),
                     max_workers=16, seed=7)
    _assert_parity(rep)
    assert any(d["action"] == "drain" for d in rep.sim.decisions)
    assert any(e[1] == "drain-dry" for e in rep.sim.events)


def test_parity_walltime_kill_requeue():
    """Tasks outliving their allocation are killed and requeued on
    renewed capacity — identical attempt counts on both paths."""
    spec = backends.get("hq")
    trace = bursty_trace(n_bursts=1, burst_size=4, burst_span_s=1.0,
                         runtime_s=40.0, jitter=0.0, seed=0)
    cfg = _elastic_cfg(workers_per_alloc=1, walltime_s=60.0,
                       idle_drain_s=50.0)
    rep = run_parity(spec, trace, autoalloc=cfg, max_attempts=6, seed=3)
    _assert_parity(rep)
    assert all(r.status == "ok" for r in rep.sim.records)
    assert max(r.attempts for r in rep.sim.records) > 1
    assert any(e[1] == "kill" for e in rep.sim.events)


def test_parity_walltime_kill_terminal_record_shape():
    """At max_attempts the kill is terminal; BOTH paths must emit the
    canonical killed-task record (start_t == end_t == kill time, zero
    cpu/compute, worker 'alloc<id>') and 'lost' for unservable work."""
    spec = backends.get("hq")
    trace = bursty_trace(n_bursts=1, burst_size=6, burst_span_s=1.0,
                         runtime_s=50.0, jitter=0.0, seed=0)
    rep = run_parity(spec, trace, n_workers=1, walltime_s=60.0,
                     max_attempts=1, seed=0)
    _assert_parity(rep)
    for res in (rep.sim, rep.live):
        by_status = {}
        for r in res.records:
            by_status.setdefault(r.status, []).append(r)
        assert by_status.get("failed") and by_status.get("lost")
        for r in by_status["failed"]:
            canon = killed_task_record(
                r.task_id, r.submit_t, r.end_t,
                int(r.worker.removeprefix("alloc")), r.attempts)
            assert r == canon, (r, canon)


class _StubOffload:
    """Deterministic stand-in for `SurrogateOffload`: trusts one model
    name outright (no GP state, so sim and live decide identically)."""

    latency_s = 0.05
    n_virtual_workers = 1

    def __init__(self, trust="short-model"):
        self.trust = trust
        self.served = 0

    def decide(self, req, cost=None):
        if req.model_name != self.trust or req.config.get("_no_surrogate"):
            return False
        req.config["_surrogate"] = True        # as the real engine stamps
        return True

    def note_served(self):
        self.served += 1

    def observe(self, *args, **kwargs):       # live conditions on values
        pass


def test_parity_surrogate_virtual_allocation_excluded_from_capacity():
    """Offloaded tasks ride the virtual allocation on both paths; it is
    never billed and never counts as capacity for autoalloc decisions."""
    spec = backends.get("hq")
    trace = bimodal_trace(n=30, seed=6)
    rep = run_parity(spec, trace, autoalloc=_elastic_cfg(),
                     max_workers=16, seed=6,
                     surrogate_factory=_StubOffload)
    _assert_parity(rep)
    for res in (rep.sim, rep.live):
        virt = [a for a in res.allocations if a.alloc_id == 0]
        assert virt and virt[0].node_seconds == 0.0   # never billed
        offloaded = [r for r in res.records
                     if r.cpu_time == pytest.approx(0.05)]
        assert offloaded                              # surrogate served
    # decisions ignore the virtual capacity: identical on both paths
    assert rep.sim.decisions == rep.live.decisions


def test_parity_max_workers_cap():
    """The pool cap binds identically: grants resized to headroom, and
    peak concurrent capacity never exceeds the cap on either path."""
    spec = backends.get("hq")
    trace = bursty_trace(n_bursts=1, burst_size=20, burst_span_s=2.0,
                         runtime_s=30.0, seed=5)
    cfg = _elastic_cfg(workers_per_alloc=8, backlog_high_s=5.0,
                       max_allocations=8, max_pending=4)
    rep = run_parity(spec, trace, autoalloc=cfg, max_workers=5, seed=5)
    _assert_parity(rep)
    for res in (rep.sim, rep.live):
        up = 0
        peak = 0
        for _t, kind, _aid, n in res.events:
            if kind == "spawn":
                up += n
                peak = max(peak, up)
            else:
                # retirements tear the whole group down; reconstruct the
                # size from the matching spawn
                spawned = {e[2]: e[3] for e in res.events
                           if e[1] == "spawn"}
                up -= spawned.get(_aid, 0)
        assert peak <= 5, res.events


# --------------------------------------------------------------------------
# regressions for the three historical divergences
# --------------------------------------------------------------------------
def _stepper_on(broker, allocator=None, **kw):
    spawned, retired_events = [], []
    return LifecycleStepper(
        broker, allocator, now=lambda: 0.0,
        spawn_workers=lambda a: spawned.append(a.alloc_id),
        retire_workers=lambda a: [],
        busy_count=lambda: {},
        record_failed=lambda *a: retired_events.append(a),
        **kw), spawned


def test_stepper_autoalloc_sees_post_transition_capacity():
    """Regression (historical live-path bug): the allocator must step
    AFTER allocation state transitions, so a grant landing this tick is
    visible capacity and no spurious extra allocation is submitted."""
    broker = Broker()
    # a granted-but-not-yet-ticked allocation large enough to cover the
    # backlog once RUNNING
    a = Allocation(broker.next_alloc_id(), 4, 1000.0).submit(0.0, 0.0)
    broker.add_allocation(a)
    for i in range(4):
        broker.push(EvalRequest("m", [[float(i)]], time_request=10.0,
                                task_id=f"t{i}"), 1)
    allocator = AutoAllocator(AutoAllocConfig(
        workers_per_alloc=4, walltime_s=1000.0, backlog_high_s=20.0,
        backlog_low_s=1.0, hysteresis_s=0.0))
    stepper, spawned = _stepper_on(broker, allocator)
    stepper.step(0.0)
    assert spawned == [a.alloc_id]             # the grant happened first
    # 40 s backlog / 4 workers = 10 s/worker < high watermark: with the
    # sim order (transitions first) the allocator stays quiet.  The old
    # live order saw zero capacity and submitted a redundant allocation.
    assert allocator.decisions == []


def test_sim_honours_max_workers_cap():
    """Regression (historical sim bug): `simulate_cluster` used to spawn
    the full `alloc.n_workers` regardless of the live pool cap."""
    spec = backends.get("hq")
    trace = bursty_trace(n_bursts=1, burst_size=16, burst_span_s=2.0,
                         runtime_s=30.0, seed=5)
    cfg = _elastic_cfg(workers_per_alloc=8, backlog_high_s=5.0,
                       max_allocations=8, max_pending=4)
    res = simulate_cluster(spec, trace, autoalloc=cfg, max_workers=3,
                           seed=5)
    assert all(r.status == "ok" for r in res.records)
    assert all(n <= 3 for _t, kind, _aid, n in res.events
               if kind == "spawn")
    assert max(a.n_workers for a in res.allocations) <= 3


def test_stepper_zero_headroom_grant_cancelled():
    """A grant arriving with zero headroom is cancelled outright (0
    node-seconds), not spawned at size zero."""
    broker = Broker()
    running = Allocation(broker.next_alloc_id(), 2, None).submit(0.0, 0.0)
    running.tick(0.0)
    broker.add_allocation(running)
    late = Allocation(broker.next_alloc_id(), 2, 500.0).submit(0.0, 0.0)
    broker.add_allocation(late)
    stepper, spawned = _stepper_on(broker, max_workers=2,
                                   worker_count=lambda: 2)
    stepper.step(0.0)                          # stepped at the grant instant
    assert spawned == []                       # nothing new came up
    assert late.state == "expired" and late.node_seconds() == 0.0
    assert [e[1] for e in stepper.events] == ["cancel"]
    assert stepper.retired == [late]


def test_uncapped_drivers_preserve_caller_worker_cap():
    """max_workers=None must not clobber a caller-set allocator cap on
    EITHER path (the live executor used to reset it to None while the
    sim preserved it — the exact divergence class this PR kills)."""
    from repro.core import Executor, LambdaModel

    spec = backends.get("hq")
    sim_alloc = AutoAllocator(_elastic_cfg(), spec=spec, seed=0)
    sim_alloc.worker_cap = 2
    simulate_cluster(spec, bimodal_trace(n=5, seed=0),
                     allocator=sim_alloc, max_workers=None, seed=0)
    assert sim_alloc.worker_cap == 2

    live_alloc = AutoAllocator(_elastic_cfg(min_allocations=1,
                                            hysteresis_s=0.05))
    live_alloc.worker_cap = 2
    factory = lambda: LambdaModel("toy", lambda p, c: [[0.0]], 1, 1)  # noqa: E731
    with Executor({"toy": factory}, n_workers=1, autoalloc=live_alloc,
                  max_workers=None) as ex:
        assert ex.autoalloc.worker_cap == 2    # preserved, not clobbered
    with Executor({"toy": factory}, n_workers=1,
                  autoalloc=AutoAllocator(_elastic_cfg()),
                  max_workers=4) as ex2:
        assert ex2.autoalloc.worker_cap == 4   # explicit cap still binds


def test_killed_task_record_is_canonical():
    r = killed_task_record("t0", 5.0, 42.0, 3, 2)
    assert r.start_t == r.end_t == 42.0
    assert r.cpu_time == 0.0 and r.compute_t == 0.0
    assert r.worker == "alloc3" and r.status == "failed" and r.attempts == 2


# --------------------------------------------------------------------------
# property: the stepper's phase order is deterministic under seed
# --------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16),
       n=st.integers(min_value=5, max_value=25),
       workers_per_alloc=st.integers(min_value=1, max_value=4),
       walltime=st.floats(min_value=60.0, max_value=600.0))
def test_stepper_deterministic_under_seed(seed, n, workers_per_alloc,
                                          walltime):
    """Same (trace, seed, config) -> byte-identical records, allocation
    records, decisions AND stepper event sequences, twice over."""
    spec = backends.get("hq")
    trace = bimodal_trace(n=n, seed=seed)
    cfg = _elastic_cfg(workers_per_alloc=workers_per_alloc,
                       walltime_s=walltime)
    a = simulate_cluster(spec, trace, autoalloc=cfg, max_workers=8,
                         seed=seed, max_attempts=6)
    b = simulate_cluster(spec, trace, autoalloc=cfg, max_workers=8,
                         seed=seed, max_attempts=6)
    assert a.records == b.records
    assert a.allocations == b.allocations
    assert a.decisions == b.decisions
    assert a.events == b.events
    # phase-order invariant: within one tick, any spawn precedes any
    # retirement of a LATER-submitted allocation's cancel... the cheap
    # checkable core: event times are non-decreasing
    assert all(x[0] <= y[0] for x, y in zip(a.events, a.events[1:]))
    assert all(math.isfinite(e[0]) for e in a.events)


# --------------------------------------------------------------------------
# observability parity: one tracer schema, two drivers, identical spans
# --------------------------------------------------------------------------
def _span_parity(spec, trace, **kw):
    from repro.obs import Tracer, span_sequence, validate_chrome_trace
    st_, lt_ = Tracer(), Tracer()
    rep = run_parity(spec, trace, tracers=(st_, lt_), **kw)
    _assert_parity(rep)
    sim_spans, live_spans = span_sequence(st_), span_sequence(lt_)
    assert sim_spans == live_spans, (
        "span sequences diverged: first sim-only="
        f"{next((a for a, b in zip(sim_spans, live_spans) if a != b), None)}")
    assert sim_spans                                # non-trivial trace
    assert validate_chrome_trace(st_.to_chrome()) == []
    assert validate_chrome_trace(lt_.to_chrome()) == []
    return rep, st_, lt_


def test_span_parity_static_pool():
    """Seeded parity trace from BOTH drivers: identical span names, ids,
    and virtual-clock timestamps (the ISSUE 6 acceptance gate)."""
    spec = backends.get("hq")
    _span_parity(spec, bimodal_trace(n=20, seed=9), n_workers=3, seed=9)


def test_span_parity_elastic_with_walltime_retries():
    spec = backends.get("hq")
    cfg = _elastic_cfg(walltime_s=60.0)
    rep, st_, _ = _span_parity(spec,
                               bursty_trace(n_bursts=2, burst_size=10,
                                            seed=3),
                               autoalloc=cfg, max_attempts=6, seed=3)
    names = {e[2] for e in st_.events()}
    # the elastic lifecycle is actually in the trace
    assert {"alloc.spawn", "alloc.kill", "task.requeue",
            "autoalloc.submit"} <= names
    # and both drivers agree on the attribution totals they derive
    sim_tot = rep.sim.overhead_attribution["totals"]
    live_tot = rep.live.overhead_attribution["totals"]
    for k, v in sim_tot.items():
        assert live_tot[k] == pytest.approx(v, abs=1e-9), k


def test_span_parity_surrogate_offload():
    spec = backends.get("hq")
    _, st_, _ = _span_parity(spec, bimodal_trace(n=30, seed=6),
                             autoalloc=_elastic_cfg(), max_workers=16,
                             seed=6, surrogate_factory=_StubOffload)
    # the virtual allocation's lifecycle is traced but flagged virtual
    virt = [e for e in st_.events()
            if e[1] == "B" and e[6] and e[6].get("virtual")]
    assert virt


def test_stepper_events_bounded_and_exposed_in_metrics():
    """Satellite: the stepper audit trail is a ring buffer (bounded) and
    surfaces through `Executor.metrics()`."""
    from repro.cluster.parity import VirtualClock, _ReplayExecutor
    from repro.obs.trace import RingBuffer
    from repro.core.executor import Executor

    broker = Broker()
    init = Allocation(broker.next_alloc_id(), 2, None)
    init.submit(0.0, 0.0)
    ex = _ReplayExecutor({"m": lambda: None}, n_workers=2,
                         cluster=broker, clock=VirtualClock(0.0),
                         monitor_interval=None)
    try:
        assert isinstance(ex._stepper.events, RingBuffer)
        cap = ex._stepper.events.capacity
        assert cap > 0
        for i in range(cap + 50):
            ex._stepper.events.append((float(i), "spawn", 0, 1))
        assert len(ex._stepper.events) == cap
        assert ex._stepper.events.n_dropped >= 50
        m = ex.metrics()
        assert len(m["stepper_events"]) == cap
        assert m["overhead_attribution"] is None     # tracing off
    finally:
        ex.shutdown()


# --------------------------------------------------------------------------
# satellite: no wall-clock leaks past the injected clock
# --------------------------------------------------------------------------
def test_eval_request_does_not_stamp_wall_clock_submit_t():
    """Regression: `EvalRequest.__post_init__` used to default submit_t
    to `time.monotonic()`, leaking wall time into virtual-clock parity
    replays before `Executor.submit` re-stamped it."""
    req = EvalRequest(model_name="m", parameters=[[0.0]])
    assert req.submit_t == 0.0


def test_load_balancer_timestamps_use_injected_clock():
    """Regression: ModelInfo.registered_t / last_health_t came from
    `time.monotonic()` even when the executor ran on a virtual clock."""
    from repro.core.balancer import LoadBalancer
    from repro.core.task import Model

    class _Probe(Model):
        def __init__(self):
            super().__init__("probe")

        def get_input_sizes(self, config=None):
            return [1]

        def get_output_sizes(self, config=None):
            return [1]

        def __call__(self, parameters, config=None):
            return [[parameters[0][0]]]

        def supports_evaluate(self):
            return True

    clock_t = [1234.5]
    lb = LoadBalancer("hq", n_workers=1, clock=lambda: clock_t[0])
    info = lb.register_model("probe", _Probe)
    assert info.registered_t == 1234.5
    clock_t[0] = 2000.0
    lb.start()
    try:
        assert lb.health_check("probe", [[0.5]], timeout=30.0)
        assert info.last_health_t == 2000.0
    finally:
        lb.shutdown()
