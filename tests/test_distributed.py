"""Multi-device numerical-equivalence tests.

These run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count
so the main test process keeps its single-device jax (per the dry-run
contract, only the dry-run may see >1 placeholder device).

Checked invariants:
  * the EP-over-(data x model) MoE path == the single-device MoE oracle,
  * sequence-parallel + context-parallel forward == unsharded forward,
  * decode flash-decoding shard_map == single-device decode.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SNIPPET_HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import model as M, sharding
from repro.launch import specs
from jax.sharding import NamedSharding, PartitionSpec as P
"""


def _run(snippet: str):
    proc = subprocess.run(
        [sys.executable, "-c", _SNIPPET_HEADER + textwrap.dedent(snippet)],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_moe_ep_over_data_matches_single_device():
    _run("""
    cfg = configs.get_reduced('dbrx-132b').replace(n_experts=8,
                                                   capacity_factor=8.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {'tokens': jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 16)),
        jnp.int32)}
    ref, _, aux_ref = M.forward(params, batch, cfg)          # no mesh

    mesh = jax.make_mesh((4, 2), ('data', 'model'))
    cfg_ep = cfg.replace(ep_over_data=True)
    psh = specs.param_shardings(cfg_ep, mesh)
    pp = jax.device_put(params, psh)
    bb = jax.device_put(batch, specs.batch_shardings(cfg_ep,
        configs.shapes()[0], mesh))
    with sharding.use_mesh(mesh):
        out, _, aux = jax.jit(
            lambda p, b: M.forward(p, b, cfg_ep))(pp, bb)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-3, rtol=2e-3)
    print('moe ep ok')
    """)


def test_seq_shard_forward_matches_unsharded():
    _run("""
    cfg = configs.get_reduced('qwen3-14b')
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = {'tokens': jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (4, 32)),
        jnp.int32)}
    ref, _, _ = M.forward(params, batch, cfg)

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    cfg_sp = cfg.replace(seq_shard=True)
    psh = specs.param_shardings(cfg_sp, mesh)
    pp = jax.device_put(params, psh)
    with sharding.use_mesh(mesh):
        out, _, _ = jax.jit(lambda p, b: M.forward(p, b, cfg_sp))(pp, batch)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-3, rtol=2e-3)
    print('seq shard ok')
    """)


def test_sharded_decode_matches_single_device():
    _run("""
    cfg = configs.get_reduced('yi-34b') if 'yi-34b' in configs.ARCH_NAMES \
        else configs.get_reduced('qwen3-14b')
    cfg = configs.get_reduced('qwen3-14b')
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    b, prompt, total = 2, 5, 8
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, (b, total)), jnp.int32)
    # single-device reference decode
    cache = M.init_cache(cfg, b, total)
    _, cache, _ = M.prefill(params, {'tokens': toks[:, :prompt]}, cfg, cache)
    ref_logits = []
    for pos in range(prompt, total):
        lg, cache = M.decode_step(params, {'tokens': toks[:, pos:pos+1]},
                                  cfg, cache, jnp.int32(pos))
        ref_logits.append(np.asarray(lg, np.float32))

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    with sharding.use_mesh(mesh):
        psh = specs.param_shardings(cfg, mesh)
        pp = jax.device_put(params, psh)
        cache = M.init_cache(cfg, b, total)
        _, cache, _ = jax.jit(lambda p, bt, c: M.prefill(p, bt, cfg, c))(
            pp, {'tokens': toks[:, :prompt]}, cache)
        for i, pos in enumerate(range(prompt, total)):
            lg, cache = jax.jit(
                lambda p, bt, c, q: M.decode_step(p, bt, cfg, c, q))(
                pp, {'tokens': toks[:, pos:pos+1]}, cache, jnp.int32(pos))
            np.testing.assert_allclose(np.asarray(lg, np.float32),
                                       ref_logits[i], atol=3e-3, rtol=3e-3)
    print('sharded decode ok')
    """)


def test_cp_prefill_matches_single_device():
    _run("""
    cfg = configs.get_reduced('qwen3-14b').replace(seq_shard=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 32)), jnp.int32)
    ref, _, _ = M.forward(params, {'tokens': toks},
                          configs.get_reduced('qwen3-14b'))
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    with sharding.use_mesh(mesh):
        pp = jax.device_put(params, specs.param_shardings(cfg, mesh))
        cache = M.init_cache(cfg, 2, 32)
        logits, cache2, _ = jax.jit(
            lambda p, b, c: M.prefill(p, b, cfg, c))(
            pp, {'tokens': toks}, cache)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref, np.float32),
                               atol=3e-3, rtol=3e-3)
    print('cp prefill ok')
    """)
