import os

# Tests and benches must see ONE device (the dry-run sets 512 itself).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
