"""Fair-share scheduling: `FairSharePolicy` unit behaviour, starvation
freedom, conservation properties, and sim/live parity for multi-tenant
traces.

The acceptance bar from the broker-service milestone: tenants weighted
1:2:4 on a seeded saturating trace receive CPU-second shares within 10%
relative error of 1/7 : 2/7 : 4/7 while the queue is backlogged, and
`run_parity` holds EXACT pop-order equality between `simulate_cluster`
and the live `Executor` under the fair-share policy.
"""
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401

from repro.cluster import (bimodal_trace, bursty_trace, run_parity,
                           simulate_cluster, with_tenants)
from repro.core import EvalRequest, backends
from repro.sched import FairSharePolicy, make_policy


def _req(tenant: str, i: int, cost: float = 10.0) -> EvalRequest:
    return EvalRequest("m", [float(i)], time_request=cost,
                       time_limit=100.0, task_id=f"{tenant}-{i}",
                       tenant=tenant)


# --------------------------------------------------------------------------
# unit behaviour
# --------------------------------------------------------------------------
def test_registered_and_constructible():
    p = make_policy("fairshare", None)
    assert isinstance(p, FairSharePolicy)
    assert p.name == "fairshare"


def test_single_tenant_is_inner_policy_passthrough():
    """One tenant => the configured inner policy, byte-for-byte: FCFS
    order for fcfs, no fair-share reordering."""
    p = FairSharePolicy(policy="fcfs")
    reqs = [_req("solo", i) for i in range(20)]
    for r in reqs:
        p.push(r, 0)
    popped = [p.pop(None)[0].task_id for _ in range(20)]
    assert popped == [r.task_id for r in reqs]
    assert p.pop(None) is None


def test_default_tenant_untagged_requests():
    """Requests with no tenant field behaviour land under 'default'."""
    p = FairSharePolicy()
    r = EvalRequest("m", [0.0], time_request=1.0, time_limit=10.0)
    p.push(r, 0)
    assert p.tenant_pending_all() == {"default": 1}
    assert p.pop(None)[0] is r


def test_weighted_shares_converge():
    """1:2:4 weights, equal-cost saturating backlog: served cost-seconds
    at half drain match the weights within 10% relative error."""
    weights = {"a": 1.0, "b": 2.0, "c": 4.0}
    p = FairSharePolicy(policy="fcfs", weights=weights, quantum_s=10.0)
    n_per = 70
    for i in range(n_per):
        for t in weights:
            p.push(_req(t, i), 0)
    half = (3 * n_per) // 2
    for _ in range(half):
        assert p.pop(None) is not None
    served = p.served_cost()
    total = sum(served.values())
    wsum = sum(weights.values())
    for t, w in weights.items():
        share = served[t] / total
        target = w / wsum
        assert abs(share - target) / target <= 0.10, \
            f"tenant {t}: share {share:.3f} vs target {target:.3f}"


def test_no_starvation_under_adversarial_bursts():
    """A weight-1 victim against two weight-8 adversaries that keep the
    queue saturated: the victim still pops within a bounded window —
    deficit round robin guarantees service every round."""
    p = FairSharePolicy(weights={"victim": 1.0, "adv1": 8.0, "adv2": 8.0},
                        quantum_s=10.0)
    for i in range(4):
        p.push(_req("victim", i), 0)
    k = 0
    pops_between_victim = 0
    victim_served = 0
    worst = 0
    for step in range(600):
        # adversaries refill continuously — the queue never drains
        p.push(_req("adv1", 1000 + k), 0)
        p.push(_req("adv2", 2000 + k), 0)
        k += 1
        item = p.pop(None)
        assert item is not None
        if item[0].tenant == "victim":
            victim_served += 1
            worst = max(worst, pops_between_victim)
            pops_between_victim = 0
            if victim_served == 4:
                break
        else:
            pops_between_victim += 1
    assert victim_served == 4, "victim starved behind weight-8 tenants"
    # 1:8:8 weights => at most ~16 adversary pops per victim pop, plus
    # round-boundary slack
    assert worst <= 40


def test_unknown_tenant_gets_default_weight():
    p = FairSharePolicy(weights={"a": 4.0})
    p.push(_req("a", 0), 0)
    p.push(_req("mystery", 0), 0)
    got = {p.pop(None)[0].tenant for _ in range(2)}
    assert got == {"a", "mystery"}


def test_backlog_cost_and_pending_introspection():
    p = FairSharePolicy()
    for i in range(3):
        p.push(_req("a", i, cost=5.0), 0)
    p.push(_req("b", 0, cost=7.0), 0)
    assert p.tenant_pending_all() == {"a": 3, "b": 1}
    bc = p.tenant_backlog_cost()
    assert bc["a"] == pytest.approx(15.0)
    assert bc["b"] == pytest.approx(7.0)
    assert len(p) == 4
    assert sorted(r.task_id for r, _ in p.pending()) == \
        ["a-0", "a-1", "a-2", "b-0"]


def test_quota_headroom_advisory():
    p = FairSharePolicy(quotas={"a": 2})
    assert p.quota_headroom("a") == 2
    p.push(_req("a", 0), 0)
    assert p.quota_headroom("a") == 1
    assert p.quota_headroom("unlimited") is None


# --------------------------------------------------------------------------
# properties
# --------------------------------------------------------------------------
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["a", "b", "c", "d"]),
                          st.integers(min_value=1, max_value=8)),
                min_size=1, max_size=60),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=8))
def test_property_conservation(pushes, wa, wb):
    """Whatever the weights and arrival pattern: every pushed item pops
    exactly once, pop never returns None while non-empty, and the queue
    reports empty afterwards."""
    p = FairSharePolicy(weights={"a": float(wa), "b": float(wb)},
                        quantum_s=2.0)
    pushed = []
    for j, (tenant, cost) in enumerate(pushes):
        r = _req(tenant, j, cost=float(cost))
        pushed.append(r.task_id)
        p.push(r, 0)
    popped = []
    while len(p):
        item = p.pop(None)
        assert item is not None, "pop returned None on non-empty queue"
        popped.append(item[0].task_id)
    assert sorted(popped) == sorted(pushed)
    assert p.pop(None) is None
    assert p.tenant_pending_all() == {}


# --------------------------------------------------------------------------
# sim / live
# --------------------------------------------------------------------------
def _fair_factory():
    return FairSharePolicy(policy="fcfs",
                           weights={"a": 1.0, "b": 2.0, "c": 4.0},
                           quantum_s=20.0)


def test_parity_fairshare_multitenant():
    """Sim and live must pop the fair-share queue in the same order:
    identical terminal records on a multi-tenant trace."""
    spec = backends.get("hq")
    trace = with_tenants(bimodal_trace(n=24, seed=11),
                         {"a": 1.0, "b": 2.0, "c": 4.0})
    rep = run_parity(spec, trace, policy=_fair_factory, n_workers=3,
                     seed=7)
    assert rep.ok, "sim/live diverged:\n" + "\n".join(rep.divergences)
    assert len(rep.sim.records) == 24


def test_sim_cpu_second_shares():
    """Weights 1:2:4 on a saturating burst: CPU-seconds completed while
    the backlog persists split within 10% relative error of the weights.
    Tenants are loaded proportionally (via `with_tenants`) so exact fair
    sharing drains them together."""
    weights = {"a": 1.0, "b": 2.0, "c": 4.0}
    trace = with_tenants(
        bursty_trace(n_bursts=1, burst_size=112, burst_span_s=1.0,
                     runtime_s=4.0, jitter=0.0, seed=3),
        weights)
    tenant_of = {f"trace-{i}": tt.tenant for i, tt in enumerate(trace)}
    res = simulate_cluster(
        backends.get("hq"), trace,
        policy=lambda: FairSharePolicy(weights=weights, quantum_s=8.0),
        n_workers=2, seed=3)
    # share measured at the 3/4-drain horizon: order records by finish
    # time and take the prefix (the backlog is still saturated there;
    # the full drain would be trivially proportional)
    done = sorted((r for r in res.records if r.status == "ok"),
                  key=lambda r: r.end_t)
    part = done[:(3 * len(done)) // 4]
    cpu = {t: 0.0 for t in weights}
    for r in part:
        cpu[tenant_of[r.task_id]] += r.cpu_time
    total = sum(cpu.values())
    wsum = sum(weights.values())
    for t, w in weights.items():
        share = cpu[t] / total
        target = w / wsum
        assert abs(share - target) / target <= 0.10, \
            f"tenant {t}: cpu share {share:.3f} vs target {target:.3f}"
