"""Per-kernel validation: Pallas (interpret mode) and the XLA chunked
fallbacks, swept over shapes/dtypes, against the pure-jnp ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import (flash_attention as fa, gp_kernel, mamba2_ssd,
                           ref, rwkv6_scan)
from repro.kernels import ops as kops

jax.config.update("jax_enable_x64", False)


# ==========================================================================
# flash attention
# ==========================================================================
ATTN_SHAPES = [
    # (b, sq, skv, h, hkv, dh)
    (2, 128, 128, 4, 2, 64),
    (1, 100, 100, 4, 4, 32),
    (2, 64, 256, 8, 2, 64),      # cross attention window (decode-ish)
    (1, 1, 128, 4, 2, 64),       # single query row
    (1, 257, 257, 2, 1, 128),    # non-multiple of block
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_vs_oracle(shape, causal, dtype):
    b, sq, skv, h, hkv, dh = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (b, sq, h, dh), dtype)
    k = jax.random.normal(k2, (b, skv, hkv, dh), dtype)
    v = jax.random.normal(k3, (b, skv, hkv, dh), dtype)
    out = fa.flash_attention(q, k, v, causal=causal, block_q=64,
                             block_kv=64, interpret=True)
    want = ref.attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", ATTN_SHAPES[:3])
def test_flash_attention_chunked_fallback(shape):
    b, sq, skv, h, hkv, dh = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (b, sq, h, dh), jnp.float32)
    k = jax.random.normal(k2, (b, skv, hkv, dh), jnp.float32)
    v = jax.random.normal(k3, (b, skv, hkv, dh), jnp.float32)
    out = ref.attention_chunked(q, k, v, causal=True, q_block=32, kv_block=32)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_chunked_vjp():
    """The custom blockwise-recompute VJP must match autodiff-through-
    oracle gradients."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(k1, (1, 96, 2, 32), jnp.float32)
    k = jax.random.normal(k2, (1, 96, 2, 32), jnp.float32)
    v = jax.random.normal(k3, (1, 96, 2, 32), jnp.float32)
    ct = jax.random.normal(k4, (1, 96, 2, 32), jnp.float32)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention(q, k, v, causal=True) * ct)

    def f_chk(q, k, v):
        return jnp.sum(ref.attention_chunked(q, k, v, causal=True,
                                             q_block=32, kv_block=32) * ct)

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_chk = jax.grad(f_chk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_chk):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(1, 80), h=st.sampled_from([1, 2, 4]),
       dh=st.sampled_from([16, 32]), seed=st.integers(0, 10_000))
def test_flash_attention_property_rowsum(sq, h, dh, seed):
    """Property: attention output rows are convex combinations of V rows
    -> with V == const c, output == c everywhere (any mask/shape)."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, sq, h, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, sq, h, dh))
    v = jnp.full((1, sq, h, dh), 3.5, jnp.float32)
    out = fa.flash_attention(q, k, v, causal=True, block_q=32, block_kv=32,
                             interpret=True)
    np.testing.assert_allclose(out, 3.5, atol=1e-4)


# ==========================================================================
# rwkv6
# ==========================================================================
RWKV_SHAPES = [(2, 130, 3, 16, 16), (1, 64, 2, 32, 32), (1, 33, 1, 8, 8)]


@pytest.mark.parametrize("shape", RWKV_SHAPES)
@pytest.mark.parametrize("with_state", [False, True])
def test_rwkv6_pallas_vs_oracle(shape, with_state):
    b, s, h, kd, vd = shape
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r = jax.random.normal(ks[0], (b, s, h, kd))
    k = jax.random.normal(ks[1], (b, s, h, kd))
    v = jax.random.normal(ks[2], (b, s, h, vd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, kd)) * 0.5 - 1.0))
    u = jax.random.normal(ks[4], (h, kd)) * 0.1
    st0 = (jax.random.normal(ks[5], (b, h, kd, vd)) * 0.1
           if with_state else None)
    out, fs = rwkv6_scan.rwkv6_wkv(r, k, v, w, u, st0, chunk=32,
                                   interpret=True)
    want, wfs = ref.rwkv6_wkv(r, k, v, w, u, st0)
    np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(fs, wfs, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("chunk", [16, 64])
def test_rwkv6_chunked_fallback(chunk):
    b, s, h, kd, vd = 2, 100, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r = jax.random.normal(ks[0], (b, s, h, kd))
    k = jax.random.normal(ks[1], (b, s, h, kd))
    v = jax.random.normal(ks[2], (b, s, h, vd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, kd)) * 0.5 - 1.0))
    u = jax.random.normal(ks[4], (h, kd)) * 0.1
    out, fs = ref.rwkv6_wkv_chunked(r, k, v, w, u, None, chunk=chunk)
    want, wfs = ref.rwkv6_wkv(r, k, v, w, u, None)
    np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(fs, wfs, atol=2e-4, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(2, 70), seed=st.integers(0, 1000))
def test_rwkv6_property_chunk_invariance(s, seed):
    """Chunked evaluation must be invariant to the chunk size."""
    b, h, kd = 1, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (b, s, h, kd))
    k = jax.random.normal(ks[1], (b, s, h, kd))
    v = jax.random.normal(ks[2], (b, s, h, kd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, kd)) * 0.3))
    u = jax.random.normal(ks[4], (h, kd)) * 0.1
    o1, s1 = ref.rwkv6_wkv_chunked(r, k, v, w, u, chunk=8)
    o2, s2 = ref.rwkv6_wkv_chunked(r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(o1, o2, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(s1, s2, atol=2e-4, rtol=2e-4)


# ==========================================================================
# mamba2 SSD
# ==========================================================================
SSD_SHAPES = [(2, 100, 3, 8, 16), (1, 64, 2, 16, 32), (1, 31, 1, 8, 8)]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("with_state", [False, True])
def test_mamba2_pallas_vs_oracle(shape, with_state):
    b, s, h, p, n = shape
    ks = jax.random.split(jax.random.PRNGKey(5), 7)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bi = jax.random.normal(ks[3], (b, s, n))
    ci = jax.random.normal(ks[4], (b, s, n))
    d = jax.random.normal(ks[5], (h,))
    st0 = (jax.random.normal(ks[6], (b, h, p, n)) * 0.1
           if with_state else None)
    y, fs = mamba2_ssd.mamba2_ssd(x, dt, a, bi, ci, d, st0, chunk=32,
                                  interpret=True)
    wy, wfs = ref.mamba2_ssd(x, dt, a, bi, ci, d, st0)
    np.testing.assert_allclose(y, wy, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(fs, wfs, atol=2e-3, rtol=2e-3)


def test_mamba2_chunked_fallback():
    b, s, h, p, n = 2, 77, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bi = jax.random.normal(ks[3], (b, s, n))
    ci = jax.random.normal(ks[4], (b, s, n))
    d = jax.random.normal(ks[5], (h,))
    y, fs = ref.mamba2_ssd_chunked(x, dt, a, bi, ci, d, chunk=16)
    wy, wfs = ref.mamba2_ssd(x, dt, a, bi, ci, d)
    np.testing.assert_allclose(y, wy, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(fs, wfs, atol=2e-3, rtol=2e-3)


def test_mamba2_decay_property():
    """With dt == 0 the state must pass through unchanged and the output
    must be exactly the D-skip."""
    b, s, h, p, n = 1, 16, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jnp.zeros((b, s, h))
    a = -jnp.ones((h,))
    bi = jax.random.normal(ks[1], (b, s, n))
    ci = jax.random.normal(ks[2], (b, s, n))
    d = jax.random.normal(ks[3], (h,))
    st0 = jnp.zeros((b, h, p, n))
    y, fs = ref.mamba2_ssd_chunked(x, dt, a, bi, ci, d, st0, chunk=8)
    np.testing.assert_allclose(y, d[None, None, :, None] * x, atol=1e-5)
    np.testing.assert_allclose(fs, 0.0, atol=1e-6)


# ==========================================================================
# GP covariance kernel
# ==========================================================================
@pytest.mark.parametrize("n,m,d", [(100, 57, 7), (33, 33, 3), (8, 300, 2)])
@pytest.mark.parametrize("kind", ["rbf", "matern52"])
def test_gp_kernel_pallas_vs_oracle(n, m, d, kind):
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    x1 = jax.random.normal(ks[0], (n, d))
    x2 = jax.random.normal(ks[1], (m, d))
    ls = jnp.exp(jax.random.normal(ks[2], (d,)) * 0.2)
    var = jnp.float32(1.7)
    got = gp_kernel.gp_kernel_matrix(x1, x2, ls, var, kind, block_n=32,
                                     block_m=32, interpret=True)
    want = ref.gp_kernel_matrix(x1, x2, ls, var, kind)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 40), d=st.integers(1, 8), seed=st.integers(0, 999))
def test_gp_kernel_properties(n, d, seed):
    """K(X,X) is symmetric PSD with variance on the diagonal (RBF)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    ls = jnp.ones((d,))
    k = ref.gp_kernel_matrix(x, x, ls, jnp.float32(2.0), "rbf")
    np.testing.assert_allclose(k, k.T, atol=1e-5)
    np.testing.assert_allclose(np.diag(k), 2.0, atol=1e-5)
    eig = np.linalg.eigvalsh(np.asarray(k))
    assert eig.min() > -1e-4


# ==========================================================================
# dispatcher
# ==========================================================================
def test_ops_dispatcher_modes():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    o_xla = kops.flash_attention(q, k, v, impl="xla")
    o_int = kops.flash_attention(q, k, v, impl="interpret")
    np.testing.assert_allclose(o_xla, o_int, atol=2e-5, rtol=2e-5)
