"""Edge-case coverage for `repro.core.metrics` (§IV-A bookkeeping).

The quantile interpolation, NaN-timestamp allocation records, degenerate
histograms, and overhead clamping are all exercised implicitly by the
benchmark suites; these tests pin the behaviours directly so a
refactor of the metrics layer cannot silently shift them.
"""
import math

import pytest

from repro.core.metrics import (AllocationRecord, TaskRecord, _stats,
                                killed_task_record, sd_histogram)


# --------------------------------------------------------------------------
# _stats quantile interpolation
# --------------------------------------------------------------------------
def test_stats_empty_is_all_zero():
    s = _stats([])
    assert s == {k: 0.0 for k in ("min", "q1", "median", "q3", "max",
                                  "mean")}


def test_stats_single_sample_every_quantile_collapses():
    s = _stats([7.0])
    assert all(s[k] == 7.0 for k in ("min", "q1", "median", "q3", "max",
                                     "mean"))


def test_stats_two_samples_interpolate_linearly():
    s = _stats([0.0, 1.0])
    assert s["min"] == 0.0 and s["max"] == 1.0
    assert s["q1"] == pytest.approx(0.25)
    assert s["median"] == pytest.approx(0.5)
    assert s["q3"] == pytest.approx(0.75)
    assert s["mean"] == pytest.approx(0.5)


def test_stats_is_order_insensitive():
    assert _stats([3.0, 1.0, 2.0]) == _stats([1.0, 2.0, 3.0])


# --------------------------------------------------------------------------
# AllocationRecord NaN handling
# --------------------------------------------------------------------------
def test_allocation_record_never_granted_holds_zero_node_seconds():
    rec = AllocationRecord(alloc_id=0, n_workers=4, submit_t=10.0,
                           start_t=float("nan"), end_t=float("nan"),
                           state="expired")
    assert rec.held_s == 0.0
    assert rec.node_seconds == 0.0


def test_allocation_record_still_held_reads_as_zero_until_released():
    rec = AllocationRecord(alloc_id=1, n_workers=2, submit_t=0.0,
                           start_t=5.0, end_t=float("nan"))
    assert rec.held_s == 0.0          # no release timestamp yet


def test_allocation_record_node_s_sentinel_vs_billed():
    derived = AllocationRecord(alloc_id=2, n_workers=3, submit_t=0.0,
                               start_t=10.0, end_t=20.0)
    assert derived.node_s == -1.0     # sentinel: derive n_workers*held
    assert derived.node_seconds == pytest.approx(30.0)
    billed = AllocationRecord(alloc_id=3, n_workers=3, submit_t=0.0,
                              start_t=10.0, end_t=20.0, node_s=12.5)
    assert billed.node_seconds == 12.5   # explicit billing wins
    zero = AllocationRecord(alloc_id=4, n_workers=3, submit_t=0.0,
                            start_t=10.0, end_t=20.0, node_s=0.0)
    assert zero.node_seconds == 0.0      # 0 is a value, not the sentinel


def test_allocation_record_negative_held_clamps_to_zero():
    rec = AllocationRecord(alloc_id=5, n_workers=2, submit_t=0.0,
                           start_t=20.0, end_t=10.0)
    assert rec.held_s == 0.0


# --------------------------------------------------------------------------
# sd_histogram degenerate inputs
# --------------------------------------------------------------------------
def test_sd_histogram_empty():
    assert sd_histogram([]) == {"edges": [], "counts": []}


def test_sd_histogram_single_value_degenerate_range():
    h = sd_histogram([0.3, 0.3, 0.3], n_bins=4)
    assert len(h["edges"]) == 5 and len(h["counts"]) == 4
    assert sum(h["counts"]) == 3.0
    assert h["edges"][0] == pytest.approx(0.3)
    assert h["edges"][-1] > h["edges"][0]     # widened, never zero-width
    assert all(b >= a for a, b in zip(h["edges"], h["edges"][1:]))


def test_sd_histogram_counts_partition_the_samples():
    xs = [0.0, 0.1, 0.2, 0.5, 1.0]
    h = sd_histogram(xs, n_bins=5)
    assert sum(h["counts"]) == float(len(xs))
    assert h["counts"][-1] >= 1.0             # max lands in the last bin


# --------------------------------------------------------------------------
# TaskRecord.overhead clamping + killed-record shape
# --------------------------------------------------------------------------
def test_task_record_overhead_clamps_at_zero():
    # cpu_time exceeding the makespan window (clock skew, rounding) must
    # never read as negative overhead
    r = TaskRecord(task_id="t", submit_t=0.0, start_t=0.0, end_t=5.0,
                   cpu_time=9.0, compute_t=9.0)
    assert r.overhead == 0.0


def test_task_record_overhead_positive_case():
    r = TaskRecord(task_id="t", submit_t=0.0, start_t=3.0, end_t=10.0,
                   cpu_time=6.0, compute_t=5.0)
    assert r.overhead == pytest.approx(4.0)


def test_killed_task_record_canonical_shape():
    r = killed_task_record("t9", submit_t=2.0, now=50.0, alloc_id=3,
                           attempts=4)
    assert r.start_t == r.end_t == 50.0
    assert r.cpu_time == 0.0 and r.compute_t == 0.0
    assert r.worker == "alloc3" and r.status == "failed"
    assert r.attempts == 4
    # all wall time since submit is overhead: nothing was ever banked
    assert r.overhead == pytest.approx(48.0)
