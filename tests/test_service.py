"""Multi-tenant broker service: journal atomicity (including a real
SIGKILL mid-write), predictor state persistence through
snapshot/restore, labelled-metrics cardinality bounds, and the
`ServiceBroker` ingestion / backpressure / crash-recovery contract.

The crash-safety bar: a broker killed at an arbitrary instant restarts
from its newest loadable journal with ZERO lost tasks — every admitted
task reaches the same terminal record set an uninterrupted run produces
(at-least-once execution; results are keyed by task id, so re-running a
task that finished after the last snapshot changes nothing)."""
import json
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.checkpoint import Journal
from repro.core import EvalRequest, EvalResult
from repro.core.task import LambdaModel
from repro.obs.registry import MetricsRegistry
from repro.sched.predictor import GPRuntimePredictor, QuantileEstimator
from repro.service import Backpressure, ServiceBroker


def _toy():
    return LambdaModel("toy", lambda p, c: [[float(p[0][0]) * 2]], 1, 1)


def _slow(dt=0.05):
    def fn(p, c):
        time.sleep(dt)
        return [[float(p[0][0])]]
    return LambdaModel("toy", fn, 1, 1)


def _req(i, tenant="a", **kw):
    return EvalRequest("toy", [[float(i)]], time_request=1.0,
                       time_limit=30.0, tenant=tenant, **kw)


# --------------------------------------------------------------------------
# journal
# --------------------------------------------------------------------------
def test_journal_write_load_latest(tmp_path):
    j = Journal(tmp_path, keep=3)
    for i in range(5):
        j.write({"i": i})
    # keep-N gc: only the last 3 sequences survive
    assert j.seqs() == [3, 4, 5]
    assert j.latest() == (5, {"i": 4})
    assert j.load(3) == {"i": 2}


def test_journal_skips_corrupt_latest(tmp_path):
    j = Journal(tmp_path, keep=5)
    j.write({"good": 1})
    j.write({"good": 2})
    # simulate a torn write published by a broken filesystem
    (tmp_path / "journal_00000003.json").write_text('{"seq": 3, "sta')
    assert j.latest() == (2, {"good": 2})
    # a fresh Journal still resumes numbering past the corrupt file
    j2 = Journal(tmp_path, keep=5)
    j2.write({"good": 3})
    assert j2.latest() == (4, {"good": 3})


def test_journal_no_tmp_debris(tmp_path):
    j = Journal(tmp_path, keep=2)
    j.write({"x": [1, 2, 3]})
    assert [p.name for p in tmp_path.iterdir()] == ["journal_00000001.json"]
    with pytest.raises(TypeError):
        j.write({"bad": object()})             # not JSON-able: fail loudly
    # the failed write left no tmpfile and no half-published journal
    assert [p.name for p in tmp_path.iterdir()] == ["journal_00000001.json"]
    assert j.latest() == (1, {"x": [1, 2, 3]})


@pytest.mark.skipif(sys.platform == "win32", reason="POSIX signals")
def test_journal_survives_sigkill_mid_write(tmp_path):
    """SIGKILL a writer process at random instants: the newest LOADABLE
    journal must always parse and carry internally-consistent state
    (payload invariant: state['n'] values all equal state['seq_echo'])."""
    script = r"""
import sys
sys.path.insert(0, %r)
from repro.checkpoint import Journal
j = Journal(%r, keep=3)
i = j.latest_seq() or 0
while True:
    i += 1
    j.write({"seq_echo": i, "n": [i] * 2000})
""" % (os.path.join(os.path.dirname(os.path.dirname(__file__)), "src"),
       str(tmp_path))
    for round_no in range(4):
        proc = subprocess.Popen([sys.executable, "-c", script],
                                stdout=subprocess.DEVNULL,
                                stderr=subprocess.DEVNULL)
        # let it publish a few, then kill hard mid-stream
        deadline = time.monotonic() + 10.0
        j = Journal(tmp_path, keep=3)
        while j.latest_seq() is None or j.latest_seq() < 2 * (round_no + 1):
            if time.monotonic() > deadline:
                break
            time.sleep(0.005)
        proc.send_signal(signal.SIGKILL)
        proc.wait()
        loaded = Journal(tmp_path, keep=3).latest()
        assert loaded is not None, "no loadable journal after SIGKILL"
        seq, state = loaded
        assert state["n"] == [state["seq_echo"]] * 2000, \
            "journal state torn across the kill"
    # no tmpfile debris counted as journals
    for p in tmp_path.iterdir():
        if p.suffix == ".tmp":
            continue                           # orphaned tmp is allowed…
        assert p.name.startswith("journal_")   # …but never a torn journal


# --------------------------------------------------------------------------
# predictor persistence (satellite: snapshot/restore round-trips GP state)
# --------------------------------------------------------------------------
def test_quantile_estimator_state_roundtrip():
    q = QuantileEstimator(window=16)
    for i in range(10):
        q.observe(_req(i, tenant="default"), compute_t=float(i + 1))
    state = q.state_dict()
    assert json.loads(json.dumps(state)) == state     # JSON-able
    q2 = QuantileEstimator(window=16)
    q2.load_state(state)
    r = _req(99)
    assert q2.predict(r) == q.predict(r)
    assert q2.quantile(0.95, "toy") == q.quantile(0.95, "toy")


def test_gp_predictor_state_roundtrip():
    gp = GPRuntimePredictor(min_fit=4, fit_steps=5, backend="incremental")
    for i in range(6):
        gp.observe(_req(i), compute_t=0.5 + 0.1 * i)
    state = gp.state_dict()
    assert state["backend"] == "incremental"
    assert json.loads(json.dumps(state)) == state     # JSON-able
    gp2 = GPRuntimePredictor(min_fit=4, fit_steps=5)  # default backend
    gp2.load_state(state)
    # the persisted engine backend wins over the constructor default
    assert gp2.backend == "incremental"
    assert gp2.n_observed("toy") == gp.n_observed("toy")
    p1, p2 = gp.predict(_req(3)), gp2.predict(_req(3))
    assert p1 is not None and p2 is not None
    assert p2 == pytest.approx(p1, rel=0.2)


def test_executor_snapshot_carries_predictor_and_tenant():
    from repro.core.executor import Executor
    ex = Executor({"toy": _toy}, n_workers=1,
                  predictor=GPRuntimePredictor(min_fit=4, fit_steps=5,
                                               backend="incremental"))
    ex.run_all([_req(i, tenant="t1") for i in range(5)])
    snap = ex.snapshot()
    ex.shutdown()
    assert snap["predictor"] is not None
    assert snap["predictor"]["backend"] == "incremental"
    ex2 = Executor.restore(
        snap, {"toy": _toy}, n_workers=1,
        predictor=GPRuntimePredictor(min_fit=4, fit_steps=5))
    try:
        assert ex2.predictor.backend == "incremental"
        assert ex2.predictor.n_observed("toy") == 5
    finally:
        ex2.shutdown()


def test_snapshot_pending_records_tenant(tmp_path):
    """Pending payloads carry the tenant, so a recovered broker refills
    the right per-tenant queues."""
    from repro.core.executor import Executor
    ex = Executor({"toy": _toy}, n_workers=0)
    ex.submit(_req(0, tenant="vip"))
    snap = ex.snapshot()
    ex.shutdown()
    assert snap["pending"][0]["tenant"] == "vip"
    restored = EvalRequest(**snap["pending"][0])
    assert restored.tenant == "vip"


# --------------------------------------------------------------------------
# labelled metrics (satellite: bounded cardinality)
# --------------------------------------------------------------------------
def test_labeled_metrics_series():
    reg = MetricsRegistry()
    reg.inc("tasks_submitted", labels={"tenant": "a"})
    reg.inc("tasks_submitted", v=2.0, labels={"tenant": "b"})
    reg.inc("tasks_submitted")                 # unlabelled stays separate
    assert reg.counters["tasks_submitted{tenant=a}"] == 1.0
    assert reg.counters["tasks_submitted{tenant=b}"] == 2.0
    assert reg.counters["tasks_submitted"] == 1.0
    reg.set_gauge("queue_depth", 7.0, labels={"tenant": "a"})
    assert reg.gauges["queue_depth{tenant=a}"] == 7.0


def test_labeled_metrics_cardinality_cap():
    reg = MetricsRegistry(max_label_sets=4)
    for i in range(10):
        reg.inc("hits", labels={"tenant": f"t{i:02d}"})
    kept = [k for k in reg.counters if k.startswith("hits{")]
    assert len(kept) == 4                      # cap holds
    assert reg.counters["labels_dropped"] == 6.0
    # established series keep counting after the cap trips
    reg.inc("hits", labels={"tenant": "t00"})
    assert reg.counters["hits{tenant=t00}"] == 2.0


# --------------------------------------------------------------------------
# service broker
# --------------------------------------------------------------------------
def test_service_end_to_end_with_billing(tmp_path):
    with ServiceBroker({"toy": _toy}, weights={"a": 1.0, "b": 2.0},
                       journal_dir=str(tmp_path), journal_every_s=0.05,
                       n_workers=2, registry=MetricsRegistry()) as svc:
        reqs = [_req(i, tenant="a" if i % 2 else "b") for i in range(10)]
        res = svc.run_all(reqs, timeout=30.0)
        assert all(r.status == "ok" for r in res)
        bill = svc.billing()
        assert bill.get("a", 0.0) >= 0.0 and set(bill) == {"a", "b"}
        assert svc.open_tasks() == {}
        assert svc.registry.counters["tasks_submitted{tenant=a}"] == 5.0
        assert svc.registry.counters["tasks_ok{tenant=b}"] == 5.0
        path = svc.checkpoint()
        assert path is not None and os.path.exists(path)
    # context-manager shutdown published a final checkpoint
    assert Journal(tmp_path).latest() is not None


def test_service_backpressure_quota():
    svc = ServiceBroker({"toy": lambda: _slow(0.3)}, quotas={"a": 2},
                        n_workers=1)
    try:
        ids = [svc.submit(_req(i)) for i in range(2)]
        with pytest.raises(Backpressure) as ei:
            svc.submit(_req(9), block=False)
        assert ei.value.tenant == "a"
        assert ei.value.open_tasks == 2
        # bounded blocking submit times out while the queue stays full
        with pytest.raises(Backpressure):
            svc.submit(_req(9), timeout=0.05)
        # other tenants are unaffected by tenant a's quota
        other = svc.submit(_req(0, tenant="b"), block=False)
        # a blocking submit admits as soon as a slot frees
        t0 = time.monotonic()
        svc.submit(_req(3), timeout=10.0)
        assert time.monotonic() - t0 < 10.0
        for t in ids + [other]:
            assert svc.result(t, timeout=30.0).status == "ok"
    finally:
        svc.shutdown()


def test_service_deadline_slo_accounting():
    with ServiceBroker({"toy": lambda: _slow(0.05)}, n_workers=1) as svc:
        ok = svc.submit(_req(0, deadline=1e9))
        miss = svc.submit(_req(1, deadline=1e-9))
        svc.result(ok, 30.0), svc.result(miss, 30.0)
        c = svc.registry.counters
        assert c["deadline_total{tenant=a}"] == 2.0
        assert c["deadline_missed{tenant=a}"] == 1.0


def test_service_crash_recovery_zero_lost(tmp_path):
    """Kill mid-workload, recover from the journal: the terminal record
    set equals the uninterrupted run's — zero lost tasks."""
    reqs = [_req(i, tenant="a" if i % 3 else "b",
                 task_id=f"crash-{i}") for i in range(16)]

    # uninterrupted reference run
    with ServiceBroker({"toy": lambda: _slow(0.02)}, n_workers=2) as ref:
        ref_res = ref.run_all([EvalRequest(**{
            "model_name": r.model_name, "parameters": r.parameters,
            "time_request": r.time_request, "time_limit": r.time_limit,
            "tenant": r.tenant, "task_id": r.task_id}) for r in reqs],
            timeout=60.0)
    ref_terminal = {(r.task_id, r.status) for r in ref_res}

    svc = ServiceBroker({"toy": lambda: _slow(0.05)},
                        weights={"a": 1.0, "b": 4.0},
                        journal_dir=str(tmp_path), journal_every_s=0.02,
                        n_workers=2)
    ids = [svc.submit(r) for r in reqs]
    while len([r for r in svc.records() if r.status == "ok"]) < 6:
        time.sleep(0.01)
    svc.checkpoint()                           # deterministic snapshot
    svc.kill()                                 # hard crash, no cleanup
    done_before = {r.task_id for r in svc.records() if r.status == "ok"}
    assert 0 < len(done_before) < len(reqs)    # genuinely mid-workload

    svc2 = ServiceBroker.recover({"toy": lambda: _slow(0.05)},
                                 journal_dir=str(tmp_path), n_workers=2)
    try:
        # recovered config came from the journal
        assert svc2.weights == {"a": 1.0, "b": 4.0}
        res = [svc2.result(t, timeout=60.0) for t in ids]
        assert {(r.task_id, r.status) for r in res} == ref_terminal
        assert all(r.status == "ok" for r in res)
        # billing survived the crash
        assert sum(svc2.billing().values()) > 0.0
    finally:
        svc2.shutdown()


def test_service_recover_empty_dir(tmp_path):
    svc = ServiceBroker.recover({"toy": _toy}, journal_dir=str(tmp_path),
                                n_workers=1)
    try:
        assert svc.result(svc.submit(_req(0)), 30.0).status == "ok"
    finally:
        svc.shutdown()


def test_service_default_tenant_single_owner_path():
    """No tenants configured, untagged requests: the service behaves as
    a plain executor front-end (default tenant, no quotas)."""
    with ServiceBroker({"toy": _toy}, n_workers=1) as svc:
        r = EvalRequest("toy", [[2.0]], time_request=1.0, time_limit=10.0)
        assert r.tenant == "default"
        out = svc.result(svc.submit(r), 30.0)
        assert out.status == "ok" and out.value == [[4.0]]
        assert set(svc.billing()) == {"default"}
