"""Dependent-task MCMC + adaptive surrogate delegation (paper §VI)."""
import numpy as np
import pytest

from repro.core import Executor, LambdaModel
from repro.uq import adaptive, gp as gp_lib, mcmc, sampling


def _quad_model_factory():
    """Cheap analytic forward model: F(x) = [x0^2 + x1, x0 - x1^2]."""
    def fn(parameters, config):
        x = np.asarray(parameters[0], float)
        return [[float(x[0] ** 2 + x[1]), float(x[0] - x[1] ** 2)]]
    return LambdaModel("quad", fn, 2, 2)


BOUNDS = [(-2.0, 2.0), (-2.0, 2.0)]
TRUTH = np.array([0.8, -0.5])
OBSERVED = [TRUTH[0] ** 2 + TRUTH[1], TRUTH[0] - TRUTH[1] ** 2]


def test_mcmc_chain_converges_toward_posterior():
    with Executor({"quad": _quad_model_factory}, n_workers=2) as ex:
        res = mcmc.run_chain(ex, "quad", x0=np.array([0.0, 0.0]),
                             bounds=BOUNDS, observed=OBSERVED,
                             n_steps=120, step_scale=0.03, sigma=0.1,
                             seed=3)
    assert res.n_evals == 121
    assert 0.05 < res.accept_rate < 0.95
    # the second half of the chain should fit the data much better
    first, second = res.log_likelihoods[:40], res.log_likelihoods[-40:]
    assert second.mean() > first.mean()
    # posterior mass near a solution consistent with the observation
    tail = res.samples[-40:]
    f1 = tail[:, 0] ** 2 + tail[:, 1]
    assert abs(np.median(f1) - OBSERVED[0]) < 0.3


def test_mcmc_multiple_chains_interleave():
    with Executor({"quad": _quad_model_factory}, n_workers=3) as ex:
        results = mcmc.run_chains(
            ex, "quad", x0s=[np.zeros(2), np.ones(2) * 0.5],
            bounds=BOUNDS, observed=OBSERVED, n_steps=30,
            step_scale=0.1, sigma=0.1)
    assert len(results) == 2
    assert all(r.n_evals == 31 for r in results)
    # chains are distinct (different seeds)
    assert not np.allclose(results[0].samples, results[1].samples)


def test_adaptive_delegation_reduces_simulator_calls():
    rng = np.random.default_rng(0)
    xs_train = rng.uniform(-2, 2, (40, 2)).astype(np.float32)
    ys_train = np.stack([xs_train[:, 0] ** 2 + xs_train[:, 1],
                         xs_train[:, 0] - xs_train[:, 1] ** 2], 1)
    post = gp_lib.fit(xs_train, ys_train, steps=200)

    # request stream: half near the training data (surrogate-safe), half
    # far outside (forces simulator runs)
    near = rng.uniform(-1.5, 1.5, (10, 2)).astype(np.float32)
    with Executor({"quad": _quad_model_factory}, n_workers=2) as ex:
        res = adaptive.evaluate_stream(ex, "quad", post, near,
                                       sd_threshold=0.25)
    assert res.n_sim_calls < len(near)          # some surrogate hits
    # every output is accurate regardless of path taken
    want = np.stack([near[:, 0] ** 2 + near[:, 1],
                     near[:, 0] - near[:, 1] ** 2], 1)
    np.testing.assert_allclose(res.outputs, want, atol=0.35)
    # simulator outputs are exact
    np.testing.assert_allclose(res.outputs[res.used_simulator],
                               want[res.used_simulator], atol=1e-5)


def test_adaptive_conditioning_enriches_surrogate():
    rng = np.random.default_rng(1)
    xs = rng.uniform(-0.5, 0.5, (15, 2)).astype(np.float32)
    ys = np.stack([xs[:, 0] ** 2 + xs[:, 1], xs[:, 0] - xs[:, 1] ** 2], 1)
    post = gp_lib.fit(xs, ys, steps=150)
    probe = np.array([[1.8, 1.8]], np.float32)   # far from training data
    _, var_before = gp_lib.predict(post, probe)
    with Executor({"quad": _quad_model_factory}, n_workers=1) as ex:
        res = adaptive.evaluate_stream(ex, "quad", post, probe,
                                       sd_threshold=0.01)
    assert res.n_sim_calls == 1
    _, var_after = gp_lib.predict(res.posterior, probe)
    # per-output [1, M] variances: every output sharpens at the probe
    assert np.all(np.asarray(var_after)[0] < np.asarray(var_before)[0])
